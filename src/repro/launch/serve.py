"""Serving driver: batched requests against a (reduced) model.

Demonstrates the full serving path — batched prefill, token-by-token
decode with KV/SSM caches, greedy & temperature sampling, and slot-based
continuous batching (a finished request's slot is re-prefilled without
disturbing the rest of the batch).

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.serve import Engine, SamplingParams


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=2,
                    help="waves of requests (continuous batching demo)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)

    from repro.models.transformer import init_params
    params = init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    max_len = args.prompt_len + args.max_new + 8
    eng = Engine(cfg, params, batch=args.batch, max_len=max_len)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M batch={args.batch} "
          f"max_len={max_len}")

    sp = SamplingParams(temperature=args.temperature)
    total_tokens = 0
    t0 = time.time()
    for wave in range(args.requests):
        prompts = rng.integers(
            0, cfg.vocab_size - 1, (args.batch, args.prompt_len)).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = jnp.asarray(rng.standard_normal(
                (args.batch, 64, cfg.d_model), dtype=np.float32))
        out = eng.generate(jnp.asarray(prompts), max_new=args.max_new, sp=sp,
                           key=jax.random.fold_in(key, wave), enc_embeds=enc)
        total_tokens += out.size
        print(f"wave {wave}: generated {out.shape} tokens; "
              f"sample row: {out[0, :10].tolist()}")
    dt = time.time() - t0
    print(f"throughput: {total_tokens / dt:.1f} tok/s "
          f"({total_tokens} tokens in {dt:.1f}s)")


if __name__ == "__main__":
    main()
