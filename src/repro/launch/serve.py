"""Serving driver: batched requests against a (reduced) model.

Two modes:

* **wave** (default) — fixed-size request waves through ``Engine.generate``
  (batched prefill, token-by-token decode, greedy & temperature sampling).
  Throughput counts *real* generated tokens (stop-token padding rows after
  early termination are excluded) and the first wave — which pays jit
  compilation — is reported separately and excluded from the steady-state
  tok/s.
* **trace** (``--trace``) — request-level continuous batching through
  ``ServeScheduler``: Poisson arrivals, mixed prompt lengths, admission
  control, prefill-into-free-slot / decode-live-batch / retire lifecycle.
  ``--engine hypar`` routes every request through the core job machinery
  (dynamic control-spawned jobs, MasterScheduler placement, ResultStore
  retention) — see DESIGN.md §8.  ``--paged`` swaps the dense per-slot KV
  cache for the paged pool + chunked-prefill path (admission by free pages,
  long prompts interleaved with decode steps) — see DESIGN.md §9.

Examples::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --max-new 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --trace --engine hypar --n-requests 32 --rate 64
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --trace --paged --prefill-chunk 32 --prompt-lens 8 16 96
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.serve import (Engine, HyParRequestTracker, PagedEngine, Request,
                         RequestQueue, SamplingParams, ServeScheduler,
                         count_generated)


def build_trace(rng: np.random.Generator, cfg, *, n_requests: int,
                rate_per_s: float, prompt_lens: list[int],
                max_new, budget_new: int | None = None,
                shared_prefix_len: int = 0,
                ttft_deadline_s: float | None = None,
                total_deadline_s: float | None = None) -> list[Request]:
    """Open-loop request trace: Poisson arrivals (exponential gaps at
    ``rate_per_s``), prompt lengths drawn uniformly from ``prompt_lens``.

    ``max_new`` may be a single realised length or a mix to draw from per
    request; ``budget_new`` is the declared generation cap clients submit
    alongside (admission must provision for it — full-lifetime reservation
    pays its pages even when the realised length stops far short, which is
    the over-provisioning reserve-on-demand exists to reclaim).

    ``shared_prefix_len`` > 0 makes every prompt open with the SAME token
    prefix (a system prompt) followed by a random remainder — the workload
    shape prefix caching exists for.

    ``ttft_deadline_s`` / ``total_deadline_s`` stamp the same SLO onto every
    request; the scheduler sheds requests predicted to miss the TTFT
    deadline and retires ones past the total deadline (DESIGN.md §14)."""
    t = 0.0
    mix = [int(m) for m in np.atleast_1d(max_new)]
    prefix = None
    if shared_prefix_len > 0:
        if min(prompt_lens) <= shared_prefix_len:
            raise ValueError(f"every prompt length {tuple(prompt_lens)} must "
                             f"exceed shared_prefix_len {shared_prefix_len} "
                             f"(each prompt = prefix + random remainder)")
        prefix = rng.integers(0, cfg.vocab_size - 1,
                              (shared_prefix_len,)).astype(np.int32)
    reqs = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / rate_per_s) if rate_per_s > 0 else 0.0
        S = int(rng.choice(prompt_lens))
        toks = rng.integers(0, cfg.vocab_size - 1, (S,)).astype(np.int32)
        if prefix is not None:
            toks = np.concatenate([prefix, toks[shared_prefix_len:]])
        enc = None
        if cfg.family == "encdec":
            enc = jnp.asarray(rng.standard_normal(
                (1, 64, cfg.d_model), dtype=np.float32))
        reqs.append(Request(rid=rid, tokens=toks,
                            max_new=int(rng.choice(mix)),
                            budget_new=budget_new,
                            arrival_s=t, enc_embeds=enc,
                            ttft_deadline_s=ttft_deadline_s,
                            total_deadline_s=total_deadline_s))
    return reqs


def warmup_requests(rng: np.random.Generator, cfg, *, prompt_lens,
                    ) -> list[Request]:
    """One request per distinct prompt length, all arriving at t=0 — pays
    every per-bucket slot-prefill compilation before the measured run."""
    reqs = []
    for rid, S in enumerate(sorted(set(int(l) for l in prompt_lens))):
        toks = rng.integers(0, cfg.vocab_size - 1, (S,)).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = jnp.asarray(rng.standard_normal(
                (1, 64, cfg.d_model), dtype=np.float32))
        reqs.append(Request(rid=rid, tokens=toks, max_new=2, arrival_s=0.0,
                            enc_embeds=enc))
    return reqs


def make_scheduler(cfg, params, args, *, sp: SamplingParams,
                   max_len: int) -> ServeScheduler:
    mesh = None
    device_groups = 1
    if getattr(args, "mesh", None):
        from repro.serve.mesh import MeshSpec, build_serve_mesh
        spec = MeshSpec.parse(args.mesh)
        mesh = build_serve_mesh(spec)
        device_groups = spec.dp
    if getattr(args, "paged", False):
        eng = PagedEngine(cfg, params, batch=args.batch, max_len=max_len,
                          page_size=args.page_size,
                          num_pages=args.num_pages,
                          prefill_chunk=args.prefill_chunk,
                          mesh=mesh,
                          attn_impl=getattr(args, "paged_attn_impl",
                                            "auto"))
    else:
        eng = Engine(cfg, params, batch=args.batch, max_len=max_len)
    tracker = None
    if args.engine == "hypar":
        jobstore = None
        if getattr(args, "store", ""):
            from repro.core.store import JobStore
            jobstore = JobStore(args.store)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        tracker = HyParRequestTracker(args.batch, strategy=args.strategy,
                                      flops_per_token=2.0 * n_params,
                                      jobstore=jobstore)
    buckets = sorted({1 << (int(l) - 1).bit_length() for l in args.prompt_lens
                      if l < max_len} | {16})
    return ServeScheduler(eng, sp=sp, tracker=tracker, buckets=buckets,
                          queue=RequestQueue(max_pending=args.max_pending),
                          reserve=getattr(args, "reserve", "lifetime"),
                          preempt_policy=getattr(args, "preempt_policy",
                                                 "fewest"),
                          admit_watermark=getattr(args, "admit_watermark", 0),
                          prefix_cache=getattr(args, "prefix_cache", False),
                          prefix_admit=getattr(args, "prefix_admit", 1),
                          device_groups=device_groups,
                          enforce_deadlines=getattr(args, "enforce_deadlines",
                                                    True),
                          watchdog_budget_s=getattr(args, "watchdog_budget",
                                                    None),
                          max_restarts=getattr(args, "max_restarts", None))


def prepare_trace(cfg, params, args, *, sp: SamplingParams):
    """Build a warmed scheduler + the request trace for it.

    Warmup runs on the SAME scheduler: Engine jit caches are per-instance,
    so a throwaway warmup engine would leave the measured replays to pay
    every prefill/decode/splice compilation they claim to have excluded.
    """
    max_len = max(args.prompt_lens) + args.max_new + 8
    rng = np.random.default_rng(args.seed)
    sched = make_scheduler(cfg, params, args, sp=sp, max_len=max_len)
    # the trace is drawn BEFORE the warmup touches the rng: warmup length
    # sets vary per engine configuration (e.g. the chunk-bucket warmup
    # below), and compared variants must replay the IDENTICAL trace
    mix = getattr(args, "max_new_mix", None)
    reqs = build_trace(rng, cfg, n_requests=args.n_requests,
                       rate_per_s=args.rate, prompt_lens=list(args.prompt_lens),
                       max_new=(mix if mix else args.max_new),
                       budget_new=(args.max_new if mix else None),
                       shared_prefix_len=getattr(args, "shared_prefix_len",
                                                 0),
                       ttft_deadline_s=getattr(args, "ttft_deadline", None),
                       total_deadline_s=getattr(args, "total_deadline", None))
    warm_lens = list(args.prompt_lens)
    if getattr(sched, "demand", False):
        # resume re-prefills (prompt + retained tokens) land in arbitrary
        # chunk buckets, not just the trace's prompt lengths — warm every
        # bucket so no measured replay pays a chunk-program compile
        warm_lens += [b for b in sched.engine.chunk_buckets
                      if b + 2 <= sched.engine.max_len]
    sched.run(warmup_requests(rng, cfg, prompt_lens=warm_lens))
    sched.reset_metrics()          # warmup rids recur in the second pass
    # second, compile-free pass: the first pass's steps are dominated by
    # compiles, and the step/retire EWMAs (which deliberately survive
    # reset_metrics as shedding calibration, DESIGN.md §14) would otherwise
    # enter the measured replays 100-1000x above steady state
    sched.run(warmup_requests(rng, cfg, prompt_lens=list(args.prompt_lens)))
    sched.reset_metrics()
    if getattr(sched, "prefix_cache_active", False):
        # drop the warmup prompts' cache entries (and their held pages):
        # measured replays start from a cold cache and earn their hits from
        # the trace's own shared prefixes
        sched.flush_prefix_cache()
    return sched, reqs


def replay_trace(sched, reqs) -> tuple:
    """One measured replay of ``reqs`` on a warmed scheduler.  Returns a
    ``(tok_per_s, results, wall, occupancy, n_rejected)`` snapshot and
    resets the scheduler's metrics for the next replay.  (``run()`` rebases
    each request's arrival onto the live clock, so every replay gets fresh
    Request copies.)"""
    replay = [dataclasses.replace(r) for r in reqs]
    t0 = time.perf_counter()
    results = sched.run(replay)
    wall = time.perf_counter() - t0
    rate = sum(r.n_generated for r in results) / wall if wall > 0 else 0.0
    # preempt/defer counters ride in the snapshot: reset_metrics() clears
    # them on the scheduler, so trace_stats cannot read them post hoc
    outcome_hist: dict[str, int] = {}
    for o in sched.outcomes.values():
        outcome_hist[o.outcome] = outcome_hist.get(o.outcome, 0) + 1
    robust = {
        "shed_queue_full": sched.queue.shed_queue_full,
        "shed_never_fits": sched.queue.shed_never_fits,
        "shed_deadline": sched.queue.shed_deadline,
        "outcomes": outcome_hist,
        "goodput_tokens": sched.goodput_tokens,
        "watchdog_trips": sched.watchdog_trips,
        "n_expired": sched.n_expired,
        "n_failed": sched.n_failed,
        "group_failovers": sched.n_group_failovers,
        "group_rejoins": sched.n_group_rejoins,
        "rejoin_backoff_s": sched.rejoin_backoff_s,
        "suspended_rids": sorted(sched._suspended),
    }
    snap = (rate, results, wall, sched.occupancy, sched.queue.n_rejected,
            sched.n_preempted, sched.resume_tokens_recomputed,
            sched.n_admit_deferred, sched.n_prefix_lookups,
            sched.n_prefix_hits, sched.pages_shared, sched.n_cow_copies,
            sched.n_cache_insert_deferred, tuple(sched.group_occupancy),
            robust)
    sched.reset_metrics()              # also clears occupancy + counters
    return snap


def run_trace(cfg, params, args, *, sp: SamplingParams,
              repeats: int = 1) -> dict:
    sched, reqs = prepare_trace(cfg, params, args, sp=sp)
    if getattr(args, "resume", False):
        # master restart: re-seed suspended-request records from the durable
        # store — resubmitted rids resume by recompute (DESIGN.md §12)
        n = sched.restore_suspended()
        print(f"restored {n} suspended request(s) from {args.store}")
    # ``repeats``: replay the SAME trace N times on the warmed scheduler and
    # keep the fastest replay — the serve benchmark's noise floor on shared
    # CI/CPU boxes is far above the engine differences it wants to resolve,
    # and best-of-N is the same discipline kernel_bench applies per-op.
    # (benchmarks/serve_bench.py goes further and ROUND-ROBINS the replays
    # of the engines it compares, so minute-scale machine drift cannot land
    # entirely on one engine's measurements.)
    snaps = [replay_trace(sched, reqs) for _ in range(max(1, repeats))]
    return trace_stats(args, sched, max(snaps, key=lambda s: s[0]))


def trace_stats(args, sched, snap) -> dict:
    """Build the stats dict from the best replay snapshot."""
    (_, results, wall, occupancy, n_rejected,
     n_preempted, resume_recomputed, n_deferred,
     n_lookups, n_hits, pages_shared, cow_copies,
     cache_insert_deferred, group_occupancy, robust) = snap
    n_tok = sum(r.n_generated for r in results)
    # NaN, not 0.0, when nothing completed: a broken/all-shed run must not
    # record perfect-looking latencies into the BENCH trajectory
    ttfts = (np.array([r.ttft_s for r in results]) if results
             else np.array([np.nan]))
    lats = (np.array([l for r in results for l in r.step_latencies_s])
            if any(r.step_latencies_s for r in results)
            else np.array([np.nan]))
    eng = sched.engine
    trace_counts = ({"chunk_prefill": eng.trace_count("chunk_prefill"),
                     "decode": eng.trace_count("decode")}
                    if sched.paged else
                    {"prefill": eng.trace_count("prefill"),
                     "decode": eng.trace_count("decode"),
                     "splice": eng.trace_count("splice")})
    stats = {
        "engine": args.engine,
        "paged": sched.paged,
        "n_requests": len(results),
        "n_rejected": n_rejected,
        "gen_tokens": n_tok,
        "wall_s": wall,
        "tok_per_s": n_tok / wall if wall > 0 else 0.0,
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p95_s": float(np.percentile(ttfts, 95)),
        "lat_p50_s": float(np.percentile(lats, 50)),
        "lat_p95_s": float(np.percentile(lats, 95)),
        "occupancy": occupancy,
        "trace_counts": trace_counts,
        "reserve": getattr(sched, "reserve", "lifetime"),
        "preempt_count": n_preempted,
        "resume_tokens_recomputed": resume_recomputed,
        "admit_deferred": n_deferred,
        "prefix_cache": sched.prefix_cache_active,
        "prefix_hit_rate": (n_hits / n_lookups if n_lookups else 0.0),
        "pages_shared": pages_shared,
        "cow_copies": cow_copies,
        "cache_insert_deferred": cache_insert_deferred,
        "mesh": getattr(args, "mesh", None) or None,
        "device_groups": len(sched.groups),
        "group_occupancy": [float(x) for x in group_occupancy],
        # robustness surface (DESIGN.md §14): typed shed counters, terminal
        # outcome histogram, deadline goodput, watchdog/failover counts
        **robust,
        "goodput_tok_per_s": (robust["goodput_tokens"] / wall
                              if wall > 0 else 0.0),
        "enforce_deadlines": getattr(sched, "enforce_deadlines", True),
    }
    if sched.paged:
        # per-device KV budget: pool tokens scaled by the byte fraction one
        # device holds — TP=2 halves it over kv_heads; DP=2 halves it over
        # pages whenever the pool size divides (odd pools stay replicated)
        total_b = eng.total_pool_bytes()
        dev_b = eng.per_device_pool_bytes()
        pool_tokens = eng.num_pages * eng.page_size
        stats["total_pool_bytes"] = total_b
        stats["per_device_pool_bytes"] = dev_b
        stats["kv_budget_tokens"] = (
            int(round(pool_tokens * dev_b / total_b)) if total_b else
            pool_tokens)
    return stats


def run_waves(cfg, params, args, *, sp: SamplingParams) -> None:
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    max_len = args.prompt_len + args.max_new + 8
    eng = Engine(cfg, params, batch=args.batch, max_len=max_len)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M batch={args.batch} "
          f"max_len={max_len}")

    total_tokens = steady_tokens = 0
    steady_s = 0.0
    for wave in range(args.requests):
        prompts = rng.integers(
            0, cfg.vocab_size - 1, (args.batch, args.prompt_len)).astype(np.int32)
        enc = None
        if cfg.family == "encdec":
            enc = jnp.asarray(rng.standard_normal(
                (args.batch, 64, cfg.d_model), dtype=np.float32))
        t0 = time.perf_counter()
        out = eng.generate(jnp.asarray(prompts), max_new=args.max_new, sp=sp,
                           key=jax.random.fold_in(key, wave), enc_embeds=enc)
        dt = time.perf_counter() - t0
        n_real = count_generated(out, sp.stop_token)
        total_tokens += n_real
        if wave == 0:
            print(f"wave 0 (compile): {n_real} tokens in {dt:.1f}s "
                  f"(excluded from steady-state tok/s)")
        else:
            steady_tokens += n_real
            steady_s += dt
        print(f"wave {wave}: generated {out.shape} -> {n_real} real tokens; "
              f"sample row: {out[0, :10].tolist()}")
    if steady_s > 0:
        print(f"throughput: {steady_tokens / steady_s:.1f} tok/s steady-state "
              f"({steady_tokens} tokens in {steady_s:.1f}s; "
              f"{total_tokens} total incl. compile wave)")
    else:
        print(f"throughput: n/a (single wave pays compilation; "
              f"{total_tokens} tokens)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=2,
                    help="waves of requests (wave mode)")
    # request-trace mode
    ap.add_argument("--trace", action="store_true",
                    help="request-level continuous batching from a trace")
    ap.add_argument("--engine", choices=["direct", "hypar"], default="direct",
                    help="trace mode: direct slot filling vs HyPar "
                         "dynamic-job scheduling")
    ap.add_argument("--strategy", choices=["greedy", "cost"], default="greedy",
                    help="hypar engine: MasterScheduler placement strategy")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, requests/s (0 = all at once)")
    ap.add_argument("--prompt-lens", type=int, nargs="+", default=[8, 16, 24],
                    help="trace mode: mixed prompt lengths")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission control: shed beyond this queue depth")
    ap.add_argument("--max-new-mix", type=int, nargs="+", default=None,
                    help="trace mode: realised generation lengths drawn "
                         "per request; --max-new then acts as the declared "
                         "cap admission provisions for")
    # paged KV + chunked prefill (trace mode)
    ap.add_argument("--paged", action="store_true",
                    help="trace mode: paged KV cache + chunked prefill "
                         "(admission by free pages)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged: tokens per KV page")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged: pool size incl. the trash page (default: "
                         "the dense engine's batch x max_len footprint)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="paged: prompt chunk length interleaved with "
                         "decode steps (multiple of --page-size)")
    ap.add_argument("--paged-attn-impl", default="auto",
                    choices=["auto", "kernel", "interpret", "ref"],
                    help="paged: decode attention impl — auto runs the "
                         "paged flash-decode Pallas kernel on TPU and the "
                         "gather_pages path elsewhere")
    ap.add_argument("--reserve", choices=["lifetime", "demand"],
                    default="lifetime",
                    help="paged: reserve a request's full prompt+budget "
                         "page span at admission (lifetime) or only its "
                         "prompt span, appending decode pages on demand "
                         "with vLLM-style preemption on exhaustion (demand)")
    ap.add_argument("--preempt-policy", choices=["fewest", "lifo"],
                    default="fewest",
                    help="demand: victim choice on pool exhaustion — "
                         "fewest generated tokens (LIFO tiebreak) or "
                         "latest admitted")
    ap.add_argument("--admit-watermark", type=int, default=0,
                    help="demand: free pages held back from admissions as "
                         "decode-append headroom")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged: share cache-hit prompt prefixes across "
                         "slots (copy-on-write pages)")
    ap.add_argument("--prefix-admit", type=int, default=1,
                    help="prefix cache: insert a prefix only on its Nth "
                         "sighting (N=1 inserts immediately); first "
                         "sightings hash host-side without taking pool "
                         "references")
    ap.add_argument("--mesh", default=None, metavar="TP,DP",
                    help="paged: shard the engine over a TPxDP device mesh "
                         "— KV heads over TP (one model replica), batch "
                         "slots + page pool over DP device groups "
                         "(DESIGN.md §13).  '1,1' forces the mesh code "
                         "path on one device (bit-identical to no mesh)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="trace mode: replay the trace N times on the "
                         "warmed scheduler and report the fastest")
    ap.add_argument("--stats-json", default="",
                    help="trace mode: dump the stats dict to this path as "
                         "JSON (the sharded bench reads it back)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="trace mode: every prompt opens with the same "
                         "token prefix of this length (system-prompt "
                         "workload; pairs with --prefix-cache)")
    ap.add_argument("--store", default="",
                    help="hypar engine: durable job-store path — suspended "
                         "requests' host-retained tokens persist, so "
                         "recovery survives a master restart (DESIGN.md "
                         "§12)")
    ap.add_argument("--resume", action="store_true",
                    help="hypar engine: re-seed suspended requests from "
                         "--store before replaying (requires --reserve "
                         "demand)")
    ap.add_argument("--store-gc", type=float, default=None, metavar="SECS",
                    help="after the run, prune done job-store rows older "
                         "than this many seconds (and their spill files)")
    ap.add_argument("--store-gc-rows", type=int, default=None, metavar="N",
                    help="after the run, keep at most N most-recent done "
                         "job-store rows")
    # deadline-aware serving + robustness (DESIGN.md §14)
    ap.add_argument("--ttft-deadline", type=float, default=None,
                    metavar="SECS",
                    help="trace mode: stamp this first-token deadline on "
                         "every request; admission sheds requests whose "
                         "predicted TTFT already exceeds it")
    ap.add_argument("--total-deadline", type=float, default=None,
                    metavar="SECS",
                    help="trace mode: stamp this whole-answer deadline on "
                         "every request; requests past it are retired as "
                         "expired")
    ap.add_argument("--no-enforce-deadlines", dest="enforce_deadlines",
                    action="store_false",
                    help="observe deadlines in the goodput metric but never "
                         "shed or expire on them (the no-shedding baseline)")
    ap.add_argument("--watchdog-budget", type=float, default=None,
                    metavar="SECS",
                    help="wall-clock budget per prefill chunk / decode wave; "
                         "a step over budget trips the watchdog, frees the "
                         "slot and re-queues the request")
    ap.add_argument("--max-restarts", type=int, default=None, metavar="N",
                    help="fault-eviction budget per request; a request "
                         "evicted more than N times fails terminally "
                         "(default: unlimited)")
    args = ap.parse_args(argv)
    if (args.store or args.resume) and args.engine != "hypar":
        ap.error("--store/--resume require --engine hypar (the tracker "
                 "owns the durable store)")
    if args.resume and not (args.store and args.reserve == "demand"):
        ap.error("--resume needs --store and --reserve demand (resume "
                 "recompute is the demand-mode recovery path)")
    if args.paged and not args.trace:
        ap.error("--paged requires --trace (wave mode is dense-only)")
    if args.reserve == "demand" and not args.paged:
        ap.error("--reserve demand requires --paged")
    if args.admit_watermark and args.reserve != "demand":
        ap.error("--admit-watermark requires --reserve demand")
    if args.prefix_cache and not args.paged:
        ap.error("--prefix-cache requires --paged (dense slots have no "
                 "pages to share)")
    if args.shared_prefix_len and not args.trace:
        ap.error("--shared-prefix-len requires --trace")
    if args.prefix_admit < 1:
        ap.error("--prefix-admit must be >= 1")
    if args.mesh:
        from repro.serve.mesh import MeshSpec
        try:
            spec = MeshSpec.parse(args.mesh)
        except ValueError as e:
            ap.error(str(e))
        if spec.size > 1 and not args.paged:
            ap.error("--mesh with more than one device requires --paged "
                     "(the sharding rules cover the paged pool)")
    if not args.trace and (args.ttft_deadline is not None
                           or args.total_deadline is not None
                           or args.watchdog_budget is not None
                           or args.max_restarts is not None):
        ap.error("--ttft-deadline/--total-deadline/--watchdog-budget/"
                 "--max-restarts require --trace (wave mode has no "
                 "scheduler)")
    if (args.store_gc is not None or args.store_gc_rows is not None) \
            and not args.store:
        ap.error("--store-gc/--store-gc-rows need --store (nothing to "
                 "prune otherwise)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    sp = SamplingParams(temperature=args.temperature)

    if args.trace:
        stats = run_trace(cfg, params, args, sp=sp, repeats=args.repeats)
        kind = "paged" if stats["paged"] else "dense"
        print(f"engine={stats['engine']} ({kind}) "
              f"requests={stats['n_requests']} "
              f"(+{stats['n_rejected']} shed) tokens={stats['gen_tokens']} "
              f"traces={stats['trace_counts']}")
        if stats.get("mesh"):
            occ = ", ".join(f"{x*100:.0f}%" for x in stats["group_occupancy"])
            print(f"mesh={stats['mesh']} groups={stats['device_groups']} "
                  f"group_occupancy=[{occ}] per_device_pool="
                  f"{stats.get('per_device_pool_bytes', 0)}B "
                  f"kv_budget={stats.get('kv_budget_tokens', 0)} tokens")
        if stats["paged"]:
            print(f"reserve={stats['reserve']} "
                  f"preempts={stats['preempt_count']} "
                  f"resume_tokens_recomputed="
                  f"{stats['resume_tokens_recomputed']} "
                  f"admit_deferred={stats['admit_deferred']}")
        if stats["prefix_cache"]:
            print(f"prefix_cache: hit_rate={stats['prefix_hit_rate']*100:.0f}% "
                  f"pages_shared={stats['pages_shared']} "
                  f"cow_copies={stats['cow_copies']}")
        if (args.ttft_deadline is not None or args.total_deadline is not None
                or args.watchdog_budget is not None):
            print(f"deadlines: enforce={stats['enforce_deadlines']} "
                  f"goodput={stats['goodput_tok_per_s']:.1f} tok/s "
                  f"shed(queue={stats['shed_queue_full']} "
                  f"never_fits={stats['shed_never_fits']} "
                  f"deadline={stats['shed_deadline']}) "
                  f"expired={stats['n_expired']} failed={stats['n_failed']} "
                  f"watchdog_trips={stats['watchdog_trips']} "
                  f"failovers={stats['group_failovers']}")
        print(f"tok/s={stats['tok_per_s']:.1f} "
              f"ttft p50={stats['ttft_p50_s']*1e3:.1f}ms "
              f"p95={stats['ttft_p95_s']*1e3:.1f}ms "
              f"lat p50={stats['lat_p50_s']*1e3:.1f}ms "
              f"p95={stats['lat_p95_s']*1e3:.1f}ms "
              f"occupancy={stats['occupancy']*100:.0f}%")
        if args.stats_json:
            import json
            with open(args.stats_json, "w") as f:
                json.dump(stats, f, indent=1, default=float)
        _maybe_store_gc(args, live_rids=stats.get("suspended_rids", ()))
        return stats
    run_waves(cfg, params, args, sp=sp)
    _maybe_store_gc(args)
    return None


def _maybe_store_gc(args, live_rids=()) -> None:
    """Post-run job-store hygiene (``--store-gc`` / ``--store-gc-rows``).

    ``live_rids`` — rids still suspended on THIS run's scheduler; their
    durable recovery rows are exempt from the age prune (they are live
    recovery state, not orphans of a dead master)."""
    if args.store_gc is None and getattr(args, "store_gc_rows", None) is None:
        return
    from repro.core.store import JobStore
    from repro.serve import HyParRequestTracker
    store = JobStore(args.store)
    try:
        exempt = [f"{HyParRequestTracker.STORE_PREFIX}{rid}"
                  for rid in live_rids]
        pruned = store.gc(max_age_s=args.store_gc,
                          max_rows=args.store_gc_rows,
                          exempt_requests=exempt)
        print(f"store gc: pruned {pruned['rows']} done row(s), "
              f"{pruned['spill_files']} spill file(s), "
              f"{pruned['request_rows']} stale request row(s) from "
              f"{args.store}")
    finally:
        store.close()


if __name__ == "__main__":
    main()
