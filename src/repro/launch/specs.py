"""ShapeDtypeStruct stand-ins + sharding trees for every (arch × cell).

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable, zero allocation — everything the dry-run needs to lower and
compile the production step functions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.data.pipeline import make_batch_specs
from repro.models.config import SHAPE_CELLS, ModelConfig, ShapeCell
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, layer_plan, loss_fn)
from repro.optim import OptimizerSpec
from repro.parallel.partition import batch_logical_axes, tree_shardings
from repro.parallel.sharding import ShardingRules, DEFAULT_RULES, use_rules
from repro.train.step import TrainState, make_train_step

__all__ = ["CellSpec", "build_cell", "choose_grad_accum", "model_flops_for",
           "rules_for_cell"]


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch × shape × mesh) cell."""

    arch: str
    cell: ShapeCell
    fn: Callable                      # jit-able step function
    args: tuple                       # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    model_flops: float
    scan_trips: int
    grad_accum: int = 1


def rules_for_cell(mesh, cell: ShapeCell,
                   cfg: ModelConfig | None = None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    if cell.kind == "decode" and cell.global_batch == 1:
        # long-context decode, batch 1: shard the KV sequence over everything
        rules["kv_seq"] = ("data", "model")
    if cfg is not None and not cfg.activation_seq_shard:
        rules["seq"] = None          # H2: Megatron-style replicated residual
    return ShardingRules(mesh=mesh, rules=rules)


def choose_grad_accum(cfg: ModelConfig, cell: ShapeCell, n_data_shards: int,
                      *, tokens_per_device_micro: int = 8_192) -> int:
    """Pick microbatching so live activations fit HBM: target ≤ ~8k tokens
    per device per microbatch, scaled down for very wide models (fp32
    logits and saved layer boundaries are the live-set drivers)."""
    per_dev = cell.tokens // max(n_data_shards, 1)
    target = tokens_per_device_micro
    if cfg.d_model >= 8192:
        target //= 8
    elif cfg.d_model >= 4096:
        target //= 2
    if cfg.padded_vocab >= 150_000:
        target = min(target, 4_096)      # fp32 logits dominate
    accum = max(1, per_dev // target)
    # accum must divide the per-shard batch
    b = cell.global_batch
    while b % accum and accum > 1:
        accum -= 1
    return accum


def model_flops_for(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_params()
    if cell.kind == "train":
        return 6.0 * n * cell.tokens
    if cell.kind == "prefill":
        return 2.0 * n * cell.tokens
    return 2.0 * n * cell.global_batch          # decode: one token per slot


def _data_shards(rules: ShardingRules) -> int:
    return rules.axis_size(rules.rules.get("batch"))


def _serving_params_struct(cfg: ModelConfig):
    """Inference serves in compute dtype (bf16) — fp32 serving weights waste
    HBM and double the per-layer gather bytes (§Perf iteration 0)."""
    ps = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    cd = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, cd)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, ps)


def build_cell(arch: str, cfg: ModelConfig, cell_name: str,
               rules: ShardingRules) -> CellSpec:
    cell = SHAPE_CELLS[cell_name]
    plan = layer_plan(cfg)
    mesh = rules.mesh

    if cell.kind == "train":
        spec = OptimizerSpec(kind=cfg.optimizer)
        accum = choose_grad_accum(cfg, cell, _data_shards(rules))
        step = make_train_step(cfg, spec, grad_accum=accum)
        state_struct = jax.eval_shape(
            lambda k: TrainState.create(cfg, spec, k), jax.random.PRNGKey(0))
        batch_struct = make_batch_specs(cfg, cell.global_batch, cell.seq_len,
                                        kind="train")
        state_sh = tree_shardings(state_struct, rules, kind="state")
        batch_sh = jax.tree.map(
            lambda leaf: jax.sharding.NamedSharding(
                mesh, rules.spec_for(("batch",) + (None,) * (len(leaf.shape) - 1),
                                     dims=leaf.shape)),
            batch_struct)
        return CellSpec(
            arch=arch, cell=cell, fn=step,
            args=(state_struct, batch_struct),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
            model_flops=model_flops_for(cfg, cell),
            scan_trips=plan.scan_trips, grad_accum=accum)

    if cell.kind == "prefill":
        def prefill_fn(params, batch):
            logits, _ = forward(cfg, params,
                                tokens=batch.get("tokens"),
                                enc_embeds=batch.get("enc_embeds"))
            # serving returns only the last position (next-token)
            return logits[:, -1, :]

        params_struct = _serving_params_struct(cfg)
        batch_struct = make_batch_specs(cfg, cell.global_batch, cell.seq_len,
                                        kind="prefill")
        params_sh = tree_shardings(params_struct, rules, kind="params")
        batch_sh = jax.tree.map(
            lambda leaf: jax.sharding.NamedSharding(
                mesh, rules.spec_for(("batch",) + (None,) * (len(leaf.shape) - 1),
                                     dims=leaf.shape)),
            batch_struct)
        return CellSpec(
            arch=arch, cell=cell, fn=prefill_fn,
            args=(params_struct, batch_struct),
            in_shardings=(params_sh, batch_sh),
            out_shardings=None, donate_argnums=(),
            model_flops=model_flops_for(cfg, cell),
            scan_trips=plan.scan_trips)

    # ---- decode -----------------------------------------------------------
    # (unrolled decode graphs were tried and REFUTED for stacked caches:
    # 126 live buffer versions, 1.9 TiB/dev — EXPERIMENTS.md §Perf iter 6)
    B, S = cell.global_batch, cell.seq_len

    if cfg.family == "encdec":
        enc_struct = jax.ShapeDtypeStruct((B, 1500, cfg.d_model),
                                          jnp.dtype(cfg.compute_dtype))

        def serve_step(params, cache, tokens, enc_out):
            return decode_step(cfg, params, cache, tokens, enc_out=enc_out)
    else:
        enc_struct = None

        def serve_step(params, cache, tokens):
            return decode_step(cfg, params, cache, tokens)

    params_struct = _serving_params_struct(cfg)
    cache_struct = jax.eval_shape(
        functools.partial(init_cache, cfg, B, S, enc_len=0))
    tokens_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    params_sh = tree_shardings(params_struct, rules, kind="params")
    cache_sh = tree_shardings(cache_struct, rules, kind="cache")
    tok_sh = jax.sharding.NamedSharding(
        mesh, rules.spec_for(("batch", None), dims=(B, 1)))

    args = (params_struct, cache_struct, tokens_struct)
    in_sh = (params_sh, cache_sh, tok_sh)
    if enc_struct is not None:
        args = args + (enc_struct,)
        enc_sh = jax.sharding.NamedSharding(
            mesh, rules.spec_for(("batch", None, None), dims=enc_struct.shape))
        in_sh = in_sh + (enc_sh,)
    return CellSpec(
        arch=arch, cell=cell, fn=serve_step, args=args,
        in_shardings=in_sh,
        out_shardings=(None, cache_sh), donate_argnums=(1,),
        model_flops=model_flops_for(cfg, cell),
        scan_trips=plan.scan_trips)
