import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The FIRST two lines above run before any other import — JAX locks the
device count at first initialisation, and the dry-run needs 512 placeholder
host devices to build the production meshes (16×16 single-pod, 2×16×16
multi-pod).  Do NOT import this module from tests (they must see 1 device).

Per cell it prints ``compiled.memory_analysis()`` (proves fit),
``compiled.cost_analysis()`` and the scan-corrected roofline terms
(repro.analysis), and appends a JSON record to the results file.

Usage::

    python -m repro.launch.dryrun --arch qwen2-1.5b --cell train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out out.jsonl]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis.hlo import xla_cost_analysis
from repro.analysis.roofline import V5E, roofline_from_compiled
from repro.configs import ARCHS, cells_for, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, rules_for_cell
from repro.models.config import SHAPE_CELLS
from repro.parallel.sharding import use_rules


def run_cell(arch: str, cell_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = SHAPE_CELLS[cell_name]
    rules = rules_for_cell(mesh, cell, cfg)
    t0 = time.time()
    with use_rules(mesh, rules.rules):
        spec = build_cell(arch, cfg, cell_name, rules)
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        lowered = jitted.lower(*spec.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    terms = roofline_from_compiled(compiled, hw=V5E, n_chips=n_chips,
                                   model_flops=spec.model_flops)
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    per_dev = arg_b + out_b + tmp_b - alias_b
    fits = per_dev <= V5E.hbm_bytes

    rec = {
        "arch": arch, "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "grad_accum": spec.grad_accum,
        "compile_s": round(t_compile, 1),
        "bytes_per_device": per_dev, "fits_hbm": bool(fits),
        "arg_bytes": arg_b, "temp_bytes": tmp_b, "alias_bytes": alias_b,
        "hlo_flops_per_dev": terms.flops,
        "hlo_traffic_per_dev": terms.traffic_bytes,
        "collective_bytes_per_dev": terms.collective_bytes,
        "collective_counts": terms.analysis.collectives.counts,
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "model_flops": spec.model_flops,
        "useful_ratio": (spec.model_flops / n_chips) / terms.flops
        if terms.flops else 0.0,
        "roofline_fraction": ((spec.model_flops / n_chips) / terms.step_s)
        / V5E.peak_flops if terms.step_s else 0.0,
        "while_trips": terms.analysis.while_trips,
        "xla_flops_per_dev": cost.get("flops"),
    }
    if verbose:
        print(f"== {arch} × {cell_name} × {rec['mesh']} "
              f"(compile {t_compile:.0f}s, accum={spec.grad_accum})")
        print(f"   memory_analysis: args={arg_b/2**30:.2f}GiB "
              f"temp={tmp_b/2**30:.2f}GiB alias={alias_b/2**30:.2f}GiB "
              f"per-dev={per_dev/2**30:.2f}GiB fits16G={fits}")
        print(f"   roofline: compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms "
              f"collective={terms.collective_s*1e3:.2f}ms "
              f"dominant={terms.dominant} "
              f"useful={rec['useful_ratio']*100:.1f}% "
              f"roofline_frac={rec['roofline_fraction']*100:.1f}%")
        print(f"   collectives: " + " ".join(
            f"{k}:{v}" for k, v in rec["collective_counts"].items() if v))
    return rec


def plan_jobfile(path: str, *, n_workers: int = 4, cores: int = 1,
                 strategy: str = "cost", verbose: bool = True) -> list:
    """Placement dry-run for a paper-format job file (§3.3 grammar).

    Parses the text, then runs the MasterScheduler segment by segment
    *without executing anything* — a static preview of worker assignment,
    co-scheduling, spawning, and (with ``strategy="cost"``) the cost-model
    estimates.  No results exist yet, so locality terms are zero; what the
    preview shows is the queue/co-schedule structure.
    """
    from repro.core import (MasterScheduler, ResultStore, VirtualCluster,
                            parse_job_file)

    graph = parse_job_file(path)
    cluster = VirtualCluster(n_schedulers=1, cores_per_worker=cores,
                             max_workers=n_workers)
    master = MasterScheduler(graph, cluster, strategy=strategy)
    store = ResultStore(cluster)
    plans = []
    for i, seg in enumerate(graph.segments):
        placements = master.plan_segment(seg.jobs, store)
        plans.append(placements)
        if verbose:
            print(f"S{i}:")
            for p in placements:
                co = (f" co={','.join(p.co_scheduled_with)}"
                      if p.co_scheduled_with else "")
                est = f" est={p.est_cost_s * 1e6:.1f}us" if strategy == "cost" else ""
                print(f"  {p.job.name} -> worker {p.worker.wid} "
                      f"(seq={p.n_sequences}){co}{est}")
    return plans


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--cell", choices=list(SHAPE_CELLS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--jobfile", default="",
                    help="placement dry-run of a paper-format job file "
                         "instead of the arch x cell compile sweep")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--strategy", choices=["greedy", "cost"], default="cost")
    args = ap.parse_args(argv)

    if args.jobfile:
        plan_jobfile(args.jobfile, n_workers=args.workers,
                     strategy=args.strategy)
        return

    cells = []
    if args.all:
        for a in ARCHS:
            for c in cells_for(a):
                cells.append((a, c))
    elif args.arch and args.cell:
        cells = [(args.arch, args.cell)]
    elif args.arch:
        cells = [(args.arch, c) for c in cells_for(args.arch)]
    else:
        ap.error("need --arch [--cell] or --all")

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    out_f = open(args.out, "a") if args.out else None
    for arch, cell in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, cell, multi_pod=mp)
                if out_f:
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
            except Exception as e:
                failures.append((arch, cell, mp, repr(e)))
                traceback.print_exc()
    if out_f:
        out_f.close()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print(f"\nall {len(cells) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
