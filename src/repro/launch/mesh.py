"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the outer data/FSDP axis (the paper's scheduler-group level:
one scheduler per pod, workers = mesh slices; DESIGN.md §2).

Defined as functions so importing this module never touches JAX device
state (device count is locked at first use).
"""
from __future__ import annotations

import jax

__all__ = ["compat_make_mesh", "make_production_mesh", "make_test_mesh"]


def compat_make_mesh(shape, axis_names):
    """``jax.make_mesh`` across jax versions.

    Newer jax wants explicit ``axis_types=(AxisType.Auto, ...)`` for
    GSPMD-propagated shardings; older jax (< 0.5) has no ``AxisType`` and
    defaults to the same behaviour.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over however many (host) devices the process has."""
    if pod > 1:
        return compat_make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat_make_mesh((data, model), ("data", "model"))
