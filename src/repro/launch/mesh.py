"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the outer data/FSDP axis (the paper's scheduler-group level:
one scheduler per pod, workers = mesh slices; DESIGN.md §2).

Defined as functions so importing this module never touches JAX device
state (device count is locked at first use).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over however many (host) devices the process has."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
