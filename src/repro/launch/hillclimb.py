import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb runner: re-lower a cell after a code/config change and
diff its roofline terms against the recorded baseline.

Usage::

    python -m repro.launch.hillclimb --arch llama3-405b --cell train_4k \
        --baseline benchmarks/results/dryrun.jsonl \
        --log benchmarks/results/perf_iterations.jsonl \
        --note "H1: ZeRO-1 weight replication"
"""
import argparse
import json
import time

from repro.launch.dryrun import run_cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--baseline", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--log", default="benchmarks/results/perf_iterations.jsonl")
    ap.add_argument("--note", default="")
    args = ap.parse_args(argv)

    mesh = "2x16x16" if args.multi_pod else "16x16"
    base = None
    try:
        with open(args.baseline) as f:
            for line in f:
                r = json.loads(line)
                if (r["arch"], r["cell"], r["mesh"]) == (args.arch, args.cell, mesh):
                    base = r
    except FileNotFoundError:
        pass
    # later iterations logged for the same cell become the new comparison point
    try:
        with open(args.log) as f:
            for line in f:
                r = json.loads(line)
                if (r["arch"], r["cell"], r["mesh"]) == (args.arch, args.cell, mesh):
                    base = r
    except FileNotFoundError:
        pass

    rec = run_cell(args.arch, args.cell, multi_pod=args.multi_pod)
    rec["note"] = args.note
    rec["ts"] = time.strftime("%Y-%m-%d %H:%M:%S")

    if base is not None:
        print("\n=== delta vs previous ===")
        for k in ("compute_s", "memory_s", "collective_s", "bytes_per_device",
                  "roofline_fraction"):
            b, n = base[k], rec[k]
            d = (n / b - 1) * 100 if b else float("inf")
            unit = "GiB" if k == "bytes_per_device" else ""
            bb = b / 2**30 if unit else b
            nn = n / 2**30 if unit else n
            print(f"  {k:20s} {bb:12.4f} -> {nn:12.4f} {unit:4s} ({d:+.1f}%)")
        rec["baseline_dominant"] = base["dominant"]
        for k in ("compute_s", "memory_s", "collective_s"):
            rec[f"delta_{k}_pct"] = (rec[k] / base[k] - 1) * 100 if base[k] else None

    with open(args.log, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"\nlogged to {args.log}")


if __name__ == "__main__":
    main()
