"""Training driver.

Two schedulers (selectable): the *fused* SPMD step (tailored) and the
*HyPar* job-graph loop (the paper's framework).  Includes checkpointing
(async, elastic restore), straggler-free deterministic stepping, and a
crash-recovery path: on restart the driver resumes from the newest complete
checkpoint.

Example (the end-to-end deliverable — ~100M-param model, a few hundred
steps)::

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-1.5b --smoke-scale 0 --steps 300 --batch 8 --seq 512 \
        --ckpt-dir /tmp/run1 --data-axis 1 --model-axis 1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import DataConfig, Prefetcher, SyntheticLMStream
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec, cosine_schedule
from repro.parallel.partition import tree_shardings
from repro.parallel.sharding import DEFAULT_RULES, ShardingRules, use_rules
from repro.train import TrainState, make_train_step


def scale_config(cfg: ModelConfig, *, layers: int, d_model: int,
                 seq: int) -> ModelConfig:
    """Scale an assigned arch down to a trainable-on-CPU size (~100M)."""
    repl = {"n_layers": layers, "d_model": d_model, "max_seq": max(seq * 2, 256)}
    if cfg.family in ("ssm", "hybrid"):
        repl["ssm_chunk"] = min(cfg.ssm_chunk, 64)
    if cfg.n_heads > 1:
        repl["n_heads"] = max(4, min(cfg.n_heads, d_model // 64))
        repl["n_kv_heads"] = max(1, min(cfg.n_kv_heads, repl["n_heads"]))
        while repl["n_heads"] % repl["n_kv_heads"]:
            repl["n_kv_heads"] -= 1
        repl["head_dim"] = d_model // repl["n_heads"]
    if cfg.d_ff:
        repl["d_ff"] = d_model * 4
    if cfg.is_moe:
        repl["n_experts"] = min(cfg.n_experts, 8)
        repl["top_k"] = min(cfg.top_k, 2)
        repl["moe_d_ff"] = d_model * 2
    if cfg.family == "encdec":
        repl["n_encoder_layers"] = layers
    return dataclasses.replace(cfg, **repl)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="0 = use all devices on the data axis")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config unchanged")
    ap.add_argument("--engine", choices=["fused", "hypar", "proc"],
                    default="fused",
                    help="fused = tailored SPMD step; hypar = the paper's "
                         "job-graph runtime (BaseExecutor, DESIGN.md §2); "
                         "proc = the same job graph on real multiprocessing "
                         "workers with a durable job store (DESIGN.md §12)")
    ap.add_argument("--dispatch", choices=["sync", "pipelined", "dataflow"],
                    default="sync", help="LocalExecutor dispatch mode "
                                         "(hypar/proc engines)")
    ap.add_argument("--placement", choices=["greedy", "cost"], default="greedy",
                    help="master-scheduler placement strategy (hypar engine)")
    ap.add_argument("--store", default="",
                    help="proc engine: sqlite job-store path — results "
                         "persist under content identity, so a killed run "
                         "restarted with --resume skips every job already "
                         "done (default: a fresh temporary store)")
    ap.add_argument("--resume", action="store_true",
                    help="proc engine: reuse an existing --store instead of "
                         "starting it fresh (memoised jobs are served from "
                         "the store, not re-executed)")
    ap.add_argument("--proc-workers", type=int, default=2,
                    help="proc engine: number of worker processes")
    ap.add_argument("--store-gc", type=float, default=None, metavar="SECS",
                    help="after the run, prune done job-store rows older "
                         "than this many seconds (and their spill files)")
    ap.add_argument("--store-gc-rows", type=int, default=None, metavar="N",
                    help="after the run, keep at most N most-recent done "
                         "job-store rows")
    args = ap.parse_args(argv)
    if (args.store or args.resume) and args.engine != "proc":
        ap.error("--store/--resume require --engine proc")
    if args.resume and not args.store:
        ap.error("--resume needs --store (a temporary store has no "
                 "previous run to resume from)")
    if (args.store_gc is not None or args.store_gc_rows is not None) \
            and not args.store:
        ap.error("--store-gc/--store-gc-rows need --store (a temporary "
                 "store is deleted whole when the run ends)")

    base = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = base if args.smoke else scale_config(
        base, layers=args.layers, d_model=args.d_model, seq=args.seq)
    n_dev = len(jax.devices())
    data_ax = args.data_axis or max(1, n_dev // args.model_axis)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((data_ax, args.model_axis), ("data", "model"))
    rules = ShardingRules(mesh=mesh, rules=dict(DEFAULT_RULES))

    spec = OptimizerSpec(kind=cfg.optimizer, lr=args.lr)
    sched = lambda s: cosine_schedule(s, base_lr=args.lr, warmup=50,
                                      total=args.steps)
    dc = DataConfig(seed=args.seed, global_batch=args.batch, seq_len=args.seq)
    stream = SyntheticLMStream(cfg, dc)

    if args.engine in ("hypar", "proc"):
        return _run_hypar(cfg, spec, stream, args)

    with use_rules(mesh, rules.rules):
        step_fn = make_train_step(cfg, spec, grad_accum=args.grad_accum,
                                  schedule=sched)
        state_struct = jax.eval_shape(
            lambda k: TrainState.create(cfg, spec, k), jax.random.PRNGKey(args.seed))
        state_sh = tree_shardings(state_struct, rules, kind="state")
        jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None), donate_argnums=(0,))

        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(
                args.ckpt_dir, state_struct,
                sharding_fn=lambda key, leaf: _lookup(state_sh, key))
            print(f"resumed from checkpoint step {start}")
        else:
            state = jax.jit(
                lambda k: TrainState.create(cfg, spec, k),
                out_shardings=state_sh)(jax.random.PRNGKey(args.seed))

        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        n_params = sum(x.size for x in jax.tree.leaves(state.params))
        print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={n_dev} "
              f"mesh=({data_ax},{args.model_axis}) steps={args.steps}")

        it = Prefetcher((stream.batch(s) for s in range(start, args.steps)),
                        depth=2)
        t0 = time.time()
        tokens_done = 0
        for s, host_batch in zip(range(start, args.steps), it):
            batch = jax.tree.map(jnp.asarray, host_batch)
            state, metrics = jitted(state, batch)
            tokens_done += args.batch * args.seq
            if (s + 1) % args.log_every == 0 or s + 1 == args.steps:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {s + 1:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"tok/s {tokens_done / dt:9.0f}")
            if ckpt and (s + 1) % args.ckpt_every == 0:
                ckpt.save(state, s + 1)
        if ckpt:
            ckpt.save(state, args.steps)
            ckpt.wait()
        final_loss = float(metrics["loss"])
        print(f"done: final loss {final_loss:.4f} "
              f"({tokens_done / (time.time() - t0):.0f} tok/s)")
        return final_loss


def _run_hypar(cfg, spec, stream, args) -> float:
    """Drive training through the paper's job-graph runtime.

    Same BaseExecutor contract as every other consumer: the dispatch mode
    and placement strategy are plain LocalExecutor knobs, nothing here
    special-cases them.  ``--engine proc`` swaps the thread workers for the
    durable ProcessExecutor (DESIGN.md §12): the trainer's functions run in
    spawn children via ``repro.train.procfns`` and every job result lands in
    the sqlite store, so a killed run restarted with ``--resume`` replays
    the done prefix as memo hits.
    """
    from repro.train import HyParTrainer

    n_micro = max(1, args.grad_accum)
    mb = max(1, args.batch // n_micro)
    batches = []
    for s in range(args.steps):
        b = stream.batch(s)
        batches.append([{k: jnp.asarray(v[m * mb:(m + 1) * mb])
                         for k, v in b.items()} for m in range(n_micro)])

    factory, made = None, []
    if args.engine == "proc":
        from repro.core import ProcessExecutor, VirtualCluster
        from repro.train import procfns

        store = args.store or None
        if store and not args.resume:
            for stale in (store, store + "-wal", store + "-shm"):
                if os.path.exists(stale):
                    os.remove(stale)
        procfns.export_env(cfg, spec, batch_keys=batches[0][0])
        proc_cluster = VirtualCluster(n_schedulers=1,
                                      max_workers=args.proc_workers)

        def factory(cluster, registry):
            ex = ProcessExecutor(
                cluster, registry, procfns.WORKER_FNS_SPEC, store=store,
                mode=("pipelined" if args.dispatch == "sync"
                      else args.dispatch),
                strategy=args.placement)
            made.append(ex)
            return ex

    trainer = HyParTrainer(cfg, spec, n_micro=n_micro,
                           cluster=(proc_cluster if factory else None),
                           mode=args.dispatch, strategy=args.placement,
                           executor_factory=factory)
    t0 = time.time()
    params, _, report = trainer.run(batches, key=jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.engine} engine: {args.steps} steps x {n_micro} micro in "
          f"{dt:.1f}s ({args.steps * args.batch * args.seq / dt:.0f} tok/s) "
          f"params={n_params / 1e6:.1f}M | {report.summary()}")
    if made:
        ex = made[0]
        print(f"job store: {ex.n_executed} executed, "
              f"{ex.n_memoised} memoised"
              + (f" (durable at {args.store})" if args.store else ""))
    if args.store and (args.store_gc is not None
                       or args.store_gc_rows is not None):
        from repro.core.store import JobStore
        gc_store = JobStore(args.store)
        try:
            pruned = gc_store.gc(max_age_s=args.store_gc,
                                 max_rows=args.store_gc_rows)
            print(f"store gc: pruned {pruned['rows']} done row(s), "
                  f"{pruned['spill_files']} spill file(s)")
        finally:
            gc_store.close()
    return dt


def _lookup(sh_tree, key: str):
    flat, _ = jax.tree_util.tree_flatten_with_path(sh_tree)
    for path, leaf in flat:
        k = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if k == key:
            return leaf
    raise KeyError(key)


if __name__ == "__main__":
    main()
