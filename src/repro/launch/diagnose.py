import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Perf diagnosis for one (arch × cell × mesh): where do the FLOPs,
HBM traffic and collective bytes actually come from?

Prints the top-N collective ops (kind, per-call bytes, trip multiplier,
defining computation) and the top computations by flops/traffic — the
profile the §Perf hillclimb iterates on (no real-TPU timings exist here;
the lowered IR is the profile, per the assignment).

Usage::

    python -m repro.launch.diagnose --arch qwen2-1.5b --cell train_4k [--multi-pod]
"""
import argparse
import re

import jax

from repro.analysis.hlo import COLLECTIVES, _parse_computations, _finalize_ops, analyze_hlo
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, rules_for_cell
from repro.models.config import SHAPE_CELLS
from repro.parallel.sharding import use_rules


def compile_cell(arch, cell_name, multi_pod=False):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = SHAPE_CELLS[cell_name]
    rules = rules_for_cell(mesh, cell, cfg)
    with use_rules(mesh, rules.rules):
        spec = build_cell(arch, cfg, cell_name, rules)
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        compiled = jitted.lower(*spec.args).compile()
    return compiled, spec, mesh


def diagnose(text: str, top: int = 20):
    comps, entry = _parse_computations(text)
    for c in comps.values():
        _finalize_ops(c)
    an = analyze_hlo(text)
    mult = {name: d["mult"] for name, d in an.by_computation.items()}

    rows = []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if not m:
            continue
        for op in comp.ops:
            if op.opcode in COLLECTIVES:
                # recover a source hint from metadata
                hint = ""
                mm = re.search(r'op_name="([^"]+)"', op.attrs)
                if mm:
                    hint = mm.group(1)[-90:]
                rows.append((op.in_bytes * m, op.opcode, op.in_bytes, int(m),
                             name[:28], hint))
    rows.sort(reverse=True)
    print(f"top {top} collective sites (total-bytes-weighted):")
    for tot, kind, b, m, comp, hint in rows[:top]:
        print(f"  {kind:19s} {b/2**20:9.2f}MiB x{m:5d} = {tot/2**30:8.2f}GiB "
              f"[{comp}] {hint}")

    print("\ntop computations by flops:")
    by_flops = sorted(an.by_computation.items(),
                      key=lambda kv: -kv[1]["flops"] * kv[1]["mult"])
    for name, d in by_flops[:10]:
        print(f"  {name[:40]:42s} mult={d['mult']:7.0f} "
              f"flops={d['flops']*d['mult']:.3e} traffic={d['traffic']*d.get('hbm_mult',0):.3e}")
    print("\nsummary:", an.summary())
    return an


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--cell", choices=list(SHAPE_CELLS), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--dump-hlo", default="")
    args = ap.parse_args(argv)
    compiled, spec, mesh = compile_cell(args.arch, args.cell, args.multi_pod)
    text = compiled.as_text()
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(text)
        print(f"wrote {len(text)/1e6:.1f}MB HLO to {args.dump_hlo}")
    diagnose(text, top=args.top)


if __name__ == "__main__":
    main()
