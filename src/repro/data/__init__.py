from .pipeline import DataConfig, SyntheticLMStream, make_batch_specs, Prefetcher
