"""Deterministic synthetic data pipeline with per-host sharding + prefetch.

At pod scale each host feeds only its shard of the global batch; the stream
is a pure function of (seed, step, host), so restarts resume bit-identically
(checkpoint stores only the step counter) and elastic re-sharding is a
re-partition of the same stream — no data server required.

Token streams are Zipf-distributed (more realistic softmax statistics than
uniform) with deterministic doc boundaries; stub-frontend families (audio,
VLM) get synthetic frame/patch embeddings from the same counter-based PRNG.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["DataConfig", "SyntheticLMStream", "make_batch_specs", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    pad_frac: float = 0.02          # tail padding to exercise loss masks


class SyntheticLMStream:
    """Stateless-per-step synthetic LM batches."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        if dc.global_batch % dc.n_hosts:
            raise ValueError("global_batch must divide over hosts")
        self.cfg, self.dc = cfg, dc
        self.local_batch = dc.global_batch // dc.n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.dc.seed, step, self.dc.host_id]))

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg, dc = self.cfg, self.dc
        rng = self._rng(step)
        B, S = self.local_batch, dc.seq_len
        V = cfg.vocab_size
        # Zipf tokens clipped to vocab
        toks = rng.zipf(dc.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = np.minimum(toks - 1, V - 1).astype(np.int32)
        mask = np.ones((B, S), np.float32)
        n_pad = int(S * dc.pad_frac)
        if n_pad:
            mask[:, S - n_pad:] = 0.0
        out = {"tokens": toks[:, :S], "labels": toks[:, 1:S + 1], "mask": mask}
        if cfg.family == "encdec":
            T = min(S, 1500)
            out["enc_embeds"] = rng.standard_normal(
                (B, T, cfg.d_model), dtype=np.float32)
            dec = min(cfg.decoder_len, S)
            out["tokens"] = toks[:, :dec]
            out["labels"] = toks[:, 1:dec + 1]
            out["mask"] = mask[:, :dec]
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                     *, kind: str = "train") -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins matching SyntheticLMStream batches."""
    f32, i32 = jnp.float32, jnp.int32
    if cfg.family == "encdec":
        dec = min(cfg.decoder_len, seq)
        specs = {
            "enc_embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f32),
            "tokens": jax.ShapeDtypeStruct((batch, dec), i32),
        }
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((batch, dec), i32)
            specs["mask"] = jax.ShapeDtypeStruct((batch, dec), f32)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        specs["mask"] = jax.ShapeDtypeStruct((batch, seq), f32)
    return specs


class Prefetcher:
    """Background-thread prefetch (depth-bounded) — keeps the host data path
    off the device critical path."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
