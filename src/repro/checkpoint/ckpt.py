"""Sharded, async, elastic checkpointing.

Layout: ``<dir>/step_<N>/`` containing one ``.npz`` per shard group plus a
``manifest.json`` (tree paths, shapes, dtypes, shard assignment, checksums).
Writes are atomic (tmp dir + rename) and a ``LATEST`` pointer is updated
last, so a crash mid-write never corrupts the restore path — the previous
complete step stays live (the fault-tolerance contract of DESIGN.md §6).

Elastic restore: arrays are loaded host-side and re-placed with *new*
shardings (possibly a different mesh shape/device count), so a job can
restart on fewer/more pods than it checkpointed from.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def _path_tuple(key: str):
    return tuple(int(p) if p.isdigit() else p for p in key.split("/"))


def save_checkpoint(directory: str, tree, step: int, *,
                    shard_groups: int = 1) -> str:
    """Write ``tree`` under ``directory/step_<step>``.  Returns the path."""
    flat, _ = _flatten_with_paths(tree)
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    keys = sorted(flat)
    groups: list[list[str]] = [[] for _ in range(max(1, shard_groups))]
    for i, k in enumerate(keys):
        groups[i % len(groups)].append(k)

    manifest = {"step": step, "files": {}, "leaves": {}}
    for gi, group in enumerate(groups):
        if not group:
            continue
        fname = f"shard_{gi:05d}.npz"
        arrs = {}
        for k in group:
            a = np.asarray(jax.device_get(flat[k]))
            stored_raw = False
            try:
                np.lib.format.dtype_to_descr(a.dtype)
                if a.dtype.hasobject or str(a.dtype) not in np.sctypeDict \
                        and a.dtype.kind == "V":
                    raise ValueError
            except Exception:
                stored_raw = True
            if str(a.dtype) in ("bfloat16",) or "float8" in str(a.dtype):
                stored_raw = True
            if stored_raw:
                arrs[k] = np.frombuffer(a.tobytes(), np.uint8)
            else:
                arrs[k] = a
            manifest["leaves"][k] = {
                "shape": list(a.shape), "dtype": str(a.dtype), "file": fname,
                "raw": stored_raw,
            }
        path = os.path.join(tmp, fname)
        np.savez(path, **arrs)
        with open(path, "rb") as f:
            manifest["files"][fname] = hashlib.sha256(f.read()).hexdigest()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def _scan_steps(directory: str) -> list[int]:
    try:
        names = os.listdir(directory)
    except OSError:
        return []
    steps = []
    for d in names:
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    """Newest complete step.  Falls back to scanning ``step_*`` dirs when
    ``LATEST`` is missing, empty, or corrupt — a crash between the step-dir
    rename and the pointer update must not make the restore path raise
    (the mid-write story of DESIGN.md §6)."""
    p = os.path.join(directory, "LATEST")
    if os.path.exists(p):
        try:
            with open(p) as f:
                return int(f.read().strip())
        except (ValueError, OSError):
            pass
    steps = _scan_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, like, *, step: int | None = None,
                       sharding_fn: Callable[[str, Any], Any] | None = None,
                       verify: bool = True):
    """Restore into the structure of ``like`` (tree of arrays or
    ShapeDtypeStructs).  ``sharding_fn(key, leaf) -> Sharding`` enables
    elastic re-placement onto a new mesh."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    root = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    if verify:
        for fname, digest in manifest["files"].items():
            with open(os.path.join(root, fname), "rb") as f:
                actual = hashlib.sha256(f.read()).hexdigest()
            if actual != digest:
                raise IOError(f"checksum mismatch in {fname}")
    data: dict[str, np.ndarray] = {}
    by_file: dict[str, list[str]] = {}
    for k, meta in manifest["leaves"].items():
        by_file.setdefault(meta["file"], []).append(k)
    for fname, ks in by_file.items():
        with np.load(os.path.join(root, fname)) as z:
            for k in ks:
                meta = manifest["leaves"][k]
                a = z[k]
                if meta.get("raw"):
                    import ml_dtypes  # bf16 / f8 round-trip via raw bytes
                    dt = np.dtype(getattr(ml_dtypes, meta["dtype"], None)
                                  or meta["dtype"])
                    a = np.frombuffer(a.tobytes(), dt).reshape(meta["shape"])
                data[k] = a

    flat_like, treedef = _flatten_with_paths(like)
    missing = set(flat_like) - set(data)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    # rebuild in treedef order
    flat_with_path, _ = jax.tree_util.tree_flatten_with_path(like)
    rebuilt = []
    for path, leaf in flat_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if sharding_fn is not None:
            arr = jax.device_put(arr, sharding_fn(key, leaf))
        rebuilt.append(arr)
    return jax.tree_util.tree_unflatten(treedef, rebuilt), step


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread (bounded to
    one in flight; the caller's arrays are snapshotted to host first so
    training can overwrite device buffers immediately)."""

    def __init__(self, directory: str, *, shard_groups: int = 1,
                 keep: int = 3):
        self.directory = directory
        self.shard_groups = shard_groups
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, tree, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, host_tree, step,
                                shard_groups=self.shard_groups)
                self._gc()
            except Exception as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = _scan_steps(self.directory)
        # NOT steps[:-self.keep]: keep=0 would slice to steps[:0] and
        # silently keep everything instead of deleting everything.  The
        # max(0, ...) stops the slice going negative (and wrongly deleting)
        # while fewer than ``keep`` checkpoints exist.
        for s in steps[:max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
