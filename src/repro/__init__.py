"""HyPar-on-JAX reproduction package.

One global knob: sharding-invariant RNG.  The whole system assumes that
``jax.random`` produces the same values whether a computation runs eagerly
on one device or jitted over a mesh (init parity across executors, elastic
checkpoint restore onto different meshes).  Newer jax defaults
``jax_threefry_partitionable`` to True; older versions (< 0.5) default to
False, under which sharded RNG silently diverges from eager RNG — so pin it
here, before any key is ever split.
"""
import jax as _jax

_jax.config.update("jax_threefry_partitionable", True)
