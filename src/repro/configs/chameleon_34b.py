"""chameleon-34b [vlm]: early-fusion, VQ image tokens in the text vocab;
the VQ tokenizer frontend is a STUB — inputs are token ids.  qk-norm for
stability as in the paper.  [arXiv:2405.09818; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22_016,
    vocab_size=65_536, qk_norm=True, tie_embeddings=False,
    max_seq=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG, name="chameleon-34b-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, max_seq=256)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
