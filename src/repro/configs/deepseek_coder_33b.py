"""deepseek-coder-33b [dense]: llama-arch GQA kv=8.  [arXiv:2401.14196; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19_200,
    vocab_size=32_256, rope_theta=100_000.0, tie_embeddings=False,
    max_seq=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-coder-33b-smoke", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512, max_seq=256)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
