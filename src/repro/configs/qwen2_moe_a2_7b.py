"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151_936, n_experts=60, top_k=4, moe_d_ff=1408,
    n_shared_experts=4, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=False, max_seq=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=96, vocab_size=512, n_experts=8, top_k=2,
    moe_d_ff=96, n_shared_experts=2, max_seq=256)

CELLS = ("train_4k", "prefill_32k", "decode_32k")
