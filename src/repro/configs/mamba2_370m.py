"""mamba2-370m [ssm]: attention-free, SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50_280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True, use_rope=False, max_seq=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-370m-smoke", n_layers=3, d_model=64,
    vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=16, max_seq=256)

CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")  # SSM: runs long
