"""whisper-base [audio]: enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_encoder_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    use_rope=False, norm="layernorm", act="gelu",
    tie_embeddings=True, decoder_len=448, max_seq=32_768 + 8,
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-base-smoke", n_layers=2, n_encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    max_seq=256, decoder_len=32)

# long_500k skipped: decoder context architecturally capped (DESIGN.md §4)
CELLS = ("train_4k", "prefill_32k", "decode_32k")
