"""llama3-405b [dense]: GQA kv=8, 128k vocab.  [arXiv:2407.21783; unverified]

Memory note (DESIGN.md §4): at 405B params AdamW fp32 states (12 B/param)
exceed 256×16 GB; production config uses Adafactor (factored second moment)
with fp32 params — the T5X-style recipe — plus full per-layer remat.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16_384, n_heads=128, n_kv_heads=8, d_ff=53_248,
    vocab_size=128_256, rope_theta=500_000.0, tie_embeddings=False,
    optimizer="adafactor", remat="full", max_seq=131_072,
    # bf16 params + Adafactor: params 3.2 GiB/chip, bf16 micro-grads with an
    # fp32 accumulator -- the combination that fits 405B training on a
    # 256-chip v5e pod (16 GiB HBM); see EXPERIMENTS.md §Dry-run.
    param_dtype="bfloat16",
    # f8 KV cache: 405B decode at 32k x 128 slots on one 16 GiB/chip pod
    # needs 4.2 GiB/chip of cache instead of 8.4 (direct-cast e4m3; per-head
    # scaling is a noted TODO)
    kv_cache_dtype="float8_e4m3fn",
    activation_seq_shard=False,   # H2 (EXPERIMENTS.md §Perf): -seq<->heads reshard storm
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama3-405b-smoke", n_layers=3, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=256, vocab_size=512, optimizer="adamw", max_seq=256,
    kv_cache_dtype="")  # smoke tests check exact decode parity; f8 is a serving choice

CELLS = ("train_4k", "prefill_32k", "decode_32k")
