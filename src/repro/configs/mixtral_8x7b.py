"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14_336,
    vocab_size=32_000, n_experts=8, top_k=2, moe_d_ff=14_336,
    sliding_window=4096, rope_theta=1_000_000.0, tie_embeddings=False,
    max_seq=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, n_experts=4, top_k=2,
    moe_d_ff=96, sliding_window=16, max_seq=256)

# SWA => sub-quadratic: long_500k runs
CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
