"""Architecture registry: the 10 assigned configs (+ the paper's Jacobi
experiment config) selectable via ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPE_CELLS, ModelConfig, ShapeCell

_MODULES = {
    "whisper-base": "whisper_base",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma3-4b": "gemma3_4b",
    "llama3-405b": "llama3_405b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-370m": "mamba2_370m",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def cells_for(arch: str) -> tuple[str, ...]:
    """Shape cells assigned to this arch (long_500k per DESIGN.md §4)."""
    return _mod(arch).CELLS


def all_cells() -> list[tuple[str, str]]:
    return [(a, c) for a in ARCHS for c in cells_for(a)]
