"""gemma3-4b [dense]: 5:1 local:global attention, 128k context, 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10_240,
    vocab_size=262_144, head_dim=256,
    local_global_ratio=5, local_window=1024,
    embed_scale=True, qk_norm=True, rope_theta=1_000_000.0,
    tie_embeddings=True, act="gelu", max_seq=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-4b-smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    local_global_ratio=2, local_window=16, max_seq=256)

# sub-quadratic (5/6 of layers local-1024): long_500k runs
CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
