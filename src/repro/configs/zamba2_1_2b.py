"""zamba2-1.2b [hybrid]: Mamba2 backbone + one SHARED attention block
invoked every 6 SSM blocks.  [arXiv:2411.15242; hf]

Simplification noted in DESIGN.md: the shared block is one parameter set
re-invoked (Zamba2's per-invocation LoRA deltas are omitted).
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32_000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=6, tie_embeddings=True, max_seq=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-1.2b-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16, hybrid_attn_every=2, max_seq=256)

CELLS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")  # hybrid: runs long
