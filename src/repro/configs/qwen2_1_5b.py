"""qwen2-1.5b [dense]: GQA kv=2, QKV bias.  [arXiv:2407.10671; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151_936, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True, max_seq=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-1.5b-smoke", n_layers=3, d_model=96, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab_size=512, max_seq=256)

CELLS = ("train_4k", "prefill_32k", "decode_32k")  # pure full attention: no long_500k
