"""Spawn-side training function table for the process-worker runtime.

``ProcessExecutor`` children resolve their functions from a
``"module:attr"`` spec and never see the master's registry —
:class:`~repro.train.hypar_loop.HyParTrainer`'s registered functions are
closures over the trainer instance and cannot cross a spawn boundary.  This
module provides the same fids (``grad``/``opt``/``take_params``/``take_opt``/
``data``) as module-level functions: the master serialises the model config,
optimizer spec and microbatch keys into ``REPRO_TRAIN_PROCFNS`` (spawn
children inherit the environment) via :func:`export_env` **before** the
executor starts its workers, and each child rebuilds the pytree treedefs
locally from the same deterministic init path.

Unlike the rest of the child-side runtime this module's functions DO import
jax in the worker process — training gradients are jax computations.  The
import happens lazily inside the functions, so merely resolving the table
stays cheap.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

__all__ = ["FNS", "WORKER_FNS_SPEC", "ENV_KEY", "export_env"]

WORKER_FNS_SPEC = "repro.train.procfns:FNS"
ENV_KEY = "REPRO_TRAIN_PROCFNS"

_CTX = None


def export_env(cfg, spec, batch_keys) -> None:
    """Master side: stage the training setup for spawn children."""
    os.environ[ENV_KEY] = json.dumps({
        "cfg": dataclasses.asdict(cfg),
        "spec": dataclasses.asdict(spec),
        "batch_keys": sorted(batch_keys),
    })


class _Ctx:
    def __init__(self):
        import jax
        from repro.models.config import ModelConfig
        from repro.models.transformer import init_params
        from repro.optim import OptimizerSpec, init_opt_state

        raw = os.environ.get(ENV_KEY)
        if not raw:
            raise RuntimeError(
                f"{ENV_KEY} is not set — the master must call "
                f"repro.train.procfns.export_env(cfg, spec, batch_keys) "
                f"before spawning process workers")
        d = json.loads(raw)
        self.cfg = ModelConfig(**d["cfg"])
        self.spec = OptimizerSpec(**d["spec"])
        # treedefs come from the same init path the master used — the child
        # only ever receives flat leaf lists, never pytrees
        params = init_params(self.cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(self.spec, params)
        self.params_def = jax.tree_util.tree_structure(params)
        self.opt_def = jax.tree_util.tree_structure(opt)
        self.n_p = self.params_def.num_leaves
        self.batch_def = jax.tree_util.tree_structure(
            {k: 0 for k in d["batch_keys"]})


def _ctx() -> _Ctx:
    global _CTX
    if _CTX is None:
        _CTX = _Ctx()
    return _CTX


def _leaves(tree) -> list[np.ndarray]:
    import jax
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def grad(params_chunks, micro_chunks):
    import jax
    import jax.numpy as jnp
    from repro.models.transformer import loss_fn

    c = _ctx()
    params = jax.tree_util.tree_unflatten(
        c.params_def, [jnp.asarray(a) for a in params_chunks])
    batch = jax.tree_util.tree_unflatten(
        c.batch_def, [jnp.asarray(a) for a in micro_chunks])
    (_, _), grads = jax.value_and_grad(
        lambda p: loss_fn(c.cfg, p, batch), has_aux=True)(params)
    return _leaves(grads)


def opt(params_chunks, opt_chunks, *grad_chunk_lists):
    import jax
    import jax.numpy as jnp
    from repro.optim import opt_update

    c = _ctx()
    params = jax.tree_util.tree_unflatten(
        c.params_def, [jnp.asarray(a) for a in params_chunks])
    opt_state = jax.tree_util.tree_unflatten(
        c.opt_def, [jnp.asarray(a) for a in opt_chunks])
    grads_sum = None
    for gc in grad_chunk_lists:
        g = jax.tree_util.tree_unflatten(
            c.params_def, [jnp.asarray(a) for a in gc])
        grads_sum = g if grads_sum is None else jax.tree.map(
            jnp.add, grads_sum, g)
    grads = jax.tree.map(lambda g: g / len(grad_chunk_lists), grads_sum)
    new_p, new_o, _ = opt_update(c.spec, grads, opt_state, params)
    return _leaves(new_p) + _leaves(new_o)


def take_params(full_chunks):
    return [np.asarray(a) for a in full_chunks[:_ctx().n_p]]


def take_opt(full_chunks):
    return [np.asarray(a) for a in full_chunks[_ctx().n_p:]]


def data(chunks):
    return [np.asarray(a) for a in chunks]


FNS = {"grad": grad, "opt": opt, "take_params": take_params,
       "take_opt": take_opt, "data": data}
