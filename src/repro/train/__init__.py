from .step import TrainState, make_train_step, make_init_fn
from .hypar_loop import HyParTrainer
