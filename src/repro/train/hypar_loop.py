"""Training expressed as a HyPar job graph — the paper's technique applied
to the LM workload (first-class integration, DESIGN.md §4).

Per optimisation step:

  segment GRAD_s : one job per microbatch, ``no_send_back=True`` — gradients
                   are *retained on the workers* (the paper's
                   communication-avoidance) and only fetched by the OPT job;
  segment OPT_s  : reduce + optimizer update, consuming ``R_grad[*]`` and
                   the previous parameters ``R_opt_{s-1}``;
  (optional) a control job re-enqueues the next step's segments — the exact
  dynamic-job pattern the paper introduces for its Jacobi solver.

Pytrees travel through the graph as ChunkedData of flattened leaves; the
treedefs are closed over by the registered functions (workers are "fat":
they contain all user functions, paper §3.2).

The fused SPMD step (repro/train/step.py) is the "tailored" implementation
this is benchmarked against — reproducing the shape of the paper's Fig. 3
experiment on the LM workload (see benchmarks/hypar_overhead.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ChunkedData, ChunkRef, FunctionRegistry, Job, JobGraph,
                        LocalExecutor, VirtualCluster)
from repro.models.config import ModelConfig
from repro.models.transformer import init_params, loss_fn
from repro.optim import OptimizerSpec, init_opt_state, opt_update

__all__ = ["HyParTrainer"]


class HyParTrainer:
    """Paper-faithful scheduled training on the LocalExecutor."""

    def __init__(self, cfg: ModelConfig, spec: OptimizerSpec, *,
                 n_micro: int = 2, cluster: VirtualCluster | None = None,
                 dynamic: bool = True, mode: str = "sync",
                 strategy: str = "greedy",
                 executor_factory: Callable[..., Any] | None = None):
        self.cfg, self.spec, self.n_micro = cfg, spec, n_micro
        self.dynamic = dynamic
        self.mode, self.strategy = mode, strategy
        # executor injection: ``factory(cluster, registry) -> BaseExecutor``
        # swaps the thread-worker LocalExecutor for e.g. the durable
        # ProcessExecutor without the trainer special-casing either
        self.executor_factory = executor_factory
        self.cluster = cluster or VirtualCluster(n_schedulers=1)
        self.registry = FunctionRegistry()
        self._params_def = None
        self._opt_def = None
        self._batches: dict[int, list[dict]] = {}
        self._register()

    # -- registered user functions (paper §3.2) -----------------------------
    def _register(self):
        cfg, spec = self.cfg, self.spec

        def grad_fn(params_cd: ChunkedData, micro_cd: ChunkedData) -> ChunkedData:
            params = jax.tree_util.tree_unflatten(
                self._params_def, params_cd.arrays())
            batch = jax.tree_util.tree_unflatten(
                self._batch_def, micro_cd.arrays())
            (_, _), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            return ChunkedData.from_arrays(jax.tree.leaves(grads))

        def opt_fn(*cds: ChunkedData) -> ChunkedData:
            params_cd, opt_cd, *grad_cds = cds
            params = jax.tree_util.tree_unflatten(
                self._params_def, params_cd.arrays())
            opt_state = jax.tree_util.tree_unflatten(
                self._opt_def, opt_cd.arrays())
            grads_sum = None
            for gcd in grad_cds:
                g = jax.tree_util.tree_unflatten(self._params_def, gcd.arrays())
                grads_sum = g if grads_sum is None else jax.tree.map(
                    jnp.add, grads_sum, g)
            grads = jax.tree.map(lambda g: g / len(grad_cds), grads_sum)
            new_p, new_o, _ = opt_update(spec, grads, opt_state, params)
            return ChunkedData.from_arrays(
                jax.tree.leaves(new_p) + jax.tree.leaves(new_o))

        def split_state(cd: ChunkedData, which: str) -> ChunkedData:
            n_p = self._params_def.num_leaves
            return ChunkedData(list(cd)[:n_p] if which == "p" else list(cd)[n_p:])

        self.registry.register("grad", grad_fn, kind="whole")
        self.registry.register("opt", opt_fn, kind="whole")
        self.registry.register("take_params",
                               lambda cd: split_state(cd, "p"), kind="whole")
        self.registry.register("take_opt",
                               lambda cd: split_state(cd, "o"), kind="whole")

    # -- graph construction ----------------------------------------------------
    def _one_step_segments(self, graph: JobGraph, s: int, *,
                           params_ref: str, opt_ref: str) -> tuple[str, str]:
        grad_jobs = []
        for m in range(self.n_micro):
            name = f"G{s}_{m}"
            job = Job(name, "grad", 0,
                      (ChunkRef(params_ref), ChunkRef(f"D{s}_{m}")),
                      no_send_back=True)   # paper: grads stay on workers
            grad_jobs.append(job)
        graph.add_segment(grad_jobs)
        opt_name = f"O{s}"
        graph.add_segment([Job(opt_name, "opt", 0,
                               (ChunkRef(params_ref), ChunkRef(opt_ref)) +
                               tuple(ChunkRef(j.name) for j in grad_jobs))])
        p_name, o_name = f"P{s + 1}", f"S{s + 1}"
        graph.add_segment([
            Job(p_name, "take_params", 1, (ChunkRef(opt_name),)),
            Job(o_name, "take_opt", 1, (ChunkRef(opt_name),)),
        ])
        return p_name, o_name

    def run(self, batches: list[list[dict]], key=None) -> tuple[Any, Any, Any]:
        """batches[s][m] = microbatch dict for step s. Returns
        (params, opt_state, report)."""
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        opt_state = init_opt_state(self.spec, params)
        p_leaves, self._params_def = jax.tree_util.tree_flatten(params)
        o_leaves, self._opt_def = jax.tree_util.tree_flatten(opt_state)
        _, self._batch_def = jax.tree_util.tree_flatten(batches[0][0])

        graph = JobGraph()
        graph.add_segment([Job("P0", "take_params", 1, ()),
                           Job("S0", "take_opt", 1, ())])
        full0 = ChunkedData.from_arrays(p_leaves + o_leaves)
        graph.bind_input("P0", full0)
        graph.bind_input("S0", full0)

        p_ref, o_ref = "P0", "S0"
        for s, step_batches in enumerate(batches):
            for m, mb in enumerate(step_batches):
                name = f"D{s}_{m}"
                # data jobs: identity chunkwise over microbatch leaves
                if "data" not in self.registry:
                    self.registry.register("data", lambda *xs: xs[0]
                                           if len(xs) == 1 else xs,
                                           kind="whole")
                graph.add_segment([Job(name, "data", 1, ())])
                graph.bind_input(name, ChunkedData.from_arrays(
                    jax.tree.leaves(mb)))
            p_ref, o_ref = self._one_step_segments(graph, s, params_ref=p_ref,
                                                   opt_ref=o_ref)

        if self.executor_factory is not None:
            executor = self.executor_factory(self.cluster, self.registry)
        else:
            executor = LocalExecutor(self.cluster, self.registry,
                                     mode=self.mode, strategy=self.strategy)
        try:
            results, report = executor.run(graph)
        finally:
            close = getattr(executor, "close", None)
            if close is not None:
                close()
        final_p = jax.tree_util.tree_unflatten(self._params_def,
                                               results[p_ref].arrays())
        final_o = jax.tree_util.tree_unflatten(self._opt_def,
                                               results[o_ref].arrays())
        return final_p, final_o, report
