"""Train-step construction: grad accumulation, remat, optimizer, schedule.

``make_train_step`` builds the fused SPMD step (one jit'd program) — this is
what the paper's framework would assemble from the job graph
(DATA → GRAD×microbatches (no_send_back) → OPT); the HyPar-scheduled
variant that literally goes through the JobGraph/SpmdExecutor lives in
``repro/train/hypar_loop.py`` and is benchmarked against this fused step in
``benchmarks/`` (framework-vs-tailored, the paper's Fig. 3 experiment shape).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import init_params, loss_fn
from repro.optim import OptimizerSpec, cosine_schedule, init_opt_state, opt_update
from repro.parallel.sharding import current_rules, logical


def _constrain_like_params(tree):
    """Pin a params-shaped tree (e.g. per-microbatch gradients) to the
    parameter shardings.  Without this GSPMD all-reduces FULL fp32 weight
    gradients per microbatch per layer instead of reduce-scattering to the
    FSDP shard — a 16x collective-bytes difference on the 16x16 mesh
    (EXPERIMENTS.md §Perf, llama3 train H-grad)."""
    if current_rules() is None:
        return tree
    from repro.parallel.partition import tree_logical_axes
    axes = tree_logical_axes(tree, kind="params")
    return jax.tree.map(
        lambda x, a: logical(x, *a) if hasattr(x, "ndim") else x,
        tree, axes, is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))

__all__ = ["TrainState", "make_train_step", "make_init_fn"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @staticmethod
    def create(cfg: ModelConfig, spec: OptimizerSpec, key) -> "TrainState":
        params = init_params(cfg, key)
        return TrainState(params=params,
                          opt_state=init_opt_state(spec, params),
                          step=jnp.zeros((), jnp.int32))


def make_init_fn(cfg: ModelConfig, spec: OptimizerSpec):
    def init_fn(key):
        return TrainState.create(cfg, spec, key)
    return init_fn


def _split_microbatches(batch: dict, n: int) -> dict:
    def re(x):
        B = x.shape[0]
        assert B % n == 0, f"batch {B} not divisible by {n} microbatches"
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(re, batch)


def make_train_step(cfg: ModelConfig, spec: OptimizerSpec, *,
                    grad_accum: int | None = None,
                    schedule: Callable | None = None,
                    impl: str = "auto"):
    """Returns ``step(state, batch) -> (state, metrics)`` — pure, jit-able.

    grad_accum > 1: microbatches are scanned with fp32 gradient
    accumulation; the cross-replica gradient reduction happens once per
    step (communication-avoidance — the paper's ``no_send_back`` applied to
    gradients, DESIGN.md §4).
    """
    accum = grad_accum if grad_accum is not None else cfg.grad_accum

    def lf(params, batch):
        return loss_fn(cfg, params, batch, impl=impl)

    vg = jax.value_and_grad(lf, has_aux=True)

    def step_fn(state: TrainState, batch: dict):
        if accum <= 1:
            (loss, metrics), grads = vg(state.params, batch)
        else:
            micro = _split_microbatches(batch, accum)

            def one(carry, mb):
                gacc, lacc = carry
                (l, m), g = vg(state.params, mb)
                # NOTE: pinning g to the param shardings here was tried and
                # REFUTED (+22% HBM, no AR->RS conversion) — see §Perf
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (gsum, lsum), ms = jax.lax.scan(one, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda x: x[-1], ms)

        lr = schedule(state.step) if schedule is not None else spec.lr
        new_params, new_opt, om = opt_update(spec, grads, state.opt_state,
                                             state.params, lr)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss_total"] = loss
        return TrainState(params=new_params, opt_state=new_opt,
                          step=state.step + 1), metrics

    return step_fn
