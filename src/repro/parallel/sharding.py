"""Logical-axis sharding rules (MaxText-style) + constraint context.

Models annotate tensors with *logical* axis names; a :class:`ShardingRules`
mapping resolves them to mesh axes.  Outside any rules context the
annotations are no-ops, so all model code runs unmodified on one device.

Default production mapping (DESIGN.md §5):

  batch        -> ("pod", "data")       data parallel over pods × data axis
  seq          -> "model"               sequence/context parallelism (activations)
  kv_seq       -> "model"               KV-cache sequence sharding (decode)
  kv_seq_long  -> ("data", "model")     500k decode, batch=1: shard KV everywhere
  heads        -> "model"               tensor parallel attention (when divisible)
  d_ff         -> "model"               tensor parallel MLP
  experts      -> "model" (if divisible) expert parallel
  vocab        -> "model"               sharded embedding/unembedding
  embed_fsdp   -> ("pod", "data")       parameter-storage sharding (ZeRO-3)
  ssm_heads    -> "model"               SSD head parallelism
  slots        -> "data"                serve: batch slots across device groups
  pages        -> "data"                serve: KV page pool across device groups

A rule resolving to an axis that does not divide the tensor dim is dropped
(replication) — divisibility-safe by construction.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules", "DEFAULT_RULES", "logical", "use_rules", "current_rules",
    "named_sharding", "logical_spec", "param_specs_for_tree",
]


_STATE = threading.local()


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh | None
    rules: dict[str, Any]   # logical name -> mesh axis | tuple | None
    enable: bool = True

    def axis_size(self, axis) -> int:
        if self.mesh is None or axis is None:
            return 1
        if isinstance(axis, (tuple, list)):
            n = 1
            for a in axis:
                n *= self.mesh.shape.get(a, 1)
            return n
        return self.mesh.shape.get(axis, 1)

    def spec_for(self, names: Sequence[str | None],
                 dims: Sequence[int] | None = None) -> P:
        """Resolve logical names to a PartitionSpec; drop non-dividing axes."""
        out = []
        used: set[str] = set()
        for i, name in enumerate(names):
            axis = self.rules.get(name) if name else None
            if axis is None:
                out.append(None)
                continue
            flat = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
            # drop axes absent from the mesh, already used, or non-dividing
            keep = []
            size = 1
            for a in flat:
                if a in used or (self.mesh and a not in self.mesh.shape):
                    continue
                s = self.mesh.shape.get(a, 1) if self.mesh else 1
                if dims is not None and dims[i] % (size * s) != 0:
                    continue
                keep.append(a)
                size *= s
            for a in keep:
                used.add(a)
            if not keep:
                out.append(None)
            elif len(keep) == 1:
                out.append(keep[0])
            else:
                out.append(tuple(keep))
        while out and out[-1] is None:
            out.pop()
        return P(*out)


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": "model",
    "dec_seq": None,
    "kv_seq": "model",
    "kv_seq_long": ("data", "model"),
    "heads": "model",
    "heads_flat": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "ssm_inner": "model",
    "experts": "model",
    "moe_capacity": ("pod", "data"),
    "vocab": "model",
    "embed": None,
    "embed_fsdp": ("pod", "data"),
    "ssm_heads": "model",
    "ssm_state": None,
    "layers": None,
    # serve-side axes (DESIGN.md §13): batch slots and the paged KV pool
    # partition over the data axis (device groups); kv_heads above covers
    # tensor-parallel decode of the pool.
    "slots": "data",
    "pages": "data",
}


def current_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh | None, rules: Mapping[str, Any] | None = None):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = ShardingRules(mesh=mesh, rules=dict(rules or DEFAULT_RULES))
    try:
        yield _STATE.rules
    finally:
        _STATE.rules = prev


def logical(x, *names: str | None):
    """Apply a logical sharding constraint; no-op outside a rules context."""
    r = current_rules()
    if r is None or r.mesh is None or not r.enable:
        return x
    spec = r.spec_for(names, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def logical_spec(names: Sequence[str | None], dims: Sequence[int],
                 rules: ShardingRules | None = None) -> P:
    r = rules or current_rules()
    if r is None:
        return P()
    return r.spec_for(names, dims)


def named_sharding(names: Sequence[str | None], dims: Sequence[int],
                   rules: ShardingRules | None = None) -> NamedSharding:
    r = rules or current_rules()
    return NamedSharding(r.mesh, r.spec_for(names, dims))


def param_specs_for_tree(tree, logical_axes_tree, rules: ShardingRules):
    """Map a tree of logical-axis tuples to NamedShardings using shapes of
    ``tree`` (a tree of ShapeDtypeStruct or arrays)."""
    def one(x, axes):
        return NamedSharding(rules.mesh, rules.spec_for(axes, dims=x.shape))
    return jax.tree.map(one, tree, logical_axes_tree)
