from .sharding import (ShardingRules, DEFAULT_RULES, logical, use_rules,
                       current_rules, named_sharding, logical_spec)
