"""Path-based logical axes for parameter / optimizer / cache trees.

One central mapping from tree paths to logical axis names (resolved by
``ShardingRules.spec_for``, which drops non-dividing axes).  This is the
framework's equivalent of the paper's automatic data distribution: the user
declares *what* a tensor is (by its place in the tree); the framework
derives placement.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding

from .sharding import ShardingRules

__all__ = ["axes_for_path", "tree_logical_axes", "tree_shardings",
           "batch_logical_axes"]


def _last(path: Sequence[str], *names: str) -> bool:
    return len(path) >= 1 and path[-1] in names


def _contains(path: Sequence[str], *names: str) -> bool:
    return any(p in names for p in path)


def axes_for_path(path: tuple[str, ...], ndim: int) -> tuple[Any, ...]:
    """Logical axes for a *parameter* leaf at ``path`` with rank ``ndim``.

    Stacked (scanned) leaves carry a leading group axis — detected by the
    caller passing the raw ndim; any extra leading dims map to None.
    """
    p = [str(x) for x in path]

    def pad(axes: tuple) -> tuple:
        extra = ndim - len(axes)
        return (None,) * extra + axes if extra > 0 else axes[-ndim:] if ndim else ()

    # --- embeddings ---------------------------------------------------------
    if _last(p, "table", "unembed"):
        return pad(("vocab", "embed_fsdp"))
    if _last(p, "enc_pos", "dec_pos"):
        return pad((None, "embed_fsdp"))

    # --- MoE ------------------------------------------------------------------
    if _contains(p, "moe"):
        if _last(p, "w") and _contains(p, "router"):
            return pad(("embed_fsdp", None))
        if _last(p, "gate", "up") and not _contains(p, "shared"):
            return pad(("experts", "embed_fsdp", "d_ff"))
        if _last(p, "down") and not _contains(p, "shared"):
            return pad(("experts", "d_ff", "embed_fsdp"))
        if _last(p, "shared_gate"):
            return pad((None, None))
        # shared expert falls through to MLP rules below

    # --- attention -------------------------------------------------------------
    if _contains(p, "attn", "cross"):
        if _last(p, "w"):
            if _contains(p, "q", "k", "v"):
                return pad(("embed_fsdp", "heads_flat"))
            if _contains(p, "o"):
                return pad(("heads_flat", "embed_fsdp"))
        if _last(p, "b"):
            return pad((None,))

    # --- SSM ----------------------------------------------------------------
    if _contains(p, "mixer"):
        if _contains(p, "in_proj") and _last(p, "w"):
            return pad(("embed_fsdp", "ssm_inner"))
        if _contains(p, "out_proj") and _last(p, "w"):
            return pad(("ssm_inner", "embed_fsdp"))
        if _last(p, "conv_w"):
            return pad((None, "ssm_inner"))
        return pad((None,) * ndim)

    # --- MLP -------------------------------------------------------------------
    if _contains(p, "mlp", "shared"):
        if _last(p, "w"):
            if _contains(p, "up", "gate"):
                return pad(("embed_fsdp", "d_ff"))
            if _contains(p, "down"):
                return pad(("d_ff", "embed_fsdp"))
        if _last(p, "b"):
            return pad((None,))

    # --- norms / scalars ---------------------------------------------------------
    return pad((None,) * max(ndim, 0))


# KV / SSM cache leaves -------------------------------------------------------


def _cache_axes(path: tuple[str, ...], ndim: int) -> tuple:
    p = [str(x) for x in path]
    if _last(p, "k", "v"):
        # cache layout (B, KV, T, D)
        axes = ("batch", "kv_heads", "kv_seq", None)
    elif _last(p, "state"):
        axes = ("batch", "ssm_heads", None, None)
    elif _last(p, "conv"):
        axes = ("batch", None, "ssm_inner")
    elif _last(p, "len"):
        return ()
    else:
        axes = (None,) * ndim
    extra = ndim - len(axes)
    return (None,) * extra + axes if extra > 0 else axes[-ndim:]


def _opt_transform(path: tuple[str, ...], axes: tuple, ndim: int) -> tuple:
    """Adafactor factored stats reshape the param axes."""
    p = [str(x) for x in path]
    if _last(p, "vr"):
        return axes[:-1]
    if _last(p, "vc"):
        return axes[:-2] + axes[-1:]
    return axes


def tree_logical_axes(tree, *, kind: str = "params"):
    """Tree of logical-axes tuples matching ``tree``'s structure.

    kind: params | state (TrainState incl. optimizer) | cache
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = tuple(str(getattr(x, "key", getattr(x, "idx", x))) for x in path)
        ndim = len(getattr(leaf, "shape", ()))
        if kind == "cache":
            out.append(_cache_axes(keys, ndim))
            continue
        # strip optimizer wrappers to find the parameter path
        core = tuple(k for k in keys
                     if k not in ("params", "opt_state", "m", "v", "f",
                                  "step", "count", "vr", "vc"))
        if keys and keys[-1] in ("step", "count"):
            out.append(())
            continue
        if keys[-1] in ("vr", "vc"):
            base = axes_for_path(core, ndim + (1 if keys[-1] == "vr" else 1))
            out.append(_opt_transform(keys, base, ndim))
        else:
            out.append(axes_for_path(core, ndim))
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_shardings(tree, rules: ShardingRules, *, kind: str = "params"):
    """NamedSharding tree for ``tree`` (arrays or ShapeDtypeStructs)."""
    axes = tree_logical_axes(tree, kind=kind)

    def one(leaf, ax):
        dims = getattr(leaf, "shape", ())
        return NamedSharding(rules.mesh, rules.spec_for(ax, dims=dims))

    return jax.tree.map(one, tree, axes)


def batch_logical_axes(batch) -> Any:
    def one_path(path, leaf):
        ndim = len(leaf.shape)
        return ("batch",) + (None,) * (ndim - 1)
    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [one_path(p, l) for p, l in flat])
