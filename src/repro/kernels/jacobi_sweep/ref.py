"""Pure-jnp oracle for the Jacobi sweep kernel."""
import jax.numpy as jnp


def jacobi_sweep_ref(A, x, b, diag):
    Af = A.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    t = Af @ xf
    return ((b.astype(jnp.float32) - t + diag.astype(jnp.float32) * xf)
            / diag.astype(jnp.float32)).astype(x.dtype)
