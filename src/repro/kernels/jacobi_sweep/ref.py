"""Pure-jnp oracles for the Jacobi sweep kernels."""
import jax.numpy as jnp


def jacobi_sweep_ref(A, x, b, diag):
    Af = A.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    t = Af @ xf
    return ((b.astype(jnp.float32) - t + diag.astype(jnp.float32) * xf)
            / diag.astype(jnp.float32)).astype(x.dtype)


def jacobi_sweep_residual_ref(A, x, b, diag):
    """Fused oracle: ``(x', ‖b - A·x‖²)`` with a single matvec.

    The residual is that of the *incoming* iterate (same contract as the
    fused kernel: convergence loops test it lagged by one iteration).
    """
    Af = A.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    df = diag.astype(jnp.float32)
    r = b.astype(jnp.float32) - Af @ xf
    x2 = (xf + r / df).astype(x.dtype)
    return x2, jnp.sum(r * r)
