"""Public wrappers for the Jacobi sweep kernels.

Dispatch (``repro.kernels.runtime.resolve_impl``): Pallas kernel on TPU,
interpret mode elsewhere, jnp oracle on ``impl="ref"``.  Block sizes left
unset are consulted from the autotune cache (``repro.kernels.tuning``) —
a cache-only lookup, safe at jit trace time.  Non-divisible N is handled
by zero-padding the system up to the block lcm (pad rows of A are zero,
pad diag is one, so padded lanes contribute exactly zero to both x' and
the fused residual) and slicing the result back.
"""
import functools
import math

import jax
import jax.numpy as jnp

from ..runtime import resolve_impl
from ..tuning import get_tuner
from .kernel import jacobi_sweep_kernel, jacobi_sweep_residual_kernel
from .ref import jacobi_sweep_ref, jacobi_sweep_residual_ref

DEFAULT_BLOCK = 256

_ref = jax.jit(jacobi_sweep_ref)
_residual_ref = jax.jit(jacobi_sweep_residual_ref)


def _tuned_blocks(N: int, dtype, row_block, col_block, impl=None):
    if row_block is None or col_block is None:
        cfg = get_tuner().lookup("jacobi_sweep", (N, N), dtype,
                                 impl=impl) or {}
        row_block = row_block or cfg.get("row_block", DEFAULT_BLOCK)
        col_block = col_block or cfg.get("col_block", DEFAULT_BLOCK)
    return row_block, col_block


def _padded_system(A, x, b, diag, rb: int, cb: int):
    # pad up to a multiple of lcm(rb, cb) computed from the UNCLAMPED block
    # sizes: clamping first can turn a power-of-two block into a value
    # coprime with the other block (e.g. N=300, blocks 512/256 -> clamped
    # rb=300, lcm(300, 256)=19200), exploding the pad.  With power-of-two
    # blocks the lcm is just max(rb, cb), so N=300 pads to 512.
    N = A.shape[0]
    pad = -N % math.lcm(rb, cb)
    if pad:
        A = jnp.pad(A, ((0, pad), (0, pad)))
        x = jnp.pad(x, (0, pad))
        b = jnp.pad(b, (0, pad))
        diag = jnp.pad(diag, (0, pad), constant_values=1.0)
    return A, x, b, diag


@functools.partial(jax.jit,
                   static_argnames=("row_block", "col_block", "interpret"))
def _sweep_call(A, x, b, diag, *, row_block, col_block, interpret):
    N = A.shape[0]
    Ap, xp, bp, dp = _padded_system(A, x, b, diag, row_block, col_block)
    out = jacobi_sweep_kernel(Ap, xp, bp, dp, row_block=row_block,
                              col_block=col_block, interpret=interpret)
    return out[:N]


@functools.partial(jax.jit,
                   static_argnames=("row_block", "col_block", "interpret"))
def _residual_call(A, x, b, diag, *, row_block, col_block, interpret):
    N = A.shape[0]
    Ap, xp, bp, dp = _padded_system(A, x, b, diag, row_block, col_block)
    out, partials = jacobi_sweep_residual_kernel(
        Ap, xp, bp, dp, row_block=row_block, col_block=col_block,
        interpret=interpret)
    return out[:N], jnp.sum(partials)


def jacobi_sweep(A, x, b, diag, *, impl="auto", row_block=None,
                 col_block=None):
    """One Jacobi sweep: A (N, N); x, b, diag (N,) -> x' (N,)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref(A, x, b, diag)
    rb, cb = _tuned_blocks(A.shape[0], x.dtype, row_block, col_block,
                           impl=impl)
    return _sweep_call(A, x, b, diag, row_block=rb, col_block=cb,
                       interpret=(impl == "interpret"))


def jacobi_sweep_residual(A, x, b, diag, *, impl="auto", row_block=None,
                          col_block=None):
    """Fused sweep: returns ``(x', ‖b - A·x‖)`` with ONE A-matvec.

    The returned norm is the residual of the *incoming* iterate ``x`` (the
    accumulator already holds A·x when x' is formed, so it is free); a
    convergence loop tests it lagged by one iteration.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        x2, rsq = _residual_ref(A, x, b, diag)
    else:
        rb, cb = _tuned_blocks(A.shape[0], x.dtype, row_block, col_block,
                               impl=impl)
        x2, rsq = _residual_call(A, x, b, diag, row_block=rb, col_block=cb,
                                 interpret=(impl == "interpret"))
    return x2, jnp.sqrt(rsq)
