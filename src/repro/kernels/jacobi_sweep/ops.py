"""jit'd wrapper for the Jacobi sweep."""
import functools
import jax

from .kernel import jacobi_sweep_kernel
from .ref import jacobi_sweep_ref


@functools.partial(jax.jit, static_argnames=("impl", "row_block", "col_block"))
def jacobi_sweep(A, x, b, diag, *, impl="auto", row_block=256, col_block=256):
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref" or A.shape[0] % min(row_block, A.shape[0]):
        return jacobi_sweep_ref(A, x, b, diag)
    return jacobi_sweep_kernel(A, x, b, diag, row_block=row_block,
                               col_block=col_block,
                               interpret=(impl == "interpret"))
