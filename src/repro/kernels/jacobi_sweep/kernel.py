"""Blocked Jacobi sweep Pallas TPU kernel — the paper's §4 hot loop.

One Jacobi iteration  x' = (b - (A - D) x) / diag(A)  as a blocked
matrix-vector product: grid (row_blocks, col_blocks), col axis innermost
sequential with a VMEM row accumulator; on the last col step the diagonal
correction, right-hand side and division are fused in.

TPU adaptation of the paper's OpenMP-parallel sweep: the (rb × cb) A tile
is the MXU operand; the accumulator never leaves VMEM (the paper's
"sequences of instructions" = row blocks here).

Two variants:

* :func:`jacobi_sweep_kernel` — the plain sweep, x' only.
* :func:`jacobi_sweep_residual_kernel` — **fused-residual** sweep.  On the
  last col step the accumulator holds ``A·x`` for the row block, so the
  residual of the *incoming* iterate, ``r = b - A·x``, is already in VMEM:
  the kernel emits both ``x' = x + r / d`` and the per-row-block partial
  sums ``Σ r²`` in the same pass.  The caller reduces the partials to
  ``‖b - A·x‖²`` outside the kernel.  A convergence loop built on this
  needs exactly **one** A-matvec per iteration (the residual it tests is
  lagged by one iteration — standard for fused Jacobi/Richardson loops),
  halving the memory traffic of the sweep+residual pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _jacobi_kernel(a_ref, x_ref, b_ref, diag_ref, xr_ref, o_ref, acc, *,
                   n_col_blocks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = a_ref[...].astype(jnp.float32)            # (rb, cb)
    x = x_ref[...].astype(jnp.float32)            # (cb, 1)
    acc[...] += jax.lax.dot_general(a, x, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ci == n_col_blocks - 1)
    def _emit():
        b = b_ref[...].astype(jnp.float32)        # (rb, 1)
        d = diag_ref[...].astype(jnp.float32)     # (rb, 1)
        xr = xr_ref[...].astype(jnp.float32)      # (rb, 1)
        # acc holds (A x) including the diagonal term; remove it.
        o_ref[...] = ((b - acc[...] + d * xr) / d).astype(o_ref.dtype)


def jacobi_sweep_kernel(A, x, b, diag, *, row_block: int = 256,
                        col_block: int = 256, interpret: bool = False):
    """A: (N, N); x, b, diag: (N,).  Returns x' (N,)."""
    N = A.shape[0]
    rb, cb = min(row_block, N), min(col_block, N)
    assert N % rb == 0 and N % cb == 0, (N, rb, cb)
    x2 = x.reshape(N, 1)
    out = pl.pallas_call(
        functools.partial(_jacobi_kernel, n_col_blocks=N // cb),
        grid=(N // rb, N // cb),
        in_specs=[
            pl.BlockSpec((rb, cb), lambda r, c: (r, c)),
            pl.BlockSpec((cb, 1), lambda r, c: (c, 0)),
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
        ],
        out_specs=pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), x.dtype),
        scratch_shapes=[pltpu.VMEM((rb, 1), jnp.float32)],
        interpret=interpret,
    )(A, x2, b.reshape(N, 1), diag.reshape(N, 1), x2)
    return out[:, 0]


def _jacobi_fused_kernel(a_ref, x_ref, b_ref, diag_ref, xr_ref, o_ref, p_ref,
                         acc, *, n_col_blocks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = a_ref[...].astype(jnp.float32)            # (rb, cb)
    x = x_ref[...].astype(jnp.float32)            # (cb, 1)
    acc[...] += jax.lax.dot_general(a, x, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ci == n_col_blocks - 1)
    def _emit():
        b = b_ref[...].astype(jnp.float32)        # (rb, 1)
        d = diag_ref[...].astype(jnp.float32)     # (rb, 1)
        xr = xr_ref[...].astype(jnp.float32)      # (rb, 1)
        r = b - acc[...]                          # residual rows of incoming x
        o_ref[...] = (xr + r / d).astype(o_ref.dtype)
        p_ref[...] = jnp.sum(r * r).reshape(1, 1)


def jacobi_sweep_residual_kernel(A, x, b, diag, *, row_block: int = 256,
                                 col_block: int = 256,
                                 interpret: bool = False):
    """Fused sweep: returns ``(x', partials)`` in one A-pass.

    ``partials`` has shape (row_blocks, 1) fp32; ``partials.sum()`` is
    ``‖b - A·x‖²`` — the squared residual of the *input* iterate.
    """
    N = A.shape[0]
    rb, cb = min(row_block, N), min(col_block, N)
    assert N % rb == 0 and N % cb == 0, (N, rb, cb)
    x2 = x.reshape(N, 1)
    out, partials = pl.pallas_call(
        functools.partial(_jacobi_fused_kernel, n_col_blocks=N // cb),
        grid=(N // rb, N // cb),
        in_specs=[
            pl.BlockSpec((rb, cb), lambda r, c: (r, c)),
            pl.BlockSpec((cb, 1), lambda r, c: (c, 0)),
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rb, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((1, 1), lambda r, c: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), x.dtype),
            jax.ShapeDtypeStruct((N // rb, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((rb, 1), jnp.float32)],
        interpret=interpret,
    )(A, x2, b.reshape(N, 1), diag.reshape(N, 1), x2)
    return out[:, 0], partials
