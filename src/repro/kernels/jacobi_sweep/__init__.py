from . import ops, ref
from .kernel import jacobi_sweep_kernel
from .ops import jacobi_sweep
