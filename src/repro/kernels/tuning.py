"""Kernel autotuning subsystem.

The four Pallas kernel packages ship sensible default block sizes, but the
paper's headline claim (framework within ~10 % of a tailored code) only
holds when the inner loops run at machine speed — and the best block
shape is a property of the *machine*, not the code (OpenFPM makes the
same point for reusable frameworks).  This module provides

* a **block-size search**: time each candidate config with the
  ``_time``-style harness used by ``benchmarks/kernel_bench`` and keep the
  fastest (:meth:`Autotuner.tune`),
* a **persistent JSON cache** keyed by ``(kernel, backend, shape-bucket,
  dtype)`` so the search runs once per machine (:class:`TuningCache`;
  corrupt or truncated cache files are discarded, never fatal),
* **transparent consultation** from every kernel ``ops.py`` wrapper:
  when the caller does not pin block sizes, :meth:`Autotuner.lookup`
  supplies the tuned config (cache-only — wrappers never *time* anything,
  so consulting is safe at jit trace time),
* the **cost-model bridge**: measured kernel times calibrate
  :class:`repro.core.scheduler.CostModelParams`
  (:func:`calibrated_cost_params`) and seed the master scheduler's
  observed-time table (:func:`observed_fn_times` in ``apps/jacobi``), so
  placement uses observed rather than roofline-guessed costs.

Tuning itself is driven from outside jit (``benchmarks/kernel_bench``,
``benchmarks/run --suite kernels``); timing inside a trace would record
tracing time, not kernel time.
"""
from __future__ import annotations

import json
import math
import os
import tempfile
import time
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax

__all__ = [
    "DEFAULT_CANDIDATES",
    "TuningCache",
    "Autotuner",
    "get_tuner",
    "shape_bucket",
    "cache_key",
    "default_impl",
    "calibrated_cost_params",
]

# Candidate grids per kernel.  Entries must be valid kwargs of the kernel's
# ops-level wrapper; invalid combinations for a given shape are skipped at
# tune time (the wrapper raises, the tuner moves on).
DEFAULT_CANDIDATES: dict[str, list[dict[str, int]]] = {
    "jacobi_sweep": [{"row_block": r, "col_block": c}
                     for r in (128, 256, 512) for c in (128, 256, 512)],
    "rmsnorm": [{"row_block": r} for r in (64, 128, 256, 512)],
    "flash_attention": [{"q_block": q, "kv_block": k}
                        for q in (128, 256, 512) for k in (128, 256, 512)],
    # the SSD kernel tiles by its (chunk, head) grid — nothing to search yet,
    # but timing it populates the cost-model bridge
    "ssd_scan": [{}],
    "paged_attention": [{"head_block": h} for h in (1, 2)],
}

_ENV_CACHE = "REPRO_TUNE_CACHE"
_DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                              "kernel_tune.json")


def shape_bucket(shape: Sequence[int]) -> tuple[int, ...]:
    """Round every dim up to the next power of two — one cache entry serves
    the whole bucket, so ragged workload shapes don't explode the cache."""
    return tuple(1 if d <= 1 else 2 ** math.ceil(math.log2(d)) for d in shape)


def default_impl(backend: str) -> str:
    """The impl a *real* run on ``backend`` resolves ``auto`` to — the only
    impl whose timings describe that backend's hardware."""
    return "kernel" if backend == "tpu" else "interpret"


def cache_key(kernel: str, backend: str, shape: Sequence[int], dtype,
              impl: str | None = None) -> str:
    """Five-part key ``kernel|backend|impl|bucket|dtype``.

    ``impl`` is the *resolved* execution path the timing was taken under
    (kernel vs interpret).  Keying by it is what stops backend poisoning:
    a forced-interpret debug run on a TPU host records
    ``...|tpu|interpret|...`` entries that a real kernel lookup
    (``...|tpu|kernel|...``) can never hit.  Unset, it defaults to the
    backend's real impl (:func:`default_impl`).
    """
    bucket = "x".join(str(d) for d in shape_bucket(shape))
    impl = impl or default_impl(backend)
    return (f"{kernel}|{backend}|{impl}|{bucket}|"
            f"{jax.numpy.dtype(dtype).name}")


class TuningCache:
    """Persistent JSON store: key -> {config, median_s, flops, bytes}."""

    def __init__(self, path: str | None = None):
        self.path = path or os.environ.get(_ENV_CACHE) or _DEFAULT_CACHE
        self._entries: dict[str, dict] = {}
        self._loaded = False

    def load(self) -> dict[str, dict]:
        if self._loaded:
            return self._entries
        self._loaded = True
        try:
            with open(self.path) as f:
                raw = json.load(f)
            if isinstance(raw, dict):
                # schema-validate each entry too: a hand-edited or
                # foreign-schema entry must be dropped here, not crash
                # lookup()/observed_s() in every ops wrapper later.
                # Legacy 4-part keys (pre impl-keying) are dropped rather
                # than migrated: they can't say whether they were timed
                # under interpret or the real kernel, which is exactly the
                # ambiguity that poisoned real-backend calibration.
                self._entries = {
                    k: v for k, v in raw.get("entries", raw).items()
                    if isinstance(v, dict)
                    and isinstance(v.get("config"), dict)
                    and isinstance(v.get("median_s"), (int, float))
                    and len(k.split("|")) == 5}
        except (OSError, ValueError):
            # missing, unreadable or corrupt cache — start fresh; tuning is
            # an optimisation, never a correctness dependency
            self._entries = {}
        return self._entries

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # atomic replace so a crash mid-write can't corrupt the cache;
        # never fatal (e.g. read-only FS, or a non-JSON-serializable config
        # value raising TypeError from json.dump) and never leaks the tmp
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": 1, "entries": self._entries}, f, indent=1)
            os.replace(tmp, self.path)
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def get(self, key: str) -> dict | None:
        return self.load().get(key)

    def put(self, key: str, entry: dict, *, persist: bool = True) -> None:
        self.load()
        self._entries[key] = entry
        if persist:
            self.save()

    def __len__(self) -> int:
        return len(self.load())


class Autotuner:
    """Block-size search + cache consultation.

    ``timer`` is injectable (tests use a seeded stub so selection is
    deterministic); it must behave like ``time.perf_counter``.
    """

    def __init__(self, cache: TuningCache | None = None, *,
                 timer: Callable[[], float] | None = None, iters: int = 3):
        # `is not None`, not truthiness: an empty TuningCache has len 0
        self.cache = cache if cache is not None else TuningCache()
        self.timer = timer or time.perf_counter
        self.iters = iters

    # -- timing ----------------------------------------------------------------
    def _time_call(self, fn: Callable[[], Any], iters: int | None = None) -> float:
        """Median wall time of ``fn`` (first call excluded: compile)."""
        iters = iters or self.iters
        jax.block_until_ready(fn())
        samples = []
        for _ in range(iters):
            t0 = self.timer()
            jax.block_until_ready(fn())
            samples.append(self.timer() - t0)
        samples.sort()
        return samples[len(samples) // 2]

    # -- search ----------------------------------------------------------------
    def tune(self, kernel: str, make_call: Callable[[dict], Callable[[], Any]],
             *, shape: Sequence[int], dtype,
             candidates: Iterable[Mapping[str, int]] | None = None,
             backend: str | None = None, impl: str | None = None,
             flops: float = 0.0,
             bytes_moved: float = 0.0, force: bool = False) -> dict:
        """Find (or recall) the fastest config for ``kernel`` at ``shape``.

        ``make_call(config)`` returns a zero-arg callable running the kernel
        with that config.  Configs that raise are skipped.  The winning
        entry — ``{config, median_s, flops, bytes, backend, impl, timed}``
        — is persisted under the impl-resolved key; a later call with the
        same key returns it without any timing (the cache round-trip the
        benchmarks rely on).  ``impl`` must be the resolved execution path
        ``make_call`` actually runs (defaults to the backend's real impl).
        """
        backend = backend or jax.default_backend()
        impl = impl or default_impl(backend)
        key = cache_key(kernel, backend, shape, dtype, impl)
        if not force:
            hit = self.cache.get(key)
            if hit is not None:
                return hit
        cands = list(candidates if candidates is not None
                     else DEFAULT_CANDIDATES.get(kernel, [{}]))
        best_cfg, best_t, timed, last_exc = None, float("inf"), 0, None
        for cfg in cands:
            try:
                fn = make_call(dict(cfg))
                t = self._time_call(fn)
            except Exception as e:            # config invalid for this shape
                last_exc = e
                continue
            timed += 1
            if t < best_t:
                best_cfg, best_t = dict(cfg), t
        if best_cfg is None:
            raise RuntimeError(
                f"autotune({kernel}): no candidate ran for shape "
                f"{tuple(shape)}") from last_exc
        entry = {"config": best_cfg, "median_s": best_t, "flops": flops,
                 "bytes": bytes_moved, "backend": backend, "impl": impl,
                 "timed": timed}
        self.cache.put(key, entry)
        return entry

    # -- consultation (cache-only: safe at trace time) -------------------------
    def lookup(self, kernel: str, shape: Sequence[int], dtype,
               backend: str | None = None,
               impl: str | None = None) -> dict | None:
        """Tuned config for (kernel, backend, impl, bucket, dtype), or
        None.  Interpret-tuned configs never answer a kernel lookup."""
        backend = backend or jax.default_backend()
        entry = self.cache.get(cache_key(kernel, backend, shape, dtype,
                                         impl))
        return dict(entry["config"]) if entry else None

    def observed_s(self, kernel: str, shape: Sequence[int], dtype,
                   backend: str | None = None, impl: str | None = None,
                   nearest: bool = False) -> float | None:
        """Measured median seconds for the tuned config, or None.

        With ``nearest=True`` a miss falls back to the closest tuned
        bucket of the same kernel/backend/dtype, scaling the time by the
        element-count ratio (work ∝ ∏dims for the kernels tuned here) —
        the benchmark tunes one bucket per kernel, while workloads land in
        whatever bucket their size hits (n=2709 buckets to 4096, the tune
        at 2048 would otherwise never be consulted)."""
        backend = backend or jax.default_backend()
        impl = impl or default_impl(backend)
        entry = self.cache.get(cache_key(kernel, backend, shape, dtype,
                                         impl))
        if entry is not None:
            return float(entry["median_s"])
        if not nearest:
            return None
        want = shape_bucket(shape)
        dtype_name = jax.numpy.dtype(dtype).name
        best = None
        for key, e in self.cache.load().items():
            parts = key.split("|")
            if (len(parts) != 5 or parts[0] != kernel
                    or parts[1] != backend or parts[2] != impl
                    or parts[4] != dtype_name):
                continue
            try:
                bucket = tuple(int(d) for d in parts[3].split("x"))
            except ValueError:
                continue
            if len(bucket) != len(want):
                continue
            dist = abs(math.log(math.prod(want) / math.prod(bucket)))
            if best is None or dist < best[0]:
                best = (dist, bucket, e)
        if best is None:
            return None
        _, bucket, e = best
        # scale by true element counts, not bucket counts: the caller's
        # actual work is ∏shape, the measurement's is ∏bucket
        return float(e["median_s"]) * math.prod(shape) / math.prod(bucket)


# ---------------------------------------------------------------------------
# Module singleton (per cache path, so REPRO_TUNE_CACHE redirects in tests)
# ---------------------------------------------------------------------------

_tuners: dict[str, Autotuner] = {}


def get_tuner(cache_path: str | None = None) -> Autotuner:
    path = cache_path or os.environ.get(_ENV_CACHE) or _DEFAULT_CACHE
    t = _tuners.get(path)
    if t is None:
        t = Autotuner(TuningCache(path))
        _tuners[path] = t
    return t


# ---------------------------------------------------------------------------
# Cost-model bridge (tuned timings -> scheduler)
# ---------------------------------------------------------------------------


def calibrated_cost_params(base=None, tuner: Autotuner | None = None,
                           backend: str | None = None):
    """Derive ``CostModelParams`` from *observed* kernel rates.

    Every cache entry for the **current backend** that recorded its
    flops/bytes yields an achieved compute rate ``flops / median_s`` and
    memory rate ``bytes / median_s``; the best achieved rates replace the
    roofline guesses in ``base``, so the cost-model placement strategy
    prices jobs with what this machine was *measured* to deliver.  Entries
    from other backends are ignored — the cache is persistent and shared,
    and e.g. TPU rates would collapse the compute term of a CPU run to
    nothing.  Entries recorded under an impl other than the backend's real
    one (:func:`default_impl`) are ignored too: a forced-interpret debug
    run on a TPU host times the Pallas *interpreter*, not the hardware,
    and would poison the calibration the same way a foreign backend
    would.  With no usable entries ``base`` is returned as-is.
    """
    from repro.core.scheduler import CostModelParams
    base = base or CostModelParams()
    tuner = tuner or get_tuner()
    backend = backend or jax.default_backend()
    want_impl = default_impl(backend)
    peak, bw = 0.0, 0.0
    for entry in tuner.cache.load().values():
        if entry.get("backend") != backend:
            continue
        if entry.get("impl") != want_impl:
            continue
        t = float(entry.get("median_s") or 0.0)
        if t <= 0:
            continue
        peak = max(peak, float(entry.get("flops") or 0.0) / t)
        bw = max(bw, float(entry.get("bytes") or 0.0) / t)
    if peak <= 0.0 and bw <= 0.0:
        return base
    return CostModelParams(
        peak_flops=peak or base.peak_flops,
        mem_bw=bw or base.mem_bw,
        link_bw=base.link_bw,
        dispatch_s=base.dispatch_s,
    )
