"""Paged flash-decode Pallas TPU kernel.

vLLM-style paged attention (DESIGN.md §15): one query token per slot
attends over that slot's KV pages *in place* in the shared page pool.  The
per-slot page table and per-slot cache lengths ride in as scalar-prefetch
operands (``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index
maps resolve ``logical page j of slot b -> physical pool page
table[b, j]`` at DMA-issue time — no dense per-slot gather is ever
materialised (the ~4%/step copy `gather_pages` pays).

Grid: (batch, kv_head_blocks, logical_pages) with the page axis innermost
and sequential; the running max / denominator / accumulator live in VMEM
scratch across page steps (the standard TPU flash-decode schedule).  GQA is
native to the layout: q arrives grouped as (B, KV, G, D) so each kv-head
block reads exactly its own pool heads.

Masking contract (shared with ``models.attention.gather_pages``): physical
page 0 is the reserved trash page — decode writes of free/mid-prefill slots
land there, so its contents are arbitrary.  Blocks whose resolved page id
is 0 read K/V as ZEROS (not NEG_INF): positions inside ``kv_len`` still
contribute exp(0 - m) to the denominator, exactly like the zero-filled
rows the gather path produces, so kernel and gather outputs match bit-for-
token even on slots whose tables point at the trash page.  Positions at or
past ``kv_len`` (and outside the sliding window) are masked to NEG_INF.

The kernel emits the *unnormalised* accumulator plus the running (m, l)
statistics; the ops wrapper LSE-merges the current token's own K/V (the
delta-cache self term) outside, mirroring ``_decode_attn_plus_self`` so the
cache write stays a pure delta.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38
LANE = 128   # the (m, l) outputs broadcast over a full lane dim


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                  acc_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                  window: int | None, page_size: int, g_pad: int,
                  n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    pid = tbl_ref[b, j]

    # skip pages that cannot contain a valid position: entirely at/past the
    # slot's length, or (sliding window) entirely before the window start
    run = j * page_size < kv_len
    if window is not None:
        run = jnp.logical_and(
            run, (j + 1) * page_size - 1 >= kv_len + 1 - window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (hb, g_pad, D)
        k = k_ref[0].astype(jnp.float32)                 # (hb, ps, D)
        v = v_ref[0].astype(jnp.float32)
        # trash page: read as zeros — see the masking contract above
        k = jnp.where(pid == 0, 0.0, k)
        v = jnp.where(pid == 0, 0.0, v)
        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g_pad, page_size), 1)
        ok = pos < kv_len
        if window is not None:
            ok = jnp.logical_and(ok, pos >= kv_len + 1 - window)
        s = jnp.where(ok[None], s, NEG_INF)              # (hb, g_pad, ps)

        m_prev = m_scr[...]                              # (hb, g_pad, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=2, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(j == n_pages - 1)
    def _emit():
        acc_ref[0] = acc_scr[...]
        m_ref[0] = jnp.broadcast_to(m_scr[...], m_ref.shape[1:])
        l_ref[0] = jnp.broadcast_to(l_scr[...], l_ref.shape[1:])


def paged_attention_kernel(q, k_pool, v_pool, pages, kv_len, *,
                           window: int | None = None, head_block: int = 1,
                           interpret: bool = False):
    """q: (B, KV, g_pad, D) pre-scaled grouped queries; k/v_pool:
    (P, KV, page_size, D) shared pools; pages: (B, n_pages) int32 page
    table; kv_len: (B,) int32 valid lengths (OLD lengths — the current
    token's self term is merged outside).

    Returns ``(acc, m, l)``: unnormalised f32 accumulator
    (B, KV, g_pad, D) and running max / denominator broadcast over a LANE
    axis, (B, KV, g_pad, LANE).
    """
    B, KV, g_pad, D = q.shape
    ps = k_pool.shape[2]
    n_pages = pages.shape[1]
    hb = head_block
    assert KV % hb == 0, (KV, hb)

    kernel = functools.partial(
        _paged_kernel, window=window, page_size=ps, g_pad=g_pad,
        n_pages=n_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV // hb, n_pages),
        in_specs=[
            pl.BlockSpec((1, hb, g_pad, D),
                         lambda b, kb, j, tbl, lens: (b, kb, 0, 0)),
            # logical page j of slot b lives in physical page tbl[b, j] —
            # the index map IS the gather
            pl.BlockSpec((1, hb, ps, D),
                         lambda b, kb, j, tbl, lens: (tbl[b, j], kb, 0, 0)),
            pl.BlockSpec((1, hb, ps, D),
                         lambda b, kb, j, tbl, lens: (tbl[b, j], kb, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hb, g_pad, D),
                         lambda b, kb, j, tbl, lens: (b, kb, 0, 0)),
            pl.BlockSpec((1, hb, g_pad, LANE),
                         lambda b, kb, j, tbl, lens: (b, kb, 0, 0)),
            pl.BlockSpec((1, hb, g_pad, LANE),
                         lambda b, kb, j, tbl, lens: (b, kb, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hb, g_pad, 1), jnp.float32),   # running max
            pltpu.VMEM((hb, g_pad, 1), jnp.float32),   # running denominator
            pltpu.VMEM((hb, g_pad, D), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, g_pad, D), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, g_pad, LANE), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, g_pad, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(pages, kv_len, q, k_pool, v_pool)
