"""Public wrapper: paged flash-decode attention over a shared page pool.

``paged_decode_attention`` is the drop-in replacement for the decode-path
``gather_pages`` + ``_decode_attn_plus_self`` pair: same inputs the serving
engine already holds (pool, page table, per-slot lengths, the current
token's K/V delta), same (B, 1, H, D) output, no materialised per-slot
view.  The kernel returns unnormalised (acc, m, l); the current token's
self term is LSE-merged here so the delta-cache write contract of
``models.attention`` is untouched.

Impl resolution differs from :func:`runtime.resolve_impl` in ONE case:
``auto`` off-TPU resolves to ``ref`` (the gather oracle), not interpret —
decode runs every step of every serve trace, and the Pallas interpreter is
orders of magnitude too slow to be a serving default.  Tests opt into
``interpret`` explicitly so CPU CI still exercises the kernel body.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from ..runtime import IMPLS, on_tpu
from ..tuning import get_tuner
from .kernel import paged_attention_kernel
from .ref import paged_decode_attention_ref

DEFAULT_HEAD_BLOCK = 1
_SUBLANE = 8   # grouped-q axis padded to the f32 sublane tile


def resolve_paged_impl(impl: str) -> str:
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; pick from {IMPLS}")
    if impl != "auto":
        return impl
    return "kernel" if on_tpu() else "ref"


def paged_decode_attention(q, k_pool, v_pool, pages, kv_len, kt, vt, *,
                           window: int | None = None, impl: str = "auto",
                           head_block: int | None = None):
    """One-token attention straight against a paged KV pool.

    q: (B, 1, H, D); k/v_pool: (P, KV, page_size, D) shared pools; pages:
    (B, n_pages) int32 per-slot page table (physical page 0 = trash, read
    as zeros); kv_len: scalar or (B,) OLD cache lengths; kt/vt:
    (B, KV, 1, D) current-token K/V.  Returns (B, 1, H, D), numerically
    matching ``_decode_attn_plus_self`` over the gathered view.

    ``head_block`` (kv heads per grid step) comes from the autotune cache
    when unset — a cache-only, trace-safe lookup like the other kernels.
    """
    impl = resolve_paged_impl(impl)
    if impl != "ref" and k_pool.dtype != q.dtype:
        impl = "ref"   # f8-stored pools: the ref path casts the layer slice
    if impl == "ref":
        return paged_decode_attention_ref(q, k_pool, v_pool, pages, kv_len,
                                          kt, vt, window=window)

    B, _, H, D = q.shape
    KV = k_pool.shape[1]
    G = H // KV
    if head_block is None:
        cfg = get_tuner().lookup("paged_attention", q.shape, q.dtype,
                                 impl=impl) or {}
        head_block = cfg.get("head_block", DEFAULT_HEAD_BLOCK)
    hb = max(1, min(int(head_block), KV))
    while KV % hb:
        hb -= 1

    kv_len = jnp.broadcast_to(jnp.reshape(jnp.asarray(kv_len), (-1,)),
                              (B,)).astype(jnp.int32)
    scale = 1.0 / math.sqrt(D)
    qf = (q.reshape(B, KV, G, D) * scale).astype(q.dtype)
    g_pad = -(-G // _SUBLANE) * _SUBLANE
    qp = jnp.pad(qf, ((0, 0), (0, 0), (0, g_pad - G), (0, 0)))
    acc, m, l = paged_attention_kernel(
        qp, k_pool, v_pool, pages.astype(jnp.int32), kv_len,
        window=window, head_block=hb, interpret=(impl == "interpret"))
    acc, m, l = acc[:, :, :G], m[:, :, :G, 0], l[:, :, :G, 0]

    # LSE merge of the current token's self term (delta-cache contract:
    # kt/vt are not yet in the pool) — mirrors _decode_attn_plus_self
    s_self = jnp.einsum("bkgd,bktd->bkgt", qf, kt.astype(q.dtype),
                        preferred_element_type=jnp.float32)[..., 0]
    m_tot = jnp.maximum(m, s_self)
    alpha = jnp.exp(m - m_tot)
    beta = jnp.exp(s_self - m_tot)
    l_tot = alpha * l + beta
    out = alpha[..., None] * acc + beta[..., None] * vt[:, :, 0, :].astype(
        jnp.float32)[:, :, None, :]
    out = out / l_tot[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)
