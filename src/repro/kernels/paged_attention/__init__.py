from . import ops, ref
from .kernel import paged_attention_kernel
from .ops import paged_decode_attention, resolve_paged_impl
