"""Reference oracle for the paged flash-decode kernel.

Gather-then-dense: assemble each slot's contiguous K/V view from the page
pool (trash-page rows explicitly zeroed — the same masking contract the
kernel's index map follows), then run the exact decode-plus-self-term math
of ``models.attention._decode_attn_plus_self``.  Kept standalone so the
kernels package never imports the models package.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -2.3819763e38


def gather_pages_ref(pool, pages):
    """pool: (P, KV, ps, D); pages: (B, n) int32 -> (B, KV, n*ps, D).
    Rows gathered from physical page 0 (the reserved trash page) are
    zeroed: its contents are scratch for free-slot writes and must never
    leak into a view."""
    g = pool[pages]                                  # (B, n, KV, ps, D)
    g = jnp.where((pages == 0)[:, :, None, None, None],
                  jnp.zeros((), pool.dtype), g)
    B, n, KV, ps, D = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, KV, n * ps, D)


def paged_decode_attention_ref(q, k_pool, v_pool, pages, kv_len, kt, vt, *,
                               window: int | None = None):
    """q: (B, 1, H, D); pools: (P, KV, ps, D); pages: (B, n) int32;
    kv_len: scalar or (B,) OLD cache lengths; kt/vt: (B, KV, 1, D) the
    current token's K/V (merged as a self term).  Returns (B, 1, H, D)."""
    k_cache = gather_pages_ref(k_pool, pages)
    v_cache = gather_pages_ref(v_pool, pages)
    B, _, H, D = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if k_cache.dtype != q.dtype:
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
        kt = kt.astype(q.dtype)
        vt = vt.astype(q.dtype)
    scale = 1.0 / math.sqrt(D)
    qf = (q.reshape(B, KV, G, D) * scale).astype(q.dtype)
    s_old = jnp.einsum("bkgd,bktd->bkgt", qf, k_cache,
                       preferred_element_type=jnp.float32)
    pos = jnp.arange(T)[None, :]
    kv_len = jnp.broadcast_to(jnp.reshape(jnp.asarray(kv_len), (-1,)), (B,))
    valid = pos < kv_len[:, None]
    if window is not None:
        valid = valid & (pos >= kv_len[:, None] + 1 - window)
    s_old = jnp.where(valid[:, None, None, :], s_old, NEG_INF)
    s_self = jnp.einsum("bkgd,bktd->bkgt", qf, kt,
                        preferred_element_type=jnp.float32)[..., 0]
    m_old = jnp.max(s_old, axis=-1)
    m = jnp.maximum(m_old, s_self)
    p_old = jnp.exp(s_old - m[..., None])
    p_self = jnp.exp(s_self - m)
    l = jnp.sum(p_old, axis=-1) + p_self
    out = jnp.einsum("bkgt,bktd->bkgd", p_old.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out + p_self[..., None] * vt[:, :, 0, :].astype(
        jnp.float32)[:, :, None, :]
    out = out / l[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)
