"""Shared platform/dispatch helpers for the kernel packages.

Every ``ops.py`` wrapper resolves ``impl="auto"`` through
:func:`resolve_impl`: the Pallas kernel on TPU, **interpret mode**
everywhere else.  Interpret mode runs the real kernel logic (BlockSpecs,
grid, accumulators) through the Pallas interpreter, so CPU CI exercises
the kernels instead of silently falling back to the jnp references — a
CPU-only bug in a BlockSpec now fails a test rather than hiding until the
first TPU run.  The jnp oracles remain reachable with ``impl="ref"`` (and
stay the default for the hot CPU *benchmark* paths, which opt in
explicitly, since interpret mode is orders of magnitude slower).
"""
from __future__ import annotations

import jax

__all__ = ["on_tpu", "resolve_impl"]

IMPLS = ("auto", "kernel", "interpret", "ref")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    """Map ``auto`` to the concrete impl for the current backend."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; pick from {IMPLS}")
    if impl != "auto":
        return impl
    return "kernel" if on_tpu() else "interpret"
