"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
platform-dispatching wrapper), ref.py (pure-jnp oracle used for allclose
validation and as the CPU fallback path).
"""
