"""jit'd wrapper with platform dispatch for the SSD intra-chunk kernel."""
import jax

from ..runtime import resolve_impl
from .kernel import ssd_intra_chunk_kernel
from .ref import ssd_intra_chunk_ref

_ref = jax.jit(ssd_intra_chunk_ref)


def ssd_intra_chunk(xh, dt, a, Bm, Cm, *, impl="auto"):
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref(xh, dt, a, Bm, Cm)
    return ssd_intra_chunk_kernel(xh, dt, a, Bm, Cm,
                                  interpret=(impl == "interpret"))
