"""jit'd wrapper with platform dispatch for the SSD intra-chunk kernel."""
import functools
import jax
import jax.numpy as jnp

from .kernel import ssd_intra_chunk_kernel
from .ref import ssd_intra_chunk_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def ssd_intra_chunk(xh, dt, a, Bm, Cm, *, impl="auto"):
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return ssd_intra_chunk_ref(xh, dt, a, Bm, Cm)
    return ssd_intra_chunk_kernel(xh, dt, a, Bm, Cm,
                                  interpret=(impl == "interpret"))
