"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch·chunk, head), the dual quadratic form of the SSD
algorithm — the compute hot-spot of Mamba-2:

    cum   = cumsum(a)                                  (Q,)
    L     = tril(exp(cum_t - cum_s))                   (Q, Q)
    M     = (C Bᵀ) ⊙ L                                 (Q, Q)
    y     = M (dt ⊙ x)                                 (Q, P)
    S_out = (B ⊙ dt ⊙ exp(cum_end - cum))ᵀ x           (N, P)

The inter-chunk state recurrence is a cheap sequential scan handled in jnp
by the caller (``repro.models.ssm.ssd_chunked``).  TPU adaptation: the
(Q, Q) decay/score matrix lives entirely in VMEM (Q = chunk ≤ 256 ⇒ 256 KB
fp32), and both heavy contractions are MXU matmuls.

Grid: (batch·chunks, heads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref):
    # x: (1,1,Q,P) dt/a: (1,1,Q,1) b/c: (1,Q,N)
    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q, 1)
    a = a_ref[0, 0].astype(jnp.float32)          # (Q, 1)
    B = b_ref[0].astype(jnp.float32)             # (Q, N)
    C = c_ref[0].astype(jnp.float32)             # (Q, N)
    Q = x.shape[0]

    cum = jnp.cumsum(a, axis=0)                  # (Q, 1)
    seg = cum - cum.reshape(1, Q)                # (Q, Q)  cum_t - cum_s
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    M = CB * L
    y = jax.lax.dot_general(M, x * dt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1:, :] - cum)       # (Q, 1)
    Bw = B * (decay_end * dt)                    # (Q, N)
    S = jax.lax.dot_general(Bw, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (N, P)
    s_ref[0, 0] = S.astype(s_ref.dtype)


def ssd_intra_chunk_kernel(xh, dt, a, Bm, Cm, *, interpret: bool = False):
    """xh: (BC, H, Q, P); dt/a: (BC, H, Q, 1); Bm/Cm: (BC, Q, N).

    Returns (y_intra (BC,H,Q,P), S_chunk (BC,H,N,P))."""
    BC, H, Q, P = xh.shape
    N = Bm.shape[-1]
    grid = (BC, H)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xh, dt, a, Bm, Cm)
