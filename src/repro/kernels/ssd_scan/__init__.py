from . import ops, ref
from .kernel import ssd_intra_chunk_kernel
from .ops import ssd_intra_chunk
