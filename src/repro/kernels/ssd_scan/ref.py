"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax
import jax.numpy as jnp


def ssd_intra_chunk_ref(xh, dt, a, Bm, Cm):
    """Same contract as the kernel: xh (BC,H,Q,P), dt/a (BC,H,Q,1),
    Bm/Cm (BC,Q,N) -> (y (BC,H,Q,P), S (BC,H,N,P))."""
    x = xh.astype(jnp.float32)
    dtf = dt[..., 0].astype(jnp.float32)          # (BC,H,Q)
    af = a[..., 0].astype(jnp.float32)
    B = Bm.astype(jnp.float32)
    C = Cm.astype(jnp.float32)
    Q = x.shape[2]
    cum = jnp.cumsum(af, axis=-1)                 # (BC,H,Q)
    seg = cum[..., :, None] - cum[..., None, :]   # (BC,H,Q,Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    CB = jnp.einsum("bqn,bsn->bqs", C, B)         # (BC,Q,Q)
    M = CB[:, None] * L                           # (BC,H,Q,Q)
    y = jnp.einsum("bhqs,bhs,bhsp->bhqp", M, dtf, x)
    decay_end = jnp.exp(cum[..., -1:] - cum)      # (BC,H,Q)
    S = jnp.einsum("bhq,bqn,bhqp->bhnp", decay_end * dtf, B, x)
    return y, S
