"""Public wrapper: platform dispatch + autotuned blocking for flash attn."""
import functools
import jax

from ..runtime import resolve_impl
from ..tuning import get_tuner
from .kernel import flash_attention_kernel
from .ref import attention_ref

DEFAULT_BLOCK = 512


# the (B,S,H,D)->(B,H,S,D) layout transposes live inside the jitted calls
# so eager invocations (e.g. the benchmark timing path) still get them
# fused instead of paying four materialised copies per call


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def _ref_call(q, k, v, *, causal, window):
    out = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def _kernel_call(q, k, v, *, causal, window, q_block, kv_block, interpret):
    out = flash_attention_kernel(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal=True, window=None, impl="auto",
                    q_block=None, kv_block=None):
    """q: (B, S, H, D); k/v: (B, T, KV, D) — model layout; returns same.

    impl: auto (kernel on TPU, interpret elsewhere) | kernel | interpret | ref
    Unset block sizes come from the autotune cache, else the 512 default.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref_call(q, k, v, causal=causal, window=window)
    if q_block is None or kv_block is None:
        cfg = get_tuner().lookup("flash_attention", q.shape, q.dtype,
                                 impl=impl) or {}
        q_block = q_block or cfg.get("q_block", DEFAULT_BLOCK)
        kv_block = kv_block or cfg.get("kv_block", DEFAULT_BLOCK)
    return _kernel_call(q, k, v, causal=causal, window=window,
                        q_block=q_block, kv_block=kv_block,
                        interpret=(impl == "interpret"))
