"""jit'd public wrapper: platform dispatch (TPU kernel / interpret / oracle)."""
import functools
import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "q_block", "kv_block"))
def flash_attention(q, k, v, *, causal=True, window=None, impl="auto",
                    q_block=512, kv_block=512):
    """q: (B, S, H, D); k/v: (B, T, KV, D) — model layout; returns same.

    impl: auto (kernel on TPU, oracle elsewhere) | kernel | interpret | ref
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "auto":
        impl = "kernel" if _on_tpu() else "ref"
    if impl == "ref":
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention_kernel(
            qt, kt, vt, causal=causal, window=window, q_block=q_block,
            kv_block=kv_block, interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)
