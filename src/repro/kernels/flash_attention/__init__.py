from . import ops, ref
from .kernel import flash_attention_kernel
from .ops import flash_attention
