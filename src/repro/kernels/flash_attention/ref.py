"""Pure-jnp oracle for the flash attention kernel."""
import math
import jax.numpy as jnp
import jax

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,H,S,D); k/v: (B,KV,T,D) -> (B,H,S,D). fp32 internally."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (qp >= kp)
    if window is not None:
        ok = ok & (qp - kp < window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v.astype(jnp.float32)).astype(q.dtype)
