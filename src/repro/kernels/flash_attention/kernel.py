"""Flash attention Pallas TPU kernel.

TPU adaptation of the (GPU) FlashAttention blocking (DESIGN.md §2): instead
of warp-level softmax reductions, tiles are sized for VMEM and the MXU —
(q_block × head_dim) and (kv_block × head_dim) operands with head_dim and
block sizes multiples of 128 where possible.  The kv axis is the innermost
*sequential* grid dimension; running max / denominator / accumulator live in
VMEM scratch across kv steps (the standard TPU flash schedule).

Supports GQA (q heads grouped over kv heads), causal masking and sliding
windows.  Grid: (batch, q_heads, q_blocks, kv_blocks).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 q_block: int, kv_block: int, kv_steps: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)

    # skip blocks that are fully masked (causal: kv entirely after q;
    # window: kv entirely before the window)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, ki * kv_block <= qi * q_block + q_block - 1)
    if window is not None:
        run = jnp.logical_and(
            run, (ki + 1) * kv_block - 1 >= qi * q_block - window + 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (qb, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (kb, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        ok = k_pos < seq_len
        if causal:
            ok = jnp.logical_and(ok, q_pos >= k_pos)
        if window is not None:
            ok = jnp.logical_and(ok, q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                                   # (qb, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                   # (kb, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(ki == kv_steps - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           q_block: int = 512, kv_block: int = 512,
                           interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KV, T, D).  Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    assert S % q_block == 0 and T % kv_block == 0, (S, q_block, T, kv_block)
    nq, nk = S // q_block, T // kv_block
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, kv_steps=nk, seq_len=T)

    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),   # running max
            pltpu.VMEM((q_block, 1), jnp.float32),   # running denominator
            pltpu.VMEM((q_block, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
