"""Fused RMSNorm wrapper: platform dispatch + autotuned row blocking."""
import functools
import jax
import jax.numpy as jnp

from ..runtime import resolve_impl
from ..tuning import get_tuner
from .kernel import rmsnorm_kernel
from .ref import rmsnorm_ref

DEFAULT_ROW_BLOCK = 256


@functools.partial(jax.jit, static_argnames=("eps",))
def _ref_call(x2d, gain, *, eps):
    return rmsnorm_ref(x2d, gain, eps=eps)


@functools.partial(jax.jit, static_argnames=("eps", "row_block", "interpret"))
def _kernel_call(x2d, gain, *, eps, row_block, interpret):
    # zero-pad ragged row counts up to the sublane multiple: padded rows
    # normalise to zero and are sliced off, so the kernel path serves every
    # shape instead of silently falling back to the oracle
    R = x2d.shape[0]
    pad = -R % 8
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    Rp = R + pad
    rb = min(row_block, Rp)
    while Rp % rb:
        rb //= 2
    out = rmsnorm_kernel(x2d, gain, eps=eps, row_block=rb,
                         interpret=interpret)
    return out[:R]


def rmsnorm(x, gain, *, eps=1e-6, impl="auto", row_block=None):
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    impl = resolve_impl(impl)
    if impl == "ref":
        out = _ref_call(x2d, gain, eps=eps)
    else:
        if row_block is None:
            cfg = get_tuner().lookup("rmsnorm", x2d.shape, x.dtype,
                                     impl=impl) or {}
            row_block = cfg.get("row_block", DEFAULT_ROW_BLOCK)
        out = _kernel_call(x2d, gain, eps=eps, row_block=row_block,
                           interpret=(impl == "interpret"))
    return out.reshape(shape)
