"""jit'd wrapper: fused RMSNorm on arbitrary-rank inputs."""
import functools
import jax
import jax.numpy as jnp

from .kernel import rmsnorm_kernel
from .ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x, gain, *, eps=1e-6, impl="auto"):
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref" or x2d.shape[0] % 8:
        out = rmsnorm_ref(x2d, gain, eps=eps)
    else:
        rb = 256
        while x2d.shape[0] % rb:
            rb //= 2
        out = rmsnorm_kernel(x2d, gain, eps=eps, row_block=rb,
                             interpret=(impl == "interpret"))
    return out.reshape(shape)
