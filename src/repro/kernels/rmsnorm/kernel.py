"""Fused RMSNorm Pallas TPU kernel.

One pass over a (rows × d) tile resident in VMEM: mean-of-squares reduction
and the scale multiply fused, fp32 accumulation, output in input dtype.
Grid over row blocks; d stays whole (d ≤ 16384 ⇒ ≤ 64 KB/row fp32, tile
rows chosen so the tile fits VMEM comfortably).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (rb, d)
    g = g_ref[...].astype(jnp.float32)            # (1, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


def rmsnorm_kernel(x2d, gain, *, eps: float = 1e-6, row_block: int = 256,
                   interpret: bool = False):
    """x2d: (R, d); gain: (d,) -> (R, d)."""
    R, d = x2d.shape
    rb = min(row_block, R)
    assert R % rb == 0, (R, rb)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda r: (r, 0)),
            pl.BlockSpec((1, d), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x2d.dtype),
        interpret=interpret,
    )(x2d, gain.reshape(1, d))
