"""Pure-jnp oracle for the fused RMSNorm kernel."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x2d, gain, *, eps=1e-6):
    xf = x2d.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * gain.astype(jnp.float32)).astype(x2d.dtype)
