from . import ops, ref
from .kernel import rmsnorm_kernel
from .ops import rmsnorm
