"""Mixture-of-Experts: top-k routing with capacity-bounded gather dispatch.

Design (DESIGN.md §4): tokens are the *chunks* of the paper's job model —
the router decides which "scheduler" (expert shard) owns each chunk, and the
dispatch/combine collectives are exactly the cross-scheduler result fetches
of the paper.

Implementation notes:

* gather-based dispatch (`jnp.take_along_axis`) — no one-hot dispatch
  einsums, so HLO FLOPs reflect real MLP work only (important for an honest
  compute roofline);
* capacity ``C = ceil(top_k * T / E * capacity_factor)`` per expert; tokens
  over capacity are dropped (their combine weight is zero) — standard
  GShard/Switch semantics;
* expert weights are laid out (E, d, ff): sharding rules put ``ff`` on the
  tensor axis (TP) always, and additionally shard E when it divides a mesh
  axis (EP);
* shared experts (qwen2-moe) are a plain dense MLP added to the routed
  output;
* aux load-balancing loss (Switch-style) is returned for the train loop.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical
from .config import ModelConfig
from .layers import _act, init_dense, init_mlp, apply_mlp, truncated_normal

__all__ = ["init_moe", "apply_moe"]


def init_moe(key, cfg: ModelConfig) -> dict:
    d, E = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(ff)
    p = {
        "router": init_dense(ks[0], d, E, cfg),
        "gate": truncated_normal(ks[1], (E, d, ff), scale_in, pdt),
        "up": truncated_normal(ks[2], (E, d, ff), scale_in, pdt),
        "down": truncated_normal(ks[3], (E, ff, d), scale_out, pdt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=ff * cfg.n_shared_experts,
                               gated=True)
        p["shared_gate"] = jnp.zeros((d, 1), pdt)  # qwen2-moe gated shared expert
    return p


def _top_k(logits, k):
    vals, idx = jax.lax.top_k(logits, k)
    return vals, idx


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array,
              *, capacity_factor: float | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    PER-ROW dispatch: every batch row routes/gathers/combines independently
    (Switch-style "groups"), so with the batch axis data-sharded the whole
    dispatch is shard-local — zero dispatch collectives.  (A global-token
    dispatch was tried and REFUTED: GSPMD replicated the (E,C,d) buffers or
    emitted all-gathers of the token stream — EXPERIMENTS.md §Perf.)
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cf = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    C = max(1, int(math.ceil(K * S / E * cf)))
    if S <= 64:
        # decode / tiny rows: dropless (serving must not drop tokens)
        C = S
    C = min(C, S)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                        # (B, S, E)
    gate_vals, expert_idx = _top_k(probs, K)                       # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)          # renorm (mixtral)

    # --- capacity-bounded position assignment (per row) ----------------------
    flat_expert = expert_idx.reshape(B, S * K)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)       # (B, S*K, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(
        pos_in_expert, flat_expert[..., None], axis=2)[..., 0]     # (B, S*K)
    keep = pos < C

    # --- gather tokens into (B, E, C, d) buffers ------------------------------
    slot = flat_expert * C + jnp.where(keep, pos, 0)
    scatter_idx = jnp.where(keep, slot, E * C)        # OOB when dropped
    token_id = jnp.broadcast_to(
        (jnp.arange(S * K, dtype=jnp.int32) // K)[None], (B, S * K))

    def row_table(si, ti):
        return jnp.full((E * C,), S, jnp.int32).at[si].set(ti, mode="drop")

    table = jax.vmap(row_table)(scatter_idx, token_id)             # (B, E*C)
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    gathered = jnp.take_along_axis(
        x_pad, table[..., None], axis=1).reshape(B, E, C, d)
    gathered = logical(gathered, "batch", None, None, None)

    # --- expert MLPs (batched over B rows and E experts) ----------------------
    g = jnp.einsum("becd,edf->becf", gathered.astype(cd), p["gate"].astype(cd))
    u = jnp.einsum("becd,edf->becf", gathered.astype(cd), p["up"].astype(cd))
    h = _act(cfg.act, g) * u
    h = logical(h, "batch", None, None, "d_ff")
    out_e = jnp.einsum("becf,efd->becd", h, p["down"].astype(cd))
    out_e = logical(out_e, "batch", None, None, None)

    # --- combine: each (token, slot) reads its expert buffer slot ------------
    flat_out = out_e.reshape(B, E * C, d)
    flat_out = jnp.concatenate(
        [flat_out, jnp.zeros((B, 1, d), flat_out.dtype)], axis=1)
    read = jnp.where(keep, slot, E * C)                            # dropped -> zero
    per_slot = jnp.take_along_axis(
        flat_out, read[..., None], axis=1).reshape(B, S, K, d)
    combined = jnp.sum(per_slot * gate_vals.astype(cd)[..., None], axis=2)

    # --- shared experts (qwen2-moe) ------------------------------------------
    if "shared" in p:
        sh = apply_mlp(cfg, p["shared"], x)
        sgate = jax.nn.sigmoid(jnp.einsum(
            "bsd,do->bso", x.astype(jnp.float32),
            p["shared_gate"].astype(jnp.float32)))
        combined = combined + sh * sgate.astype(cd)

    # --- Switch-style load-balance auxiliary loss ----------------------------
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E,
                                 dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    return combined, aux.astype(jnp.float32)
