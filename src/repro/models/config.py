"""Model configuration — one dataclass covers every assigned architecture.

Families: dense | moe | ssm | hybrid | encdec | vlm (vlm/audio reuse the
transformer backbone with a stub modality frontend, per the assignment).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "round_up"]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0             # 0 -> d_model // n_heads
    # --- attention ---
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False                 # chameleon stability trick
    sliding_window: int | None = None     # mixtral SWA
    local_global_ratio: int = 0           # gemma3: 5 local per 1 global
    local_window: int | None = None       # gemma3 local attention window
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                     # routed expert hidden width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2): one shared attention block every k SSM blocks ---
    hybrid_attn_every: int = 0
    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    decoder_len: int = 448                # whisper max target positions
    # --- misc arch ---
    act: str = "silu"                     # silu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    tie_embeddings: bool = True
    embed_scale: bool = False             # gemma: embeddings * sqrt(d_model)
    max_seq: int = 131_072
    # --- numerics / training ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""              # "" -> compute_dtype; f8 for big serving
    optimizer: str = "adamw"              # adamw | adafactor
    remat: str = "full"                   # none | full | save_dots
    scan_layers: bool = True
    # --- parallelism hints (see parallel/sharding.py) ---
    vocab_pad_multiple: int = 256
    attn_partitioning: str = "auto"       # auto | heads | context
    activation_seq_shard: bool = True     # False: Megatron-style replicated
                                          # activations between blocks (H2)
    grad_accum: int = 1

    # ---- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Total parameter count (embedding included once when tied)."""
        d, ff, V = self.d_model, self.d_ff, self.padded_vocab
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * H + 2 * d * hd * KV + hd * H * d       # q,k,v,o
        if self.qkv_bias:
            attn += hd * (H + 2 * KV)
        mlp_dense = 3 * d * ff                                  # gate,up,down
        per_layer = 0
        if self.family == "ssm":
            di, s = self.ssm_d_inner, self.ssm_state
            ng = max(1, self.ssm_n_heads // 8)  # group count heuristic unused
            # in_proj: d -> 2*di + 2*state + n_heads(dt); out_proj: di -> d
            per_layer = d * (2 * di + 2 * s + self.ssm_n_heads) + di * d \
                + self.ssm_conv * (di + 2 * s) + 2 * d
            total = self.n_layers * per_layer
        elif self.family == "hybrid":
            di, s = self.ssm_d_inner, self.ssm_state
            m_layer = d * (2 * di + 2 * s + self.ssm_n_heads) + di * d \
                + self.ssm_conv * (di + 2 * s) + 2 * d
            total = self.n_layers * m_layer + (attn + mlp_dense + 2 * d)
        elif self.is_moe:
            routed = 3 * d * self.moe_d_ff * self.n_experts if self.moe_d_ff \
                else 3 * d * self.d_ff * self.n_experts
            shared = 3 * d * (self.moe_d_ff * self.n_shared_experts) \
                if self.n_shared_experts else 0
            router = d * self.n_experts
            per_layer = attn + routed + shared + router + 2 * d
            total = self.n_layers * per_layer
        else:
            per_layer = attn + mlp_dense + 2 * d
            total = self.n_layers * per_layer
            if self.family == "encdec":
                # encoder layers + decoder cross-attention
                total += self.n_encoder_layers * (attn + mlp_dense + 2 * d)
                total += self.n_layers * (attn + d)
        total += V * d                                  # embedding
        if not self.tie_embeddings:
            total += V * d
        total += d                                      # final norm
        return int(total)

    def active_params(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        ew = self.moe_d_ff or self.d_ff
        dead = 3 * d * ew * (self.n_experts - self.top_k) * self.n_layers
        return self.n_params() - int(dead)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
