"""Basic layers: norms, MLPs, embeddings, RoPE.  Pure-functional (dict
params), no framework dependency; sharding is applied by annotation from
``repro.parallel.sharding``."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = [
    "init_norm", "apply_norm", "init_mlp", "apply_mlp", "init_embedding",
    "embed", "unembed", "rope_freqs", "apply_rope", "init_dense", "dense",
    "truncated_normal",
]


def truncated_normal(key, shape, scale: float, dtype) -> jax.Array:
    """He/LeCun-style truncated-normal init (MaxText convention)."""
    stddev = scale / 0.87962566103423978
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


# -- norms ---------------------------------------------------------------------


def init_norm(cfg: ModelConfig, shape_d: int | None = None) -> dict:
    d = shape_d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array, *, eps: float = 1e-6,
               use_kernel: bool = False) -> jax.Array:
    """RMSNorm / LayerNorm in fp32 accumulations, output in x.dtype."""
    if use_kernel and cfg.norm == "rmsnorm":
        from repro.kernels.rmsnorm import ops as rms_ops
        return rms_ops.rmsnorm(x, p["scale"].astype(jnp.float32), eps=eps)
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) \
            * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# -- dense / MLP -----------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, cfg: ModelConfig, *,
               bias: bool = False, scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), scale, jnp.dtype(cfg.param_dtype))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.dtype(cfg.param_dtype))
    return p


def dense(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    out = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        out = out + p["b"].astype(compute_dtype)
    return out


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {name}")


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, *,
             gated: bool | None = None) -> dict:
    """Gated (SwiGLU-style) or plain 2-layer MLP."""
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    gated = gated if gated is not None else (cfg.act == "silu")
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d, ff, cfg),
         "down": init_dense(ks[1], ff, d, cfg, scale=1.0 / math.sqrt(ff))}
    if gated:
        p["gate"] = init_dense(ks[2], d, ff, cfg)
    return p


def apply_mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    up = dense(p["up"], x, cd)
    if "gate" in p:
        h = _act(cfg.act, dense(p["gate"], x, cd)) * up
    else:
        h = _act(cfg.act, up)
    return dense(p["down"], h, cd)


# -- embeddings -------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig) -> dict:
    V, d = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"table": truncated_normal(ks[0], (V, d), 1.0, jnp.dtype(cfg.param_dtype))}
    if not cfg.tie_embeddings:
        p["unembed"] = truncated_normal(ks[1], (V, d), 1.0 / math.sqrt(d),
                                        jnp.dtype(cfg.param_dtype))
    return p


def embed(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(p["table"].astype(cd), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    return x


def unembed(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    table = p.get("unembed", p["table"])
    return x.astype(cd) @ table.astype(cd).T


# -- RoPE -------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, positions: jax.Array, hd: int | None = None,
               theta: float | None = None):
    """Returns (sin, cos) of shape positions.shape + (hd/2,), fp32."""
    hd = hd or cfg.hd
    theta = theta or cfg.rope_theta
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., seq, heads, hd); sin/cos: (..., seq, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)
