"""Attention: GQA + RoPE + causal/sliding/local-global masks.

Three execution paths, all numerically equivalent (tested against each
other and against the Pallas kernel oracle):

* ``dense``  — materialise scores; used for short sequences and as oracle.
* ``tiled``  — flash-style online-softmax over KV tiles (pure jnp, scan);
  the *lowering path* for long sequences so the compiled HLO never
  materialises an S×S tensor — this keeps the dry-run memory roofline
  honest on CPU, and is also what XLA:TPU receives when the Pallas kernel
  is disabled.
* ``pallas`` — the TPU kernel (``repro.kernels.flash_attention``), selected
  on TPU platforms or when forced; validated in interpret mode on CPU.

Decode (single new token vs. a long KV cache) is a separate einsum path:
it is memory-bound, and with the KV sequence axis sharded over the mesh the
softmax reductions lower to the all-reduce pattern of distributed
flash-decoding (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical

from .config import ModelConfig
from .layers import apply_norm, apply_rope, dense, init_dense, init_norm, rope_freqs

__all__ = ["init_attention", "attention", "decode_attention", "KVCache",
           "gather_pages"]

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "q": init_dense(ks[0], d, H * hd, cfg, bias=cfg.qkv_bias),
        "k": init_dense(ks[1], d, KV * hd, cfg, bias=cfg.qkv_bias),
        "v": init_dense(ks[2], d, KV * hd, cfg, bias=cfg.qkv_bias),
        "o": init_dense(ks[3], H * hd, d, cfg, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(cfg, hd)
        p["k_norm"] = init_norm(cfg, hd)
    return p


# ---------------------------------------------------------------------------
# Mask helpers
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int | None, dtype):
    """(q, k) additive bias: 0 where attendable, NEG_INF elsewhere."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), jnp.bool_)
    rel = q_pos[:, None] - k_pos[None, :]
    if causal:
        ok = ok & (rel >= 0)
    if window is not None:
        ok = ok & (rel < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


# ---------------------------------------------------------------------------
# Core attention paths (q: B,S,H,D  k/v: B,T,KV,D)
# ---------------------------------------------------------------------------


def _dense_attn(q, k, v, q_pos, k_pos, *, causal, window):
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(D))
    qg = qf.reshape(B, S, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    scores = scores + _mask_bias(q_pos, k_pos, causal=causal, window=window,
                                 dtype=scores.dtype)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def _tiled_attn(q, k, v, q_pos, k_pos, *, causal, window,
                q_tile: int = 1024, kv_tile: int = 1024):
    """Flash-style: online softmax over KV tiles; python loop over q tiles
    (static triangular schedule — fully-masked tiles are never emitted into
    the HLO), ``lax.scan`` over kv tiles inside."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_tile = min(q_tile, S)
    kv_tile = min(kv_tile, T)
    # pad to tile multiples
    Sp, Tp = -(-S // q_tile) * q_tile, -(-T // kv_tile) * kv_tile
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, Sp - S), constant_values=-1)       # padded q: masked rows
    kpos = jnp.pad(k_pos, (0, Tp - T), constant_values=2**30)    # padded k: unattendable
    nq, nk = Sp // q_tile, Tp // kv_tile
    kp = kp.reshape(B, nk, kv_tile, KV, D)
    vp = vp.reshape(B, nk, kv_tile, KV, D)
    kpos_t = kpos.reshape(nk, kv_tile)
    scale = 1.0 / math.sqrt(D)

    outs = []
    for i in range(nq):
        qi = qp[:, i * q_tile:(i + 1) * q_tile].astype(jnp.float32) * scale
        qi = qi.reshape(B, q_tile, KV, G, D)
        qpos_i = qpos[i * q_tile:(i + 1) * q_tile]
        # causal: kv tiles strictly after this q tile can never be attended
        hi = nk if not causal else -(-((i + 1) * q_tile) // kv_tile)
        # sliding window: tiles entirely before the window start are masked
        lo = 0
        if window is not None and causal:
            lo = max(0, (i * q_tile - window - kv_tile + 1) // kv_tile)

        def step(carry, xs):
            m, l, acc = carry
            kj, vj, kpos_j = xs
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj.astype(jnp.float32))
            s = s + _mask_bias(qpos_i, kpos_j, causal=causal, window=window,
                               dtype=s.dtype)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_tile), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_tile), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_tile, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kp[:, lo:hi].swapaxes(0, 1), vp[:, lo:hi].swapaxes(0, 1),
             kpos_t[lo:hi]))
        o = acc / jnp.maximum(l, 1e-37)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, q_tile, H, D))
    out = jnp.concatenate(outs, axis=1)[:, :S]
    return out.astype(q.dtype)


def gather_pages(pool, page_table):
    """Assemble per-slot contiguous KV views from a shared page pool.

    pool: (P, KV, page_size, D) — one physical page pool for one layer — or
    (G, P, KV, page_size, D) group-stacked (ONE gather covers all scanned
    layers of a pattern: the gather runs outside the layer scan, not once
    per iteration); page_table: (B, n_pages) int32 — logical page i of slot
    b lives in physical page ``page_table[b, i]``.  Returns
    ([G,] B, KV, n_pages*page_size, D), the same layout dense caches use, so
    every attention path downstream is layout-agnostic.  The gather
    materialises the view (the TPU kernel route indexes pages inside the
    kernel instead); positions past a slot's ``kv_len`` may contain stale
    data from freed pages — they are masked to NEG_INF before the softmax
    exactly like the zero tail of a dense cache, so results are unaffected.

    Trash-page contract (shared with the paged_attention kernel's index
    map): table entry 0 is the engine's reserved trash page — free slots
    and the not-yet-written tail of a mid-prefill slot point there, and
    mid-prefill chunk writes land in it, so its CONTENTS are arbitrary
    concurrent garbage.  Rows gathered from page 0 are zeroed here rather
    than trusted to the kv_len mask alone: a mid-prefill slot's kv_len
    covers positions whose pages are still 0, and zeros reproduce the
    dense cache's zero tail bit-for-bit (exp(0-m) terms in the softmax
    denominator and 0·v in the numerator), where garbage would not.
    """
    if pool.ndim == 5:
        g = pool[:, page_table]                  # (G, B, n, KV, ps, D)
        g = jnp.where((page_table == 0)[None, :, :, None, None, None],
                      jnp.zeros((), g.dtype), g)
        G, B, n, KV, ps, D = g.shape
        out = g.transpose(0, 1, 3, 2, 4, 5).reshape(G, B, KV, n * ps, D)
        return logical(out, None, "slots", "kv_heads", None, None)
    g = pool[page_table]                         # (B, n, KV, ps, D)
    g = jnp.where((page_table == 0)[:, :, None, None, None],
                  jnp.zeros((), g.dtype), g)
    B, n, KV, ps, D = g.shape
    out = g.transpose(0, 2, 1, 3, 4).reshape(B, KV, n * ps, D)
    return logical(out, "slots", "kv_heads", None, None)


def _chunk_attn_with_cache(q, k_cache, v_cache, start, kt, vt, *,
                           window: int | None = None):
    """Chunked-prefill attention: a prompt chunk at positions
    ``start .. start+C-1`` attends over the already-written cache entries
    (positions < start) plus itself (causal within the chunk) — the S>1
    generalisation of ``_decode_attn_plus_self``.  The chunk's own K/V enter
    through a separate score block so the cache write stays a pure delta.

    q: (B, C, H, D); k_cache/v_cache: (B, KV, T, D) views (dense buffers or
    gathered pages); kt/vt: (B, KV, C, D).  Scores are materialised
    (C × (T+C)) — chunks are short by construction, so this never
    approaches the S×S blow-up the tiled path exists to avoid.

    The FIRST chunk of every prompt has ``start == 0`` — nothing in the
    cache to read — so the whole C×T cache-score block is skipped behind a
    ``lax.cond``: measured on CPU it is the dominant cost of a chunk call
    (the view is worst-case wide), and most calls are first chunks (every
    short prompt is a single chunk).
    """
    B, C, H, D = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if k_cache.dtype != q.dtype:   # f8-stored caches: cast the layer slice
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
        kt = kt.astype(q.dtype)
        vt = vt.astype(q.dtype)
    scale = 1.0 / math.sqrt(D)
    qf = (q.reshape(B, C, KV, G, D) * scale).astype(q.dtype)
    q_pos = start + jnp.arange(C)                                  # (C,)
    rel = q_pos[:, None] - q_pos[None, :]                          # (C, C)
    valid_self = rel >= 0
    if window is not None:
        valid_self = valid_self & (rel < window)

    def with_cache(_):
        t_pos = jnp.arange(T)
        s_old = jnp.einsum("bckgd,bktd->bkgct", qf, k_cache,
                           preferred_element_type=jnp.float32)
        valid_old = t_pos[None, :] < start                         # (1, T)
        if window is not None:
            valid_old = valid_old & (q_pos[:, None] - t_pos[None, :] < window)
        s_old = jnp.where(valid_old[None, None, None], s_old, NEG_INF)
        s_self = jnp.einsum("bckgd,bksd->bkgcs", qf, kt,
                            preferred_element_type=jnp.float32)
        s_self = jnp.where(valid_self[None, None, None], s_self, NEG_INF)
        s = jnp.concatenate([s_old, s_self], axis=-1)              # (.., T+C)
        w = jax.nn.softmax(s, axis=-1)
        w_old, w_self = w[..., :T], w[..., T:]
        out = jnp.einsum("bkgct,bktd->bckgd", w_old.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
        out = out + jnp.einsum("bkgcs,bksd->bckgd", w_self.astype(vt.dtype),
                               vt, preferred_element_type=jnp.float32)
        return out.reshape(B, C, H, D).astype(q.dtype)

    def first_chunk(_):
        return _dense_attn(q, kt.swapaxes(1, 2), vt.swapaxes(1, 2),
                           q_pos, q_pos, causal=True, window=window)

    return jax.lax.cond(jnp.asarray(start) > 0, with_cache, first_chunk,
                        None)


def decode_attention(q, k_cache, v_cache, kv_len, *, window: int | None = None):
    """One-token attention against a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, KV, T, D) — heads-major layout so both dots
    contract the trailing dims without transpose copies; kv_len: scalar or
    (B,) — number of valid cache entries.  The dots consume the bf16 cache
    directly with fp32 accumulation (no materialised fp32 cast — §Perf).
    Softmax over the (sharded) T axis lowers to max/sum all-reduces:
    distributed flash-decoding.
    """
    B, _, H, D = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if k_cache.dtype != q.dtype:   # f8-stored caches: cast the layer slice
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
    qf = (q.reshape(B, KV, G, D) * (1.0 / math.sqrt(D))).astype(q.dtype)
    s = jnp.einsum("bkgd,bktd->bkgt", qf, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(T)[None, :]
    valid = pos < jnp.reshape(jnp.asarray(kv_len), (-1, 1))
    if window is not None:
        valid = valid & (pos >= jnp.reshape(jnp.asarray(kv_len), (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def _decode_attn_plus_self(q, k_cache, v_cache, kv_len_old, kt, vt, *,
                           window: int | None = None):
    """Decode attention over the *old* cache entries plus the just-computed
    token's own K/V (kt/vt, (B,KV,1,D)) — so the cache write can happen
    outside, as a pure delta.  Numerically identical to writing first and
    attending over kv_len_old+1 entries."""
    B, _, H, D = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if k_cache.dtype != q.dtype:   # f8-stored caches: cast the layer slice
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
        kt = kt.astype(q.dtype)
        vt = vt.astype(q.dtype)
    scale = 1.0 / math.sqrt(D)
    qf = (q.reshape(B, KV, G, D) * scale).astype(q.dtype)
    s_old = jnp.einsum("bkgd,bktd->bkgt", qf, k_cache,
                       preferred_element_type=jnp.float32)
    pos = jnp.arange(T)[None, :]
    kv_len_new = jnp.reshape(kv_len_old, (-1, 1)) + 1
    valid = pos < jnp.reshape(kv_len_old, (-1, 1))
    if window is not None:
        valid = valid & (pos >= kv_len_new - window)
    s_old = jnp.where(valid[:, None, None, :], s_old, NEG_INF)
    s_self = jnp.einsum("bkgd,bktd->bkgt", qf, kt,
                        preferred_element_type=jnp.float32)[..., 0]  # (B,KV,G)
    # log-sum-exp merge of the self term — no concat along the (sharded) T
    # axis, so everything stays shard-local except the max/sum reductions
    m_old = jnp.max(s_old, axis=-1)
    m = jnp.maximum(m_old, s_self)
    p_old = jnp.exp(s_old - m[..., None])
    p_self = jnp.exp(s_self - m)                                   # (B,KV,G)
    l = jnp.sum(p_old, axis=-1) + p_self
    out = jnp.einsum("bkgt,bktd->bkgd", p_old.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out + p_self[..., None] * vt[:, :, 0, :].astype(
        jnp.float32)[:, :, None, :]
    out = out / l[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full layer
# ---------------------------------------------------------------------------


class KVCache:
    """Pytree-friendly KV cache for one attention layer.

    Layout (B, KV, T, D): heads-major so decode dots contract trailing dims
    (no transpose copies of multi-GiB caches — §Perf)."""

    @staticmethod
    def init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
        hd, KV = cfg.hd, cfg.n_kv_heads
        return {
            "k": jnp.zeros((batch, KV, max_len, hd), dtype),
            "v": jnp.zeros((batch, KV, max_len, hd), dtype),
        }


def attention(cfg: ModelConfig, p: dict, x, *, positions, kv_x=None,
              kv_positions=None, causal: bool = True,
              window: int | None = None, cache: dict | None = None,
              cache_len=None, impl: str = "auto",
              rope: bool | None = None, paged_impl: str = "ref",
              chunk_continue: bool = False) -> tuple[jax.Array, dict | None]:
    """Full attention layer: qkv proj -> rope -> core -> out proj.

    ``cache``/``cache_len``: decode mode — x is (B, 1, d); K/V for the new
    token are written at ``cache_len`` and attention runs against the cache.
    ``kv_x``: cross-attention (whisper decoder) — keys/values from encoder.
    ``chunk_continue``: S > 1 with a *live* cache — chunked prefill: the
    chunk attends over prior cache entries (< ``cache_len``) plus itself.
    Paged caches reach this layer in one of two forms: on the reference
    path the serving engine gathers per-slot views (``gather_pages``) into
    the dense (B, KV, T, D) layout before the block runs, so reads here are
    layout-agnostic; on the kernel path (``paged_impl`` in
    kernel/interpret, S == 1) the cache instead carries the raw pools plus
    the page table (``k_pool``/``v_pool``/``pages``) and the paged
    flash-decode kernel resolves pages inside its index map — no gather.
    Writes stay deltas either way.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rope = cfg.use_rope if rope is None else rope

    q = dense(p["q"], x, cd).reshape(B, S, H, hd)
    reuse_cached_kv = cache is not None and kv_x is not None
    if reuse_cached_kv:
        k = v = None  # cross-attention decode: encoder K/V already cached
    else:
        src = x if kv_x is None else kv_x
        k = dense(p["k"], src, cd).reshape(B, src.shape[1], KV, hd)
        v = dense(p["v"], src, cd).reshape(B, src.shape[1], KV, hd)

    if cfg.qk_norm:
        q = apply_norm(cfg, p["q_norm"], q)
        if k is not None:
            k = apply_norm(cfg, p["k_norm"], k)

    kv_pos = positions if kv_positions is None else kv_positions
    if rope:
        sin_q, cos_q = rope_freqs(cfg, positions, hd)
        q = apply_rope(q, sin_q, cos_q)
        if kv_x is None:
            sin_k, cos_k = rope_freqs(cfg, kv_pos, hd)
            k = apply_rope(k, sin_k, cos_k)

    new_cache = None
    if cache is not None:
        if kv_x is None:
            # DELTA cache contract (§Perf iter 4 — best measured variant):
            # return only this step's K/V; the caller writes them into the
            # cache buffer.  The written value is independent of the cache
            # read.  (Write-then-read through the stacked carry was tried
            # and REFUTED: +113% memory term — see EXPERIMENTS.md §Perf.)
            k_store = cache.get("k", cache.get("k_pool"))
            kt = k.swapaxes(1, 2).astype(k_store.dtype)      # (B,KV,S,D)
            vt = v.swapaxes(1, 2).astype(k_store.dtype)
            # delta marked by key STRUCTURE (k_delta/v_delta) so it survives
            # being scanned out as ys (a bool leaf would get stacked)
            new_cache = {"k_delta": kt, "v_delta": vt}
            if S == 1 and "k_pool" in cache:
                # paged kernel path: attend straight against the page pool
                # through the table — DESIGN.md §15
                from repro.kernels.paged_attention import ops as pa_ops
                out = pa_ops.paged_decode_attention(
                    q, cache["k_pool"], cache["v_pool"], cache["pages"],
                    jnp.asarray(cache_len), kt, vt, window=window,
                    impl=paged_impl)
            elif S == 1:
                out = _decode_attn_plus_self(
                    q, cache["k"], cache["v"], jnp.asarray(cache_len),
                    kt, vt, window=window)
            elif chunk_continue:
                out = _chunk_attn_with_cache(
                    q, cache["k"], cache["v"], jnp.asarray(cache_len), kt, vt,
                    window=window)
            else:
                # batched prefill: attend over the freshly computed local
                # K/V (the cache holds exactly these entries when starting
                # from empty) — no cache read at all
                q_pos = jnp.asarray(cache_len) + jnp.arange(S)
                k_pos = jnp.asarray(cache_len) + jnp.arange(S)
                if S * S <= 4096 * 4096 // 4:
                    out = _dense_attn(q, k, v, q_pos, k_pos,
                                      causal=True, window=window)
                else:
                    out = _tiled_attn(q, k, v, q_pos, k_pos,
                                      causal=True, window=window)
        else:
            out = decode_attention(q, cache["k"], cache["v"], cache_len,
                                   window=None)
            new_cache = cache
    else:
        q_pos = positions[0] if positions.ndim > 1 else positions
        k_pos = kv_pos[0] if kv_pos.ndim > 1 else kv_pos
        if impl == "pallas":
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
        elif S * k.shape[1] <= 4096 * 4096 // 4 or impl == "dense":
            out = _dense_attn(q, k, v, q_pos, k_pos, causal=causal, window=window)
        else:
            out = _tiled_attn(q, k, v, q_pos, k_pos, causal=causal, window=window)

    out = dense(p["o"], out.reshape(B, S, H * hd), cd)
    return out, new_cache
