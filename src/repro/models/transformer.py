"""Model composition: every assigned architecture as a pattern-scanned stack.

A model is ``(pattern, n_groups, tail)``: the *pattern* is a short list of
block kinds (e.g. gemma3's ``5×local + 1×global``), scanned ``n_groups``
times with stacked parameters, plus an unscanned *tail* (remainder layers).
This keeps the compiled HLO small (one pattern body) while allowing
heterogeneous stacks — and gives the HLO analyzer a single while-loop whose
trip count is ``n_groups`` (DESIGN.md §5).

Block kinds:
  dense   — attention + gated MLP            (qwen2, deepseek, llama3, chameleon)
  local   — sliding-window attention + MLP   (gemma3 local layers)
  global  — full attention + MLP             (gemma3 global layers)
  moe     — attention + mixture-of-experts   (mixtral [SWA], qwen2-moe)
  ssm     — Mamba-2 mixer                    (mamba2, zamba2 backbone)
  shared  — zamba2's *shared* attention+MLP block (one parameter set,
            invoked at every occurrence)
  enc/dec — whisper encoder / decoder (cross-attention) blocks
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.parallel.sharding import current_rules, logical
from .attention import KVCache, attention, gather_pages, init_attention
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, embed, init_embedding, init_mlp,
                     init_norm, truncated_normal, unembed)
from .moe import apply_moe, init_moe
from .ssm import SSMCache, apply_mamba2, init_mamba2, mamba2_decode_step

__all__ = [
    "layer_plan", "init_params", "forward", "loss_fn", "init_cache",
    "init_paged_cache", "prefill", "decode_step", "chunk_prefill_step",
    "param_count",
]


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    pattern: tuple[str, ...]
    n_groups: int
    tail: tuple[str, ...]
    enc_pattern: tuple[str, ...] = ()
    enc_groups: int = 0

    @property
    def scan_trips(self) -> int:
        return self.n_groups


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    L = cfg.n_layers
    if cfg.family == "encdec":
        return LayerPlan(pattern=("dec",), n_groups=L, tail=(),
                         enc_pattern=("enc",), enc_groups=cfg.n_encoder_layers)
    if cfg.family == "ssm":
        return LayerPlan(pattern=("ssm",), n_groups=L, tail=())
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        g, r = divmod(L, k)
        return LayerPlan(pattern=("ssm",) * k + ("shared",), n_groups=g,
                         tail=("ssm",) * r)
    if cfg.local_global_ratio:
        k = cfg.local_global_ratio + 1
        g, r = divmod(L, k)
        return LayerPlan(pattern=("local",) * cfg.local_global_ratio + ("global",),
                         n_groups=g, tail=("local",) * r)
    if cfg.is_moe:
        return LayerPlan(pattern=("moe",), n_groups=L, tail=())
    return LayerPlan(pattern=("dense",), n_groups=L, tail=())


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _has_attn(kind: str) -> bool:
    return kind in ("dense", "local", "global", "moe", "shared", "enc", "dec")


def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 6)
    if kind == "ssm":
        return {"ln1": init_norm(cfg), "mixer": init_mamba2(ks[0], cfg)}
    p = {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
         "ln2": init_norm(cfg)}
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if kind == "dec":
        p["ln_cross"] = init_norm(cfg)
        p["cross"] = init_attention(ks[2], cfg, cross=True)
    return p


def _window_for(cfg: ModelConfig, kind: str) -> int | None:
    if kind == "local":
        return cfg.local_window
    if kind in ("dense", "moe", "global", "shared"):
        return cfg.sliding_window if kind in ("dense", "moe") else None
    return None


def apply_block(cfg: ModelConfig, kind: str, p: dict, x, *, positions,
                enc_out=None, cache=None, cache_len=None,
                impl: str = "auto", paged_impl: str = "ref",
                chunk_continue: bool = False, valid_len=None):
    """Returns (x, new_cache, aux_loss).

    ``chunk_continue``: S > 1 against a LIVE cache — chunked prefill: the
    block continues from the cache (attention over prior entries + itself;
    SSM from the cached conv tail + state) instead of starting fresh.
    ``valid_len``: true (unpadded) length of a bucketed prompt chunk.
    Paged serving engines pass attention caches either as pre-gathered
    per-slot VIEWS in the dense layout (reference path, see
    ``decode_step``) or — on the paged-kernel decode path — as raw pools
    plus the page table under ``k_pool``/``v_pool``/``pages``, which the
    attention layer hands to the paged flash-decode kernel.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if kind == "ssm":
        h = apply_norm(cfg, p["ln1"], x)
        if cache is not None and x.shape[1] == 1:
            out, new_cache = mamba2_decode_step(cfg, p["mixer"], h, cache)
        elif cache is not None and chunk_continue:
            # chunked prefill: continue the conv + SSD scan from the cache
            out, new_cache = apply_mamba2(cfg, p["mixer"], h, cache=cache,
                                          valid_len=valid_len,
                                          return_cache=True)
        elif cache is not None:
            # batched prefill: run the chunked scan, emit a decode cache
            out, new_cache = apply_mamba2(cfg, p["mixer"], h,
                                          valid_len=valid_len,
                                          return_cache=True)
        else:
            out = apply_mamba2(cfg, p["mixer"], h)
        return x + out, new_cache, aux

    causal = kind != "enc"
    window = _window_for(cfg, kind)
    h = apply_norm(cfg, p["ln1"], x)
    sa_cache = cache.get("self") if cache is not None else None
    out, new_sa = attention(cfg, p["attn"], h, positions=positions,
                            causal=causal, window=window, cache=sa_cache,
                            cache_len=cache_len, impl=impl,
                            paged_impl=paged_impl,
                            chunk_continue=chunk_continue,
                            rope=cfg.use_rope and kind != "enc" and kind != "dec")
    x = x + logical(out, "batch", "seq", "embed")

    if kind == "dec" and enc_out is not None:
        h = apply_norm(cfg, p["ln_cross"], x)
        enc_len = enc_out.shape[1]
        # cross K/V recomputed per call (cacheing them is a serving-engine
        # optimisation; see repro/serve/engine.py)
        out, _ = attention(cfg, p["cross"], h, kv_x=enc_out,
                           positions=positions,
                           kv_positions=jnp.arange(enc_len),
                           causal=False, rope=False)
        x = x + out

    h = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        out, aux = apply_moe(cfg, p["moe"], h)
    else:
        out = apply_mlp(cfg, p["mlp"], h)
    x = x + logical(out, "batch", "seq", "embed")

    if cache is not None and kind != "ssm":
        # return ONLY the update (deltas) — returning the old cache slices
        # would double-buffer them through the scan ys (§Perf)
        new_cache = {"self": new_sa} if new_sa is not None else {}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": init_embedding(keys[0], cfg)}

    def stacked(key, kind, n):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: init_block(k, cfg, kind))(ks)

    # scanned groups: one stacked param tree per pattern position
    gkeys = jax.random.split(keys[1], max(len(plan.pattern), 1))
    params["groups"] = [
        stacked(gkeys[i], kind, plan.n_groups) if kind != "shared" else {}
        for i, kind in enumerate(plan.pattern)
    ]
    if "shared" in plan.pattern:
        params["shared"] = init_block(keys[2], cfg, "shared")
    tkeys = jax.random.split(keys[3], max(len(plan.tail), 1))
    params["tail"] = [init_block(tkeys[i], cfg, kind)
                      for i, kind in enumerate(plan.tail)]
    params["norm_f"] = init_norm(cfg)

    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[4], 3)
        params["enc_groups"] = [stacked(ekeys[0], "enc", plan.enc_groups)]
        params["enc_norm_f"] = init_norm(cfg)
        params["enc_pos"] = truncated_normal(
            ekeys[1], (cfg.max_seq, cfg.d_model), 0.02,
            jnp.dtype(cfg.param_dtype))
        params["dec_pos"] = truncated_normal(
            ekeys[2], (cfg.max_seq, cfg.d_model), 0.02,
            jnp.dtype(cfg.param_dtype))
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "save_dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _constrain_block_params(p):
    """Re-assert the (FSDP/TP) sharding of per-layer params sliced out of the
    scan xs.  Without this GSPMD may all-gather the whole stacked weight
    array outside the loop ("wide" while), keeping every layer's gathered
    weights live simultaneously — §Perf iteration H7."""
    if current_rules() is None or p is None:
        return p
    from repro.parallel.partition import axes_for_path
    flat, treedef = jax.tree_util.tree_flatten_with_path(p)
    out = []
    for path, leaf in flat:
        keys = tuple(str(getattr(x, "key", getattr(x, "idx", x))) for x in path)
        axes = axes_for_path(keys, getattr(leaf, "ndim", 0))
        out.append(logical(leaf, *axes) if hasattr(leaf, "ndim") else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _run_stack(cfg: ModelConfig, plan_pattern, groups, tail_kinds, tail,
               shared, x, positions, *, enc_out=None, impl="auto"):
    """Scan the pattern over groups, then the tail. Returns (x, aux)."""

    def group_body(carry, gparams):
        h, aux = carry
        for i, kind in enumerate(plan_pattern):
            # (H7 constraint on sliced params is applied only on decode
            # paths; in training it triggered GSPMD replicate-then-partition
            # weight all-reduces — §Perf)
            p = shared if kind == "shared" else gparams[i]
            h, _, a = apply_block(cfg, kind, p, h, positions=positions,
                                  enc_out=enc_out, impl=impl)
            aux = aux + a
        h = logical(h, "batch", "seq", "embed")
        return (h, aux), None

    aux0 = jnp.zeros((), jnp.float32)
    if groups and jax.tree.leaves(groups):
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        if cfg.scan_layers and n_groups > 1:
            body = _remat(cfg, group_body)
            (x, aux0), _ = jax.lax.scan(body, (x, aux0), tuple(groups))
        else:
            for g in range(n_groups):
                gp = jax.tree.map(lambda t: t[g], tuple(groups))
                (x, aux0), _ = _remat(cfg, group_body)((x, aux0), gp)
    for i, kind in enumerate(tail_kinds):
        x, _, a = apply_block(cfg, kind, tail[i], x, positions=positions,
                              enc_out=enc_out, impl=impl)
        aux0 = aux0 + a
    return x, aux0


def forward(cfg: ModelConfig, params: dict, *, tokens=None, embeds=None,
            positions=None, enc_embeds=None, impl: str = "auto"):
    """Full-sequence forward (train / prefill).  Returns (logits, aux).

    ``tokens``: (B, S) int32 — LM input.
    ``embeds``: (B, S, d) — precomputed embeddings (stub modality frontend).
    ``enc_embeds``: (B, T, d) — encoder input for encdec (whisper frames).
    """
    plan = layer_plan(cfg)
    if embeds is None:
        x = embed(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    x = logical(x, "batch", "seq", "embed")

    enc_out = None
    if cfg.family == "encdec":
        assert enc_embeds is not None, "encdec model needs enc_embeds"
        e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
        e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
        e = logical(e, "batch", "seq", "embed")
        e, _ = _run_stack(cfg, plan.enc_pattern, tuple(params["enc_groups"]),
                          (), (), None, e, jnp.arange(e.shape[1]), impl=impl)
        enc_out = apply_norm(cfg, params["enc_norm_f"], e)
        x = x + params["dec_pos"][positions].astype(x.dtype)

    x, aux = _run_stack(cfg, plan.pattern, tuple(params["groups"]),
                        plan.tail, params["tail"], params.get("shared"),
                        x, positions, enc_out=enc_out, impl=impl)
    x = apply_norm(cfg, params["norm_f"], x)
    logits = unembed(cfg, params["embed"], x)
    logits = logical(logits, "batch", None, "vocab")
    return logits, aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, impl="auto"):
    """Next-token cross-entropy (+ MoE aux).  batch: tokens, labels, mask
    (+ enc_embeds / embeds for stub-frontend families)."""
    logits, aux = forward(cfg, params,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          enc_embeds=batch.get("enc_embeds"),
                          impl=impl)
    labels = batch["labels"]
    mask = batch.get("mask")
    logits = logits.astype(jnp.float32)
    # mask out vocab padding
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e9, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(pad)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype, enc_len: int = 0):
    if kind == "ssm":
        return SSMCache.init(cfg, batch)
    return {"self": KVCache.init(cfg, batch, max_len, dtype)}


def _is_delta(upd) -> bool:
    return isinstance(upd, dict) and "k_delta" in upd


def _write_kv(buf, delta, pos, *, batch_axis: int):
    """Write a K/V delta into a cache buffer at the token position.

    ``pos`` scalar — one dynamic-update-slice for the whole batch (training /
    uniform decode).  ``pos`` (B,) — per-slot positions (continuous batching:
    slots inserted at different times sit at different lengths), written as a
    vmap over the batch axis, one slice per slot.
    """
    idx = jnp.asarray(pos)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice(
            buf, delta, (0,) * (buf.ndim - 2) + (idx, 0))
    per_row = lambda c, d, i: jax.lax.dynamic_update_slice(
        c, d, (0,) * (c.ndim - 2) + (i, 0))
    return jax.vmap(per_row, in_axes=(batch_axis, batch_axis, 0),
                    out_axes=batch_axis)(buf, delta, idx)


def _write_kv_paged(pool, delta, pos, pages, page_size, *, stacked: bool):
    """Scatter one decode step's K/V delta into a shared page pool.

    pool: (G, P, KV, ps, D) stacked or (P, KV, ps, D) unstacked; delta the
    matching (…, B, KV, 1, D); pos (B,) per-slot token positions; pages
    (B, n_pages) page table.  Logical position ``pos`` of slot ``b`` lives
    in physical page ``pages[b, pos // ps]`` at offset ``pos % ps``.  Slots
    never share pages (allocator invariant), so the scatter indices are
    unique across live slots; free slots all map to the reserved trash page,
    whose contents are never validly read.
    """
    pos = jnp.broadcast_to(jnp.asarray(pos), (pages.shape[0],))
    B = pages.shape[0]
    pid = jnp.take_along_axis(pages, (pos // page_size)[:, None],
                              axis=1)[:, 0]                       # (B,)
    off = pos % page_size
    val = delta.astype(pool.dtype)
    if stacked:
        # (G, B, KV, 1, D) -> (B, G, KV, D); advanced indices (pid, off)
        # are separated by slices, so the batch axis moves to the front
        val = jnp.moveaxis(val[:, :, :, 0, :], 1, 0)
        out = pool.at[:, pid, :, off, :].set(val)
        return logical(out, None, "pages", "kv_heads", None, None)
    out = pool.at[pid, :, off, :].set(val[:, :, 0, :])
    return logical(out, "pages", "kv_heads", None, None)


def _page_view_block(block_cache, pages):
    """Replace a block's attention page pools by per-slot gathered views in
    the dense (…, B, KV, T, D) layout; non-attention caches (SSM state) and
    dense caches pass through untouched."""
    if pages is None or not (isinstance(block_cache, dict)
                             and "self" in block_cache):
        return block_cache
    return {**block_cache,
            "self": {kk: gather_pages(block_cache["self"][kk], pages)
                     for kk in ("k", "v")}}


def _page_views(block_caches, pages):
    return tuple(_page_view_block(bc, pages) for bc in block_caches)


def _page_pool_view_block(block_cache, pages, *, stacked: bool):
    """Kernel-path counterpart of ``_page_view_block``: instead of
    materialising the gathered dense view, pass the raw pools through with
    the page table alongside (``k_pool``/``v_pool``/``pages``) so the paged
    flash-decode kernel resolves pages inside its BlockSpec index map.  For
    stacked (group-scanned) caches the table is broadcast over the layer
    axis so the scan slices it back out per layer — a (G, B, n_pages) int32
    broadcast, trivially small next to the gather it replaces."""
    if pages is None or not (isinstance(block_cache, dict)
                             and "self" in block_cache):
        return block_cache
    sp = block_cache["self"]
    pg = pages
    if stacked:
        pg = jnp.broadcast_to(pages, (sp["k"].shape[0],) + pages.shape)
    return {**block_cache,
            "self": {"k_pool": sp["k"], "v_pool": sp["v"], "pages": pg}}


def _apply_cache_update(old_layer_cache, upd, pos, *, pages=None,
                        page_size=None, update_mask=None):
    """Apply a block's cache update to an UNSTACKED layer cache."""
    if upd is None:
        return old_layer_cache
    out = {}
    for key, val in upd.items():
        if key == "self" and _is_delta(val):
            if pages is not None:
                out["self"] = {
                    kk: _write_kv_paged(old_layer_cache["self"][kk],
                                        val[f"{kk}_delta"], pos, pages,
                                        page_size, stacked=False)
                    for kk in ("k", "v")}
            else:
                out["self"] = {
                    kk: _write_kv(old_layer_cache["self"][kk],
                                  val[f"{kk}_delta"], pos, batch_axis=0)
                    for kk in ("k", "v")}
        else:
            val = val.astype(old_layer_cache[key].dtype)
            if update_mask is not None:
                m = update_mask.reshape((-1,) + (1,) * (val.ndim - 1))
                val = jnp.where(m, val, old_layer_cache[key])
            out[key] = val
    return out


def _apply_stacked_updates(stacked, updates, pos, *, pages=None,
                           page_size=None, update_mask=None):
    """Apply scan-collected per-layer updates to a stacked cache.

    KV deltas (G,B,KV,S,D) are written with ONE dynamic-update-slice at the
    token position (or one per slot for per-slot ``pos`` vectors; one
    scatter through the page table for paged pools); SSM states come out of
    the scan already whole, stacked — they simply replace the old buffers.

    ``update_mask`` (B,) bool: slots whose NON-delta state (SSM conv tail +
    SSD state) may advance.  Attention K/V of masked-out slots is already
    harmless (paged decode writes them to the trash page), but SSM state is
    a dense per-slot buffer with no page indirection — a mid-prefill slot's
    carried state must not be advanced by interleaved decode steps of the
    live batch (DESIGN.md §9)."""
    if updates is None:
        return stacked
    new = dict(stacked)
    for key, val in updates.items():
        if key == "self" and _is_delta(val):
            if pages is not None:
                new["self"] = {
                    kk: _write_kv_paged(stacked["self"][kk],
                                        val[f"{kk}_delta"], pos, pages,
                                        page_size, stacked=True)
                    for kk in ("k", "v")}
            else:
                new["self"] = {
                    kk: _write_kv(stacked["self"][kk],
                                  val[f"{kk}_delta"].astype(stacked["self"][kk].dtype),
                                  pos, batch_axis=1)
                    for kk in ("k", "v")}
        else:
            val = val.astype(stacked[key].dtype)
            if update_mask is not None:
                # stacked leaves are (G, B, ...): batch is axis 1
                m = update_mask.reshape((1, -1) + (1,) * (val.ndim - 2))
                val = jnp.where(m, val, stacked[key])
            new[key] = val
    return new


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, enc_len: int = 0):
    plan = layer_plan(cfg)
    dtype = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)

    def stacked_cache(kind):
        one = lambda: _init_block_cache(cfg, kind, batch, max_len, dtype, enc_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_groups,) + x.shape).copy()
            if plan.n_groups > 1 else x[None], one())

    cache = {
        "groups": [stacked_cache(kind) for kind in plan.pattern],
        "tail": [_init_block_cache(cfg, kind, batch, max_len, dtype, enc_len)
                 for kind in plan.tail],
        "len": jnp.zeros((), jnp.int32),
    }
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, *, num_pages: int,
                     page_size: int, enc_len: int = 0):
    """Paged serving cache: attention layers hold ONE shared page pool
    (P, KV, page_size, D) per k/v instead of per-slot (B, KV, max_len, D)
    buffers — memory scales with pages in use, not batch × worst-case
    request.  SSM states are O(1) per slot and stay dense (B, …).  The
    page table mapping slots to pool pages lives host-side in the serving
    engine and is passed into each jitted program (DESIGN.md §9)."""
    plan = layer_plan(cfg)
    dtype = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)
    if cfg.family == "encdec":
        raise NotImplementedError("paged KV for encdec (cross-attention "
                                  "buffers) is not implemented")

    def block_cache(kind):
        if kind == "ssm":
            return SSMCache.init(cfg, batch)
        return {"self": {
            "k": jnp.zeros((num_pages, cfg.n_kv_heads, page_size, cfg.hd),
                           dtype),
            "v": jnp.zeros((num_pages, cfg.n_kv_heads, page_size, cfg.hd),
                           dtype),
        }}

    def stacked_cache(kind):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_groups,) + x.shape).copy()
            if plan.n_groups > 1 else x[None], block_cache(kind))

    return {
        "groups": [stacked_cache(kind) for kind in plan.pattern],
        "tail": [block_cache(kind) for kind in plan.tail],
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens, *,
                enc_out=None, embeds=None, impl: str = "auto",
                pages=None, page_size: int | None = None, valid_len=None,
                update_mask=None, paged_impl: str = "ref"):
    """One cache-extending step.  tokens: (B, S) int32 (or embeds (B,S,d));
    S == 1 is decode, S > 1 is batched prefill (cache must be fresh).
    ``pages``/``page_size``: the cache's attention buffers are shared page
    pools; reads gather per-slot views through the page table, writes
    scatter through it.  ``paged_impl``: resolved through
    ``resolve_paged_impl`` — on ``kernel``/``interpret`` the S == 1 decode
    path skips the gather entirely and the paged flash-decode kernel
    indexes the pools through the page table (DESIGN.md §15); ``ref``
    (and ``auto`` off-TPU) keeps ``gather_pages`` as the oracle path.
    ``valid_len``: true prompt length of a bucketed
    (right-padded) prefill — masks SSM state updates past the true end.
    ``update_mask`` (B,) bool: freeze the per-slot SSM state of masked-out
    slots (mid-prefill slots under chunk interleaving).
    Returns (logits (B, S, V), new_cache)."""
    plan = layer_plan(cfg)
    if embeds is None:
        x = embed(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    S = x.shape[1]
    pos = jnp.asarray(cache["len"])
    # ``len`` may be a scalar (uniform batch) or (B,) vector (per-slot
    # lengths under continuous batching — slots inserted at different times
    # sit at different positions).  Vector lengths are decode-only: batched
    # prefill always starts from a fresh (scalar, zero-length) cache.
    if pos.ndim == 1 and S > 1:
        raise ValueError("per-slot cache lengths only support single-token "
                         "decode (S == 1); prefill from a fresh cache")
    if pos.ndim == 1:
        positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)   # (B, S)
    else:
        positions = pos + jnp.arange(S, dtype=jnp.int32)            # (S,)
    if cfg.family == "encdec":
        pe = params["dec_pos"][positions]
        x = x + (pe if positions.ndim > 1 else pe[None]).astype(x.dtype)

    # Cache-update architecture (§Perf iterations 2-8): during one step the
    # KV cache is READ-ONLY — the new token's contribution enters attention
    # through a log-sum-exp self term — so the stacked caches are scanned as
    # read-only xs (no while-carry copy hazards), per-layer updates come out
    # as small delta ys, and ONE batched dynamic-update-slice per cache
    # applies them afterwards into the donated input buffers.
    from repro.kernels.paged_attention import resolve_paged_impl
    use_paged_kernel = (pages is not None and S == 1
                        and resolve_paged_impl(paged_impl) != "ref")

    def group_body(carry, xs):
        h = carry
        gparams, gcache = xs
        updates = []
        for i, kind in enumerate(plan.pattern):
            p = (params.get("shared") if kind == "shared"
                 else _constrain_block_params(gparams[i]))
            h, nc, _ = apply_block(cfg, kind, p, h, positions=positions,
                                   enc_out=enc_out, cache=gcache[i],
                                   cache_len=pos, impl=impl,
                                   paged_impl=paged_impl,
                                   valid_len=valid_len)
            updates.append(nc)
        return h, tuple(updates)

    groups = tuple(params["groups"])
    gcaches = tuple(cache["groups"])
    # paged: gather each slot's pages into dense-layout K/V views ONCE per
    # pattern (outside the layer scan — the stacked gather covers every
    # group), so the blocks read a view indistinguishable from a dense
    # cache; writes go through the page table into the pools afterwards.
    # On the paged-kernel decode path no view is materialised at all: the
    # pools pass straight through and the kernel's index map IS the gather.
    if use_paged_kernel:
        read_gcaches = tuple(_page_pool_view_block(bc, pages, stacked=True)
                             for bc in gcaches)
        read_tail = [_page_pool_view_block(bc, pages, stacked=False)
                     for bc in cache["tail"]]
    else:
        read_gcaches = _page_views(gcaches, pages)
        read_tail = [_page_view_block(bc, pages) for bc in cache["tail"]]
    if jax.tree.leaves(groups):
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        if cfg.scan_layers and n_groups > 1:
            x, updates = jax.lax.scan(group_body, x, (groups, read_gcaches))
        else:
            outs = []
            for g in range(n_groups):
                gp = jax.tree.map(lambda t: t[g], groups)
                gc = jax.tree.map(lambda t: t[g], read_gcaches)
                x, upd = group_body(x, (gp, gc))
                outs.append(upd)
            updates = jax.tree.map(lambda *ts: jnp.stack(ts), *outs) \
                if outs else None
        new_gcaches = tuple(
            _apply_stacked_updates(gcaches[i], updates[i], pos,
                                   pages=pages, page_size=page_size,
                                   update_mask=update_mask)
            for i in range(len(plan.pattern)))
    else:
        new_gcaches = gcaches

    new_tail = []
    for i, kind in enumerate(plan.tail):
        x, nc, _ = apply_block(cfg, kind, params["tail"][i], x,
                               positions=positions, enc_out=enc_out,
                               cache=read_tail[i], cache_len=pos,
                               impl=impl, paged_impl=paged_impl,
                               valid_len=valid_len)
        new_tail.append(_apply_cache_update(cache["tail"][i], nc, pos,
                                            pages=pages, page_size=page_size,
                                            update_mask=update_mask))

    x = apply_norm(cfg, params["norm_f"], x)
    logits = unembed(cfg, params["embed"], x)
    logits = logical(logits, "batch", None, "vocab")
    new_cache = {"groups": list(new_gcaches), "tail": new_tail,
                 "len": pos + S}
    return logits, new_cache


def _write_kv_chunk_paged(pool, delta, start, pages_1d, page_size, *,
                          stacked: bool):
    """Write a whole prefill chunk's K/V into a slot's pages.

    Chunks are page-aligned by construction (``start`` and the chunk length
    are multiples of ``page_size``), so a chunk of C tokens is exactly
    C / page_size whole pages: reshape the delta into pages and scatter them
    at the slot's physical page ids — one scatter per chunk, not per token.
    """
    C = delta.shape[-2]
    n = C // page_size
    pids = jax.lax.dynamic_slice_in_dim(pages_1d, start // page_size, n)
    if stacked:
        G, _, KV, _, D = delta.shape
        val = delta[:, 0].reshape(G, KV, n, page_size, D).swapaxes(1, 2)
        out = pool.at[:, pids].set(val.astype(pool.dtype))
        return logical(out, None, "pages", "kv_heads", None, None)
    _, KV, _, D = delta.shape
    val = delta[0].reshape(KV, n, page_size, D).swapaxes(0, 1)
    out = pool.at[pids].set(val.astype(pool.dtype))
    return logical(out, "pages", "kv_heads", None, None)


def chunk_prefill_step(cfg: ModelConfig, params: dict, cache: dict, tokens, *,
                       slot, start, valid_len, pages_row=None,
                       page_size: int | None = None, impl: str = "auto"):
    """One prompt chunk of a chunked prefill into batch slot ``slot``.

    tokens: (1, C) — the chunk, right-padded to its bucket; ``start`` is the
    chunk's first logical position, ``valid_len`` the true (unpadded) token
    count in this chunk.  The chunk attends over the slot's already-written
    cache (positions < start) plus itself, and SSM layers continue from the
    slot's cached conv tail + state — so N chunks produce exactly the state
    one full prefill would.  Attention K/V go through ``pages_row`` (the
    slot's page-table row, (1, n_pages)) into the shared pool; SSM state is
    sliced out of / written back into the slot's row of the dense per-slot
    buffers.  Padding past ``valid_len`` writes garbage K/V into the slot's
    own pages (positions ≥ the true length are never valid reads and are
    overwritten by decode) and is masked out of SSM state updates.

    Returns (last_logits (1, 1, V) at the true last chunk token, new_cache).
    The slot's cache ``len`` is set to ``start + valid_len`` — re-asserted
    every chunk, so decode steps interleaved between chunks (which bump
    every slot's length) cannot drift a mid-prefill slot.
    """
    if cfg.family == "encdec":
        raise NotImplementedError("chunked prefill for encdec models")
    plan = layer_plan(cfg)
    x = embed(cfg, params["embed"], tokens)
    C = x.shape[1]
    positions = start + jnp.arange(C, dtype=jnp.int32)
    x = logical(x, "batch", "seq", "embed")

    def slot_row(tree):
        return jax.tree.map(
            lambda t: jax.lax.dynamic_slice_in_dim(t, slot, 1, axis=0), tree)

    def block_step(kind, p, h, bcache):
        """Run one block on the chunk; returns (h, update) where ``update``
        is an SSM 1-row cache or an attention K/V delta.  ``bcache`` holds
        gathered page VIEWS for attention kinds (reads only — writes go to
        the pools in ``apply_update``) and full per-slot buffers for SSM."""
        if kind == "ssm":
            # FIRST chunk (start == 0): the slot's dense SSM buffers still
            # hold the previous occupant's state — there is no splice step
            # in the paged engine to replace them, so continue from the
            # fresh-prefill zeros instead (attention needs no equivalent:
            # its first chunk skips the cache read behind a lax.cond)
            c = jax.tree.map(
                lambda t: jnp.where(jnp.asarray(start) > 0, t,
                                    jnp.zeros_like(t)),
                slot_row(bcache))
        else:
            c = bcache
        return apply_block(cfg, kind, p, h, positions=positions, cache=c,
                           cache_len=start, impl=impl,
                           chunk_continue=True, valid_len=valid_len)[:2]

    def apply_update(kind, bcache, upd, *, stacked):
        if upd is None:
            return bcache
        if kind == "ssm":
            # write the 1-row continuation state back into the slot's row
            axis = 1 if stacked else 0
            return jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=axis),
                bcache, upd)
        out = dict(bcache)
        for key, val in upd.items():
            if key == "self" and _is_delta(val):
                out["self"] = {
                    kk: _write_kv_chunk_paged(bcache["self"][kk],
                                              val[f"{kk}_delta"], start,
                                              pages_row[0], page_size,
                                              stacked=stacked)
                    for kk in ("k", "v")}
            else:
                out[key] = val
        return out

    def group_body(carry, xs):
        h = carry
        gparams, gcache = xs
        updates = []
        for i, kind in enumerate(plan.pattern):
            p = (params.get("shared") if kind == "shared"
                 else _constrain_block_params(gparams[i]))
            h, upd = block_step(kind, p, h, gcache[i])
            updates.append(upd)
        return h, tuple(updates)

    groups = tuple(params["groups"])
    gcaches = tuple(cache["groups"])
    # attention reads go through the slot's gathered page view (one stacked
    # gather per pattern, outside the scan); writes go into the pools
    read_gcaches = _page_views(gcaches, pages_row)
    read_tail = [_page_view_block(bc, pages_row) for bc in cache["tail"]]
    if jax.tree.leaves(groups):
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        if cfg.scan_layers and n_groups > 1:
            x, updates = jax.lax.scan(group_body, x, (groups, read_gcaches))
        else:
            outs = []
            for g in range(n_groups):
                gp = jax.tree.map(lambda t: t[g], groups)
                gc = jax.tree.map(lambda t: t[g], read_gcaches)
                x, upd = group_body(x, (gp, gc))
                outs.append(upd)
            updates = jax.tree.map(lambda *ts: jnp.stack(ts), *outs) \
                if outs else None
        new_gcaches = tuple(
            apply_update(kind, gcaches[i], updates[i], stacked=True)
            for i, kind in enumerate(plan.pattern))
    else:
        new_gcaches = gcaches

    new_tail = []
    for i, kind in enumerate(plan.tail):
        x, upd = block_step(kind, params["tail"][i], x, read_tail[i])
        new_tail.append(apply_update(kind, cache["tail"][i], upd,
                                     stacked=False))

    x = apply_norm(cfg, params["norm_f"], x)
    # only the true last chunk token's logits are ever consumed (first-token
    # sampling after the final chunk) — slice BEFORE the unembed so
    # intermediate chunks never pay a (C, V) projection
    last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
    logits = unembed(cfg, params["embed"], last)
    new_cache = {"groups": list(new_gcaches), "tail": new_tail,
                 "len": jnp.asarray(cache["len"]).at[slot].set(
                     start + valid_len)}
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, tokens=None, *, embeds=None,
            enc_embeds=None, max_len: int | None = None, impl="auto"):
    """Run the prompt through the model, building a KV cache.

    Implemented as forward + cache-write: for attention layers we recompute
    K/V per layer into the cache.  (Serving engines use this for the prefill
    phase; decode then extends the cache.)  Returns (cache, last_logits).
    """
    # Simple reference implementation: step-by-step decode over the prompt.
    # The serving engine (repro/serve) overrides this with a batched
    # single-pass prefill; this function is the small-scale reference.
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    max_len = max_len or (S + 64)
    enc_out = None
    if cfg.family == "encdec":
        plan = layer_plan(cfg)
        e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
        e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
        e, _ = _run_stack(cfg, plan.enc_pattern, tuple(params["enc_groups"]),
                          (), (), None, e, jnp.arange(e.shape[1]), impl=impl)
        enc_out = apply_norm(cfg, params["enc_norm_f"], e)
    cache = init_cache(cfg, B, max_len,
                       enc_len=enc_out.shape[1] if enc_out is not None else 0)

    def body(cache, t):
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1) \
            if tokens is not None else None
        emb = jax.lax.dynamic_slice_in_dim(embeds, t, 1, axis=1) \
            if embeds is not None else None
        logits, cache = decode_step(cfg, params, cache, tok, enc_out=enc_out,
                                    embeds=emb, impl=impl)
        return cache, logits

    cache, logits = jax.lax.scan(body, cache, jnp.arange(S))
    return cache, logits[-1], enc_out
