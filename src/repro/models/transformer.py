"""Model composition: every assigned architecture as a pattern-scanned stack.

A model is ``(pattern, n_groups, tail)``: the *pattern* is a short list of
block kinds (e.g. gemma3's ``5×local + 1×global``), scanned ``n_groups``
times with stacked parameters, plus an unscanned *tail* (remainder layers).
This keeps the compiled HLO small (one pattern body) while allowing
heterogeneous stacks — and gives the HLO analyzer a single while-loop whose
trip count is ``n_groups`` (DESIGN.md §5).

Block kinds:
  dense   — attention + gated MLP            (qwen2, deepseek, llama3, chameleon)
  local   — sliding-window attention + MLP   (gemma3 local layers)
  global  — full attention + MLP             (gemma3 global layers)
  moe     — attention + mixture-of-experts   (mixtral [SWA], qwen2-moe)
  ssm     — Mamba-2 mixer                    (mamba2, zamba2 backbone)
  shared  — zamba2's *shared* attention+MLP block (one parameter set,
            invoked at every occurrence)
  enc/dec — whisper encoder / decoder (cross-attention) blocks
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.parallel.sharding import current_rules, logical
from .attention import KVCache, attention, init_attention
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, embed, init_embedding, init_mlp,
                     init_norm, truncated_normal, unembed)
from .moe import apply_moe, init_moe
from .ssm import SSMCache, apply_mamba2, init_mamba2, mamba2_decode_step

__all__ = [
    "layer_plan", "init_params", "forward", "loss_fn", "init_cache",
    "prefill", "decode_step", "param_count",
]


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    pattern: tuple[str, ...]
    n_groups: int
    tail: tuple[str, ...]
    enc_pattern: tuple[str, ...] = ()
    enc_groups: int = 0

    @property
    def scan_trips(self) -> int:
        return self.n_groups


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    L = cfg.n_layers
    if cfg.family == "encdec":
        return LayerPlan(pattern=("dec",), n_groups=L, tail=(),
                         enc_pattern=("enc",), enc_groups=cfg.n_encoder_layers)
    if cfg.family == "ssm":
        return LayerPlan(pattern=("ssm",), n_groups=L, tail=())
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        g, r = divmod(L, k)
        return LayerPlan(pattern=("ssm",) * k + ("shared",), n_groups=g,
                         tail=("ssm",) * r)
    if cfg.local_global_ratio:
        k = cfg.local_global_ratio + 1
        g, r = divmod(L, k)
        return LayerPlan(pattern=("local",) * cfg.local_global_ratio + ("global",),
                         n_groups=g, tail=("local",) * r)
    if cfg.is_moe:
        return LayerPlan(pattern=("moe",), n_groups=L, tail=())
    return LayerPlan(pattern=("dense",), n_groups=L, tail=())


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _has_attn(kind: str) -> bool:
    return kind in ("dense", "local", "global", "moe", "shared", "enc", "dec")


def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 6)
    if kind == "ssm":
        return {"ln1": init_norm(cfg), "mixer": init_mamba2(ks[0], cfg)}
    p = {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
         "ln2": init_norm(cfg)}
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if kind == "dec":
        p["ln_cross"] = init_norm(cfg)
        p["cross"] = init_attention(ks[2], cfg, cross=True)
    return p


def _window_for(cfg: ModelConfig, kind: str) -> int | None:
    if kind == "local":
        return cfg.local_window
    if kind in ("dense", "moe", "global", "shared"):
        return cfg.sliding_window if kind in ("dense", "moe") else None
    return None


def apply_block(cfg: ModelConfig, kind: str, p: dict, x, *, positions,
                enc_out=None, cache=None, cache_len=None,
                impl: str = "auto"):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if kind == "ssm":
        h = apply_norm(cfg, p["ln1"], x)
        if cache is not None and x.shape[1] == 1:
            out, new_cache = mamba2_decode_step(cfg, p["mixer"], h, cache)
        elif cache is not None:
            # batched prefill: run the chunked scan, emit a decode cache
            out, new_cache = apply_mamba2(cfg, p["mixer"], h, return_cache=True)
        else:
            out = apply_mamba2(cfg, p["mixer"], h)
        return x + out, new_cache, aux

    causal = kind != "enc"
    window = _window_for(cfg, kind)
    h = apply_norm(cfg, p["ln1"], x)
    sa_cache = cache.get("self") if cache is not None else None
    out, new_sa = attention(cfg, p["attn"], h, positions=positions,
                            causal=causal, window=window, cache=sa_cache,
                            cache_len=cache_len, impl=impl,
                            rope=cfg.use_rope and kind != "enc" and kind != "dec")
    x = x + logical(out, "batch", "seq", "embed")

    if kind == "dec" and enc_out is not None:
        h = apply_norm(cfg, p["ln_cross"], x)
        enc_len = enc_out.shape[1]
        # cross K/V recomputed per call (cacheing them is a serving-engine
        # optimisation; see repro/serve/engine.py)
        out, _ = attention(cfg, p["cross"], h, kv_x=enc_out,
                           positions=positions,
                           kv_positions=jnp.arange(enc_len),
                           causal=False, rope=False)
        x = x + out

    h = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        out, aux = apply_moe(cfg, p["moe"], h)
    else:
        out = apply_mlp(cfg, p["mlp"], h)
    x = x + logical(out, "batch", "seq", "embed")

    if cache is not None and kind != "ssm":
        # return ONLY the update (deltas) — returning the old cache slices
        # would double-buffer them through the scan ys (§Perf)
        new_cache = {"self": new_sa} if new_sa is not None else {}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": init_embedding(keys[0], cfg)}

    def stacked(key, kind, n):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: init_block(k, cfg, kind))(ks)

    # scanned groups: one stacked param tree per pattern position
    gkeys = jax.random.split(keys[1], max(len(plan.pattern), 1))
    params["groups"] = [
        stacked(gkeys[i], kind, plan.n_groups) if kind != "shared" else {}
        for i, kind in enumerate(plan.pattern)
    ]
    if "shared" in plan.pattern:
        params["shared"] = init_block(keys[2], cfg, "shared")
    tkeys = jax.random.split(keys[3], max(len(plan.tail), 1))
    params["tail"] = [init_block(tkeys[i], cfg, kind)
                      for i, kind in enumerate(plan.tail)]
    params["norm_f"] = init_norm(cfg)

    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[4], 3)
        params["enc_groups"] = [stacked(ekeys[0], "enc", plan.enc_groups)]
        params["enc_norm_f"] = init_norm(cfg)
        params["enc_pos"] = truncated_normal(
            ekeys[1], (cfg.max_seq, cfg.d_model), 0.02,
            jnp.dtype(cfg.param_dtype))
        params["dec_pos"] = truncated_normal(
            ekeys[2], (cfg.max_seq, cfg.d_model), 0.02,
            jnp.dtype(cfg.param_dtype))
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "save_dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _constrain_block_params(p):
    """Re-assert the (FSDP/TP) sharding of per-layer params sliced out of the
    scan xs.  Without this GSPMD may all-gather the whole stacked weight
    array outside the loop ("wide" while), keeping every layer's gathered
    weights live simultaneously — §Perf iteration H7."""
    if current_rules() is None or p is None:
        return p
    from repro.parallel.partition import axes_for_path
    flat, treedef = jax.tree_util.tree_flatten_with_path(p)
    out = []
    for path, leaf in flat:
        keys = tuple(str(getattr(x, "key", getattr(x, "idx", x))) for x in path)
        axes = axes_for_path(keys, getattr(leaf, "ndim", 0))
        out.append(logical(leaf, *axes) if hasattr(leaf, "ndim") else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _run_stack(cfg: ModelConfig, plan_pattern, groups, tail_kinds, tail,
               shared, x, positions, *, enc_out=None, impl="auto"):
    """Scan the pattern over groups, then the tail. Returns (x, aux)."""

    def group_body(carry, gparams):
        h, aux = carry
        for i, kind in enumerate(plan_pattern):
            # (H7 constraint on sliced params is applied only on decode
            # paths; in training it triggered GSPMD replicate-then-partition
            # weight all-reduces — §Perf)
            p = shared if kind == "shared" else gparams[i]
            h, _, a = apply_block(cfg, kind, p, h, positions=positions,
                                  enc_out=enc_out, impl=impl)
            aux = aux + a
        h = logical(h, "batch", "seq", "embed")
        return (h, aux), None

    aux0 = jnp.zeros((), jnp.float32)
    if groups and jax.tree.leaves(groups):
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        if cfg.scan_layers and n_groups > 1:
            body = _remat(cfg, group_body)
            (x, aux0), _ = jax.lax.scan(body, (x, aux0), tuple(groups))
        else:
            for g in range(n_groups):
                gp = jax.tree.map(lambda t: t[g], tuple(groups))
                (x, aux0), _ = _remat(cfg, group_body)((x, aux0), gp)
    for i, kind in enumerate(tail_kinds):
        x, _, a = apply_block(cfg, kind, tail[i], x, positions=positions,
                              enc_out=enc_out, impl=impl)
        aux0 = aux0 + a
    return x, aux0


def forward(cfg: ModelConfig, params: dict, *, tokens=None, embeds=None,
            positions=None, enc_embeds=None, impl: str = "auto"):
    """Full-sequence forward (train / prefill).  Returns (logits, aux).

    ``tokens``: (B, S) int32 — LM input.
    ``embeds``: (B, S, d) — precomputed embeddings (stub modality frontend).
    ``enc_embeds``: (B, T, d) — encoder input for encdec (whisper frames).
    """
    plan = layer_plan(cfg)
    if embeds is None:
        x = embed(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    x = logical(x, "batch", "seq", "embed")

    enc_out = None
    if cfg.family == "encdec":
        assert enc_embeds is not None, "encdec model needs enc_embeds"
        e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
        e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
        e = logical(e, "batch", "seq", "embed")
        e, _ = _run_stack(cfg, plan.enc_pattern, tuple(params["enc_groups"]),
                          (), (), None, e, jnp.arange(e.shape[1]), impl=impl)
        enc_out = apply_norm(cfg, params["enc_norm_f"], e)
        x = x + params["dec_pos"][positions].astype(x.dtype)

    x, aux = _run_stack(cfg, plan.pattern, tuple(params["groups"]),
                        plan.tail, params["tail"], params.get("shared"),
                        x, positions, enc_out=enc_out, impl=impl)
    x = apply_norm(cfg, params["norm_f"], x)
    logits = unembed(cfg, params["embed"], x)
    logits = logical(logits, "batch", None, "vocab")
    return logits, aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, impl="auto"):
    """Next-token cross-entropy (+ MoE aux).  batch: tokens, labels, mask
    (+ enc_embeds / embeds for stub-frontend families)."""
    logits, aux = forward(cfg, params,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          enc_embeds=batch.get("enc_embeds"),
                          impl=impl)
    labels = batch["labels"]
    mask = batch.get("mask")
    logits = logits.astype(jnp.float32)
    # mask out vocab padding
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e9, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(pad)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      dtype, enc_len: int = 0):
    if kind == "ssm":
        return SSMCache.init(cfg, batch)
    return {"self": KVCache.init(cfg, batch, max_len, dtype)}


def _is_delta(upd) -> bool:
    return isinstance(upd, dict) and "k_delta" in upd


def _write_kv(buf, delta, pos, *, batch_axis: int):
    """Write a K/V delta into a cache buffer at the token position.

    ``pos`` scalar — one dynamic-update-slice for the whole batch (training /
    uniform decode).  ``pos`` (B,) — per-slot positions (continuous batching:
    slots inserted at different times sit at different lengths), written as a
    vmap over the batch axis, one slice per slot.
    """
    idx = jnp.asarray(pos)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice(
            buf, delta, (0,) * (buf.ndim - 2) + (idx, 0))
    per_row = lambda c, d, i: jax.lax.dynamic_update_slice(
        c, d, (0,) * (c.ndim - 2) + (i, 0))
    return jax.vmap(per_row, in_axes=(batch_axis, batch_axis, 0),
                    out_axes=batch_axis)(buf, delta, idx)


def _apply_cache_update(old_layer_cache, upd, pos):
    """Apply a block's cache update to an UNSTACKED layer cache."""
    if upd is None:
        return old_layer_cache
    out = {}
    for key, val in upd.items():
        if key == "self" and _is_delta(val):
            out["self"] = {
                kk: _write_kv(old_layer_cache["self"][kk],
                              val[f"{kk}_delta"], pos, batch_axis=0)
                for kk in ("k", "v")}
        else:
            out[key] = val
    return out


def _apply_stacked_updates(stacked, updates, pos):
    """Apply scan-collected per-layer updates to a stacked cache.

    KV deltas (G,B,KV,S,D) are written with ONE dynamic-update-slice at the
    token position (or one per slot for per-slot ``pos`` vectors); SSM states
    come out of the scan already whole, stacked — they simply replace the old
    buffers."""
    if updates is None:
        return stacked
    new = dict(stacked)
    for key, val in updates.items():
        if key == "self" and _is_delta(val):
            new["self"] = {
                kk: _write_kv(stacked["self"][kk],
                              val[f"{kk}_delta"].astype(stacked["self"][kk].dtype),
                              pos, batch_axis=1)
                for kk in ("k", "v")}
        else:
            new[key] = val.astype(stacked[key].dtype)
    return new


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, enc_len: int = 0):
    plan = layer_plan(cfg)
    dtype = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)

    def stacked_cache(kind):
        one = lambda: _init_block_cache(cfg, kind, batch, max_len, dtype, enc_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_groups,) + x.shape).copy()
            if plan.n_groups > 1 else x[None], one())

    cache = {
        "groups": [stacked_cache(kind) for kind in plan.pattern],
        "tail": [_init_block_cache(cfg, kind, batch, max_len, dtype, enc_len)
                 for kind in plan.tail],
        "len": jnp.zeros((), jnp.int32),
    }
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens, *,
                enc_out=None, embeds=None, impl: str = "auto"):
    """One cache-extending step.  tokens: (B, S) int32 (or embeds (B,S,d));
    S == 1 is decode, S > 1 is batched prefill (cache must be fresh).
    Returns (logits (B, S, V), new_cache)."""
    plan = layer_plan(cfg)
    if embeds is None:
        x = embed(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    S = x.shape[1]
    pos = jnp.asarray(cache["len"])
    # ``len`` may be a scalar (uniform batch) or (B,) vector (per-slot
    # lengths under continuous batching — slots inserted at different times
    # sit at different positions).  Vector lengths are decode-only: batched
    # prefill always starts from a fresh (scalar, zero-length) cache.
    if pos.ndim == 1 and S > 1:
        raise ValueError("per-slot cache lengths only support single-token "
                         "decode (S == 1); prefill from a fresh cache")
    if pos.ndim == 1:
        positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)   # (B, S)
    else:
        positions = pos + jnp.arange(S, dtype=jnp.int32)            # (S,)
    if cfg.family == "encdec":
        pe = params["dec_pos"][positions]
        x = x + (pe if positions.ndim > 1 else pe[None]).astype(x.dtype)

    # Cache-update architecture (§Perf iterations 2-8): during one step the
    # KV cache is READ-ONLY — the new token's contribution enters attention
    # through a log-sum-exp self term — so the stacked caches are scanned as
    # read-only xs (no while-carry copy hazards), per-layer updates come out
    # as small delta ys, and ONE batched dynamic-update-slice per cache
    # applies them afterwards into the donated input buffers.
    def group_body(carry, xs):
        h = carry
        gparams, gcache = xs
        updates = []
        for i, kind in enumerate(plan.pattern):
            p = (params.get("shared") if kind == "shared"
                 else _constrain_block_params(gparams[i]))
            h, nc, _ = apply_block(cfg, kind, p, h, positions=positions,
                                   enc_out=enc_out, cache=gcache[i],
                                   cache_len=pos, impl=impl)
            updates.append(nc)
        return h, tuple(updates)

    groups = tuple(params["groups"])
    gcaches = tuple(cache["groups"])
    if jax.tree.leaves(groups):
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        if cfg.scan_layers and n_groups > 1:
            x, updates = jax.lax.scan(group_body, x, (groups, gcaches))
        else:
            outs = []
            for g in range(n_groups):
                gp = jax.tree.map(lambda t: t[g], groups)
                gc = jax.tree.map(lambda t: t[g], gcaches)
                x, upd = group_body(x, (gp, gc))
                outs.append(upd)
            updates = jax.tree.map(lambda *ts: jnp.stack(ts), *outs) \
                if outs else None
        new_gcaches = tuple(
            _apply_stacked_updates(gcaches[i], updates[i], pos)
            for i in range(len(plan.pattern)))
    else:
        new_gcaches = gcaches

    new_tail = []
    for i, kind in enumerate(plan.tail):
        x, nc, _ = apply_block(cfg, kind, params["tail"][i], x,
                               positions=positions, enc_out=enc_out,
                               cache=cache["tail"][i], cache_len=pos, impl=impl)
        new_tail.append(_apply_cache_update(cache["tail"][i], nc, pos))

    x = apply_norm(cfg, params["norm_f"], x)
    logits = unembed(cfg, params["embed"], x)
    logits = logical(logits, "batch", None, "vocab")
    new_cache = {"groups": list(new_gcaches), "tail": new_tail,
                 "len": pos + S}
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, tokens=None, *, embeds=None,
            enc_embeds=None, max_len: int | None = None, impl="auto"):
    """Run the prompt through the model, building a KV cache.

    Implemented as forward + cache-write: for attention layers we recompute
    K/V per layer into the cache.  (Serving engines use this for the prefill
    phase; decode then extends the cache.)  Returns (cache, last_logits).
    """
    # Simple reference implementation: step-by-step decode over the prompt.
    # The serving engine (repro/serve) overrides this with a batched
    # single-pass prefill; this function is the small-scale reference.
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    max_len = max_len or (S + 64)
    enc_out = None
    if cfg.family == "encdec":
        plan = layer_plan(cfg)
        e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
        e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
        e, _ = _run_stack(cfg, plan.enc_pattern, tuple(params["enc_groups"]),
                          (), (), None, e, jnp.arange(e.shape[1]), impl=impl)
        enc_out = apply_norm(cfg, params["enc_norm_f"], e)
    cache = init_cache(cfg, B, max_len,
                       enc_len=enc_out.shape[1] if enc_out is not None else 0)

    def body(cache, t):
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1) \
            if tokens is not None else None
        emb = jax.lax.dynamic_slice_in_dim(embeds, t, 1, axis=1) \
            if embeds is not None else None
        logits, cache = decode_step(cfg, params, cache, tok, enc_out=enc_out,
                                    embeds=emb, impl=impl)
        return cache, logits

    cache, logits = jax.lax.scan(body, cache, jnp.arange(S))
    return cache, logits[-1], enc_out
