from .config import ModelConfig, ShapeCell, SHAPE_CELLS
from .transformer import (layer_plan, init_params, forward, loss_fn,
                          init_cache, decode_step, prefill, param_count)
