"""Mamba-2 (SSD — state-space duality) blocks.

Chunked SSD algorithm (Dao & Gu 2024, §6): the sequence is split into chunks
of length Q; within a chunk the dual quadratic (attention-like) form is
used, states are carried across chunks by a sequential scan.  This is the
exact structure the paper's job model expresses naturally: chunks = jobs
with a carried dependency (DESIGN.md §4).

The intra-chunk quadratic form is the compute hot-spot; a Pallas kernel
(``repro.kernels.ssd_scan``) implements it with VMEM tiling on TPU; this
module is the pure-jnp path (and the kernel's oracle).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_dense, init_norm, apply_norm, dense, truncated_normal

__all__ = ["init_mamba2", "apply_mamba2", "mamba2_decode_step", "SSMCache", "ssd_chunked"]


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.ssm_d_inner
    N, H = cfg.ssm_state, cfg.ssm_n_heads
    conv_dim = di + 2 * N               # x + B + C go through the causal conv
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        # in_proj: d -> [z, xBC, dt]
        "in_proj": init_dense(ks[0], d, 2 * di + 2 * N + H, cfg),
        "conv_w": truncated_normal(ks[1], (cfg.ssm_conv, conv_dim),
                                   1.0 / math.sqrt(cfg.ssm_conv), pdt),
        "conv_b": jnp.zeros((conv_dim,), pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)).astype(pdt),
        "D": jnp.ones((H,), pdt),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))).astype(pdt),
        "norm": init_norm(cfg, di),
        "out_proj": init_dense(ks[3], di, d, cfg, scale=1.0 / math.sqrt(di)),
    }


def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk: int, initial_state=None,
                return_final_state: bool = False, impl: str = "jnp"):
    """Chunked SSD core.

    xh: (B, S, H, P)   per-head inputs
    dt: (B, S, H)      softplus'd step sizes
    A:  (H,)           negative decay rates
    Bm: (B, S, N)      input projections (single group, shared over heads)
    Cm: (B, S, N)      output projections
    Returns y: (B, S, H, P) [, final_state (B, H, P, N)].
    """
    B_, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        # pad to a chunk multiple with dt=0 steps (decay=1, zero input:
        # the recurrent state passes through padding unchanged)
        pad = Q - S % Q
        y = ssd_chunked(
            jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
            chunk=Q, initial_state=initial_state,
            return_final_state=return_final_state, impl=impl)
        if return_final_state:
            return y[0][:, :S], y[1]
        return y[:, :S]
    nc = S // Q

    from repro.parallel.sharding import logical

    xf = xh.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    # per-step log decay  a[t] = A * dt[t]  (negative)
    a = dtf * A[None, None, :]                                 # (B,S,H)
    # SSD head parallelism: the (B,nc,Q,Q,H) intra-chunk decay tensors are
    # the dominant live set of the XLA path — shard their H axis (TP);
    # heads never cross in SSD, so no collectives are introduced (§Perf)
    xc = logical(xf.reshape(B_, nc, Q, H, P),
                 "batch", None, None, "ssm_heads", None)
    dtc = logical(dtf.reshape(B_, nc, Q, H),
                  "batch", None, None, "ssm_heads")
    ac = logical(a.reshape(B_, nc, Q, H),
                 "batch", None, None, "ssm_heads")
    Bc = Bf.reshape(B_, nc, Q, N)
    Cc = Cf.reshape(B_, nc, Q, N)

    cum = jnp.cumsum(ac, axis=2)                               # (B,nc,Q,H)
    if impl in ("kernel", "interpret"):
        # Pallas path: (B·nc, H, Q, P) layout, kernel computes y_intra + states
        from repro.kernels.ssd_scan.ops import ssd_intra_chunk
        xk = xc.reshape(B_ * nc, Q, H, P).transpose(0, 2, 1, 3)
        dtk = dtc.reshape(B_ * nc, Q, H).transpose(0, 2, 1)[..., None]
        ak = ac.reshape(B_ * nc, Q, H).transpose(0, 2, 1)[..., None]
        Bk = Bc.reshape(B_ * nc, Q, N)
        Ck = Cc.reshape(B_ * nc, Q, N)
        yk, Sk = ssd_intra_chunk(xk, dtk, ak, Bk, Ck, impl=impl)
        y_intra = yk.transpose(0, 2, 1, 3).reshape(B_, nc, Q, H, P)
        S_chunk = Sk.transpose(0, 1, 3, 2).reshape(B_, nc, H, P, N)
    else:
        # ---- intra-chunk (dual quadratic form) ----------------------------
        # L[t,s] = exp(cum[t] - cum[s]) for s<=t else 0
        seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
        L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)             # (B,nc,Q,Q)
        M = CB[..., None] * L                                  # (B,nc,Q,Q,H)
        y_intra = jnp.einsum("bcqsh,bcsh,bcshp->bcqhp", M, dtc, xc)

        # state contribution of chunk c:
        #   sum_s exp(cum_end - cum[s]) dt[s] B[s] x[s]
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,Q,H)
        S_chunk = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchpn",
                             decay_to_end, dtc, Bc, xc)        # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    # ---- inter-chunk sequential scan over nc chunks -------------------------
    if initial_state is None:
        s0 = jnp.zeros((B_, H, P, N), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def step(s_prev, xs):
        s_c, dec = xs                                          # (B,H,P,N), (B,H)
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    final_state, s_before = jax.lax.scan(
        step, s0, (S_chunk.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    s_before = s_before.swapaxes(0, 1)                         # (B,nc,H,P,N)

    # inter contribution: y[t] += exp(cum[t]) * C[t] · s_before
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp",
                         jnp.exp(cum), Cc, s_before)
    y = (y_intra + y_inter).reshape(B_, S, H, P).astype(xh.dtype)
    if return_final_state:
        return y, final_state
    return y


def _causal_conv(x, w, b, hist=None):
    """x: (B, S, C); w: (W, C); causal depthwise conv.  ``hist``:
    (B, W-1, C) left context (a previous chunk's raw-conv tail) instead of
    the default zero padding — chunked prefill continues seamlessly through
    the same arithmetic as the zero-padded one-shot path."""
    W = w.shape[0]
    if hist is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([hist, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def apply_mamba2(cfg: ModelConfig, p: dict, x: jax.Array, *,
                 initial_state=None, return_state: bool = False,
                 return_cache: bool = False, cache: dict | None = None,
                 valid_len=None, impl: str = "auto"):
    """Full Mamba-2 mixer. x: (B,S,d) -> (B,S,d).

    ``return_cache``: also return a decode cache (conv tail + final SSD
    state) so a serving engine can continue token-by-token (prefill).
    ``cache``: *continue* a prefill from a prior chunk's decode cache — the
    causal conv reads the cached ``conv`` tail as left context and the SSD
    scan starts from the cached ``state`` (chunked prefill, DESIGN.md §9).
    ``valid_len``: scalar — positions ≥ ``valid_len`` are right-padding
    (bucketed prompts): their ``dt`` is forced to 0 so they decay nothing
    into the state (decay = 1, input = 0), and the returned conv tail is
    sliced at the *true* end, so padding can never leak into decode.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    P = cfg.ssm_head_dim
    W = cfg.ssm_conv
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "jnp"

    proj = dense(p["in_proj"], x, cd)
    z, xBC_raw, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    xBC_f32 = xBC_raw.astype(jnp.float32)
    if cache is not None:
        # the previous chunk's raw-conv tail is the left context
        hist = cache["conv"].astype(jnp.float32)                # (B, W-1, C)
        if initial_state is None:
            initial_state = cache["state"]
    else:
        hist = jnp.zeros((B, W - 1, xBC_f32.shape[-1]), jnp.float32)
    xBC_full = jnp.concatenate([hist, xBC_f32], axis=1)
    xBC = _causal_conv(xBC_f32, p["conv_w"].astype(jnp.float32),
                       p["conv_b"].astype(jnp.float32), hist=hist)
    xBC = jax.nn.silu(xBC).astype(cd)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if valid_len is not None:
        # padding steps must not touch the state: dt = 0 ⇒ decay 1, input 0
        real = jnp.arange(S)[None, :, None] < valid_len
        dtf = jnp.where(real, dtf, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(B, S, H, P)
    y, fstate = ssd_chunked(xh, dtf, A, Bm, Cm, chunk=cfg.ssm_chunk,
                            initial_state=initial_state,
                            return_final_state=True, impl=impl)
    y = y + xh.astype(jnp.float32).astype(cd) * p["D"].astype(cd)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = apply_norm(cfg, p["norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y, cd)
    if return_cache:
        if valid_len is None:
            assert S >= W - 1, f"prefill length {S} < conv window {W - 1}"
            tail = xBC_f32[:, S - (W - 1):S, :]
        else:
            # xBC_full row i corresponds to position i - (W-1); the last
            # W-1 *real* inputs are rows [valid_len, valid_len + W - 1)
            tail = jax.lax.dynamic_slice_in_dim(xBC_full, valid_len, W - 1,
                                                axis=1)
        return out, {"conv": tail, "state": fstate}
    if return_state:
        return out, fstate
    return out


# ---------------------------------------------------------------------------
# Decode (single-token recurrence)
# ---------------------------------------------------------------------------


class SSMCache:
    @staticmethod
    def init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
        di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
        P = cfg.ssm_head_dim
        return {
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), jnp.float32),
            "state": jnp.zeros((batch, H, P, N), jnp.float32),
        }


def mamba2_decode_step(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    """x: (B, 1, d); returns (out (B,1,d), new_cache)."""
    cd = jnp.dtype(cfg.compute_dtype)
    B, _, d = x.shape
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    P = cfg.ssm_head_dim

    proj = dense(p["in_proj"], x, cd)[:, 0]                     # (B, ...)
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)

    # conv ring buffer: history (B, W-1, C) + current
    hist = cache["conv"]
    full = jnp.concatenate([hist, xBC.astype(jnp.float32)[:, None, :]], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bwc,wc->bc", full, w) + p["conv_b"].astype(jnp.float32)
    xBC_t = jax.nn.silu(conv_out)
    new_conv = full[:, 1:]

    xs, Bm, Cm = jnp.split(xBC_t, [di, di + N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(B, H, P)
    decay = jnp.exp(dtf * A[None, :])                            # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dtf, Bm, xh)
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]

    y = y.reshape(B, 1, di).astype(cd)
    y = apply_norm(cfg, p["norm"], y * jax.nn.silu(z[:, None, :]))
    out = dense(p["out_proj"], y, cd)
    return out, {"conv": new_conv, "state": state}
