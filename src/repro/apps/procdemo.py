"""Spawn-safe demo workload for the process executor.

This module is imported by *worker child processes* (via the
``"repro.apps.procdemo:FNS"`` spec), so its import must stay jax-free and
cheap: plain numpy functions at module level, with the master-side registry
and graph builders importing the heavy core lazily.

The workload is a chain of chunkwise matmul+tanh segments over a fixed
weight (the dispatch-overhead shape of ``benchmarks/hypar_overhead.py``)
ending in a whole-kind reduction — enough structure to exercise placement,
pipelining, memoisation and crash recovery, deterministic end to end.

``REPRO_PROCDEMO_SLEEP`` (seconds, float) slows every worker function down;
crash tests use it to widen the window for killing a worker mid-run.
"""
from __future__ import annotations

import os

import numpy as np

__all__ = ["FNS", "WORKER_FNS_SPEC", "make_registry", "build_graph",
           "expected_results"]

WORKER_FNS_SPEC = "repro.apps.procdemo:FNS"


def _maybe_sleep() -> None:
    s = float(os.environ.get("REPRO_PROCDEMO_SLEEP", "0") or 0.0)
    if s > 0:
        import time
        time.sleep(s)


def init_chunk(x: np.ndarray) -> np.ndarray:
    _maybe_sleep()
    return np.asarray(x, np.float64) * 0.1


def step(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Chunkwise matmul+tanh; called as ``step(weight, activation)`` in the
    demo graph (bound inputs are prepended by the executors)."""
    _maybe_sleep()
    return np.tanh(a @ b)


def reduce_sum(*inputs) -> np.ndarray:
    """Whole-kind: one chunk collection per input ref, summed.  Elements may
    be raw arrays (process child) or DataChunks (LocalExecutor parity)."""
    _maybe_sleep()
    chunks = [np.asarray(getattr(c, "data", c))
              for cd in inputs for c in cd]
    return np.sum(np.stack(chunks), axis=0)


FNS = {"pd_init": init_chunk, "pd_step": step, "pd_reduce": reduce_sum}


def _weight(dim: int) -> np.ndarray:
    rng = np.random.default_rng(7)
    return (rng.standard_normal((dim, dim)) / np.sqrt(dim)).astype(np.float64)


def make_registry(host: bool = False):
    """Master-side registry mirroring :data:`FNS` (same fids, same kinds);
    the master only consults the *kinds* — execution happens in the child.

    ``host=True`` instead registers numpy whole-kind wrappers with the same
    zip-over-chunks semantics, so the SAME graph runs on LocalExecutor's
    worker threads (whose chunkwise path jits, which a host numpy function
    cannot survive) bit-identically to the process children — the thread
    baseline of ``benchmarks/hypar_overhead.run_proc_dispatch``."""
    from repro.core import (ChunkedData, DataChunk, FunctionKind,
                            FunctionRegistry)
    reg = FunctionRegistry()
    if host:
        def chunkzip(f):
            def wrap(*cds):
                lists = [[np.asarray(getattr(c, "data", c)) for c in cd]
                         for cd in cds]
                return ChunkedData([DataChunk(f(*args))
                                    for args in zip(*lists)])
            return wrap

        reg.register("pd_init", chunkzip(init_chunk), kind=FunctionKind.WHOLE)
        reg.register("pd_step", chunkzip(step), kind=FunctionKind.WHOLE)
        # reduce keeps float64 by wrapping itself: the executor's fallback
        # normalisation (from_arrays) would round-trip through jnp/float32
        reg.register("pd_reduce",
                     lambda *cds: ChunkedData([DataChunk(reduce_sum(*cds))]),
                     kind=FunctionKind.WHOLE)
        return reg
    reg.register("pd_init", init_chunk, kind=FunctionKind.CHUNKWISE)
    reg.register("pd_step", step, kind=FunctionKind.CHUNKWISE)
    reg.register("pd_reduce", reduce_sum, kind=FunctionKind.WHOLE)
    return reg


def build_graph(*, width: int = 4, depth: int = 3, dim: int = 16,
                seed: int = 0):
    """``width`` parallel chains of ``depth`` chunkwise steps feeding one
    whole-kind reduction.  Deterministic in ``seed``."""
    from repro.core import (ChunkedData, ChunkRef, DataChunk, Job, JobGraph,
                            ParallelSegment)

    def host_chunks(*arrays):
        # keep bound inputs as float64 numpy — from_arrays would round-trip
        # through jnp.asarray and truncate to float32
        return ChunkedData([DataChunk(a) for a in arrays])

    rng = np.random.default_rng(seed)
    w = _weight(dim)
    g = JobGraph([ParallelSegment(
        [Job(f"init{i}", "pd_init") for i in range(width)])])
    for i in range(width):
        g.bind_input(f"init{i}", host_chunks(
            rng.standard_normal((dim, dim)).astype(np.float64)))
    prev = [f"init{i}" for i in range(width)]
    for d in range(depth):
        jobs = []
        for i in range(width):
            name = f"step{d}_{i}"
            jobs.append(Job(name, "pd_step",
                            inputs=(ChunkRef(prev[i]),)))
            g.bind_input(name, host_chunks(w))
        g.add_segment(jobs)
        prev = [j.name for j in jobs]
    g.add_segment([Job("reduce", "pd_reduce",
                       inputs=tuple(ChunkRef(p) for p in prev))])
    return g


def expected_results(*, width: int = 4, depth: int = 3, dim: int = 16,
                     seed: int = 0) -> dict[str, list[np.ndarray]]:
    """Pure-numpy oracle for :func:`build_graph` — what any executor must
    produce, bit for bit."""
    rng = np.random.default_rng(seed)
    w = _weight(dim)
    out: dict[str, list[np.ndarray]] = {}
    prev = []
    for i in range(width):
        x = init_chunk(rng.standard_normal((dim, dim)).astype(np.float64))
        out[f"init{i}"] = [x]
        prev.append(x)
    for d in range(depth):
        nxt = []
        for i in range(width):
            # note: graph binds the weight FIRST (bound inputs prepend), so
            # the chunkwise call is step(w, x) — mirror that order here
            y = step(w, prev[i])
            out[f"step{d}_{i}"] = [y]
            nxt.append(y)
        prev = nxt
    out["reduce"] = [reduce_sum(*[[p] for p in prev])]
    return out
