"""The paper's §4 validation workload: a parallel Jacobi solver for
``A·x = b`` built three ways.

1. ``jacobi_hypar``   — the paper's decomposition: job J1 computes the
   update sweep over row chunks, J2 applies updates + computes the
   residual, J3 (a control job) checks convergence and *re-enqueues*
   J1/J2 — the exact dynamic-job mechanism of paper §3.3.  Runs on the
   LocalExecutor (scheduler/worker dispatch cost included).
2. ``jacobi_tailored`` — the 'tailored MPI implementation' stand-in: a
   hand-written jitted ``lax.while_loop`` (zero framework overhead).
3. ``jacobi_spmd``     — beyond-paper: the HyPar iterative segment fused to
   one on-device ``while_loop`` by the SpmdExecutor (framework
   expressiveness at tailored speed).

All three variants use the **fused-residual sweep**: each iteration
performs exactly ONE ``A``-matvec — the residual it convergence-tests is
``‖b - A·x_{k-1}‖``, already resident in the sweep's accumulator, instead
of a second matvec against the fresh iterate (the sweep jobs J1 emit the
squared-residual partials alongside their x' rows and J2 only reduces
them).  The tested residual is therefore lagged by one iteration — for a
fixed-iteration run (the paper's setup, tol=0) the iterates are
identical, and the exact final residual is recomputed once outside the
timed loop for reporting.

Paper's claim (Fig. 3): the framework stays within ~10 % (mean) of the
tailored runtime at sizes 2709/4209/7209, 500 iterations.
``benchmarks/jacobi_paper.py`` reproduces that table.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ChunkedData, ChunkRef, DataChunk, FunctionRegistry,
                        Job, JobGraph, LocalExecutor, IterativeSpec,
                        SpmdExecutor, VirtualCluster)
from repro.kernels.jacobi_sweep.ops import jacobi_sweep_residual
from repro.kernels.runtime import on_tpu
from repro.kernels.tuning import calibrated_cost_params, get_tuner

__all__ = ["make_system", "jacobi_tailored", "jacobi_hypar", "jacobi_spmd",
           "JacobiResult"]


@dataclasses.dataclass
class JacobiResult:
    x: np.ndarray
    iters: int
    residual: float
    seconds: float
    extra: dict = dataclasses.field(default_factory=dict)


def make_system(n: int, seed: int = 0):
    """Diagonally-dominant dense system with a known solution."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((n, n)).astype(np.float32) / n
    np.fill_diagonal(A, 3.0)
    x_true = rng.standard_normal(n).astype(np.float32)
    b = (A @ x_true).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(b), x_true


# ---------------------------------------------------------------------------
# 1. tailored ("efficient MPI implementation" stand-in)
# ---------------------------------------------------------------------------


def jacobi_tailored(A, b, *, iters: int = 500, tol: float = 0.0,
                    kernel: bool = False) -> JacobiResult:
    diag = jnp.diag(A)

    def sweep(x):
        """(x', ‖b - A·x‖) in ONE matvec (fused-residual sweep)."""
        if kernel:
            return jacobi_sweep_residual(A, x, b, diag)
        r = b - A @ x
        return x + r / diag, jnp.linalg.norm(r)

    def cond(state):
        i, x, res = state
        return jnp.logical_and(i < iters, res > tol)

    def body(state):
        i, x, _ = state
        x2, res = sweep(x)          # res is ‖b - A·x‖, lagged one iteration
        return i + 1, x2, res

    run = jax.jit(lambda x0: jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), x0, jnp.asarray(jnp.inf))))
    x0 = jnp.zeros_like(b)
    run(x0)[1].block_until_ready()          # compile outside the timing
    t0 = time.perf_counter()
    i, x, _ = run(x0)
    x.block_until_ready()
    dt = time.perf_counter() - t0
    res = float(jnp.linalg.norm(b - A @ x))   # exact, outside the timed loop
    return JacobiResult(np.asarray(x), int(i), res, dt)


# ---------------------------------------------------------------------------
# 2. HyPar job graph (paper-faithful: J1 sweep, J2 residual, J3 dynamic)
# ---------------------------------------------------------------------------


def jacobi_hypar(A, b, *, iters: int = 500, tol: float = 0.0,
                 n_chunks: int = 4, cluster: VirtualCluster | None = None,
                 mode: str = "sync", strategy: str = "greedy") -> JacobiResult:
    n = b.shape[0]
    diag = jnp.diag(A)
    reg = FunctionRegistry()
    A_rows = ChunkedData.from_array(A, n_chunks)          # row-chunked A
    b_c = ChunkedData.from_array(b, n_chunks)
    d_c = ChunkedData.from_array(diag, n_chunks)
    bounds = np.cumsum([0] + [c.data.shape[0] for c in A_rows])

    # J1: one fused-residual sweep over a row chunk.  Row chunk i needs
    # x[rows_i] for the diagonal correction; the row offset is
    # closure-specialised per chunk.  Each sweep emits its x' rows AND the
    # chunk's squared-residual partial Σ(b_i - A_i·x)² — the residual of
    # the incoming iterate is free once A_i·x is computed, so J2 never
    # pays a second matvec.
    # Whole-fn contract: args are ChunkedData — cds[0] is the bound static
    # data (A_i, b_i, d_i [, x0]), cds[1] (if present) is R_{X_{k-1}}.
    # The array work is jitted (the paper's users register *compiled*
    # functions; eager per-op dispatch is not part of the framework cost).
    def make_sweep(lo, hi):
        @jax.jit
        def kernel(A_chunk, b_chunk, d_chunk, x_full):
            xi = jax.lax.dynamic_slice(x_full, (lo,), (hi - lo,))
            r = b_chunk - A_chunk @ x_full        # incl. the diagonal term
            return xi + r / d_chunk, jnp.sum(r * r)

        def sweep(*cds):
            st = cds[0]
            x_full = (cds[1].get_data_chunk(0).data if len(cds) > 1
                      else st.get_data_chunk(3).data)
            x2, rsq = kernel(st.get_data_chunk(0).data,
                             st.get_data_chunk(1).data,
                             st.get_data_chunk(2).data, x_full)
            return ChunkedData.from_arrays([x2, rsq])
        return sweep

    state = {"iter": 0}

    @jax.jit
    def _residual_kernel(xs, rsqs):
        # reduce the sweeps' partials: no matvec here — the residual is the
        # lagged ‖b - A·x_{k-1}‖ the sweeps computed for free
        return jnp.concatenate(xs), jnp.sqrt(jnp.sum(jnp.stack(rsqs)))

    def residual_fn(*cds):
        # one ChunkedData per sweep job; chunk 0 = x' rows, chunk 1 = Σr²
        x_new, res = _residual_kernel(
            [cd.get_data_chunk(0).data for cd in cds],
            [cd.get_data_chunk(1).data for cd in cds])
        return ChunkedData.from_arrays([x_new, res])

    reg.register("residual", residual_fn, kind="whole")

    def check_fn(cd, ctx):
        res = float(np.asarray(cd.get_data_chunk(1).data))
        state["res"] = res
        if res > tol and state["iter"] < iters - 1:
            state["iter"] += 1
            _enqueue_iteration(ctx)
        return cd

    reg.register("check", check_fn, kind="control")
    for i in range(n_chunks):
        reg.register(f"sweep{i}", make_sweep(int(bounds[i]), int(bounds[i + 1])),
                     kind="whole")

    graph = JobGraph()
    xc = ChunkedData.from_arrays([jnp.zeros_like(b)])

    def _sweep_jobs(k: int, x_ref: str | None):
        jobs = []
        for i in range(n_chunks):
            name = f"S{k}_{i}"
            inputs = (ChunkRef(x_ref),) if x_ref else ()
            rows = float(bounds[i + 1] - bounds[i])
            jobs.append(Job(name, f"sweep{i}", 0, inputs, no_send_back=True,
                            cost_hint=2.0 * rows * n))
        return jobs

    def _enqueue_iteration(ctx):
        k = state["iter"]
        seg = ctx.current_segment
        jobs = _sweep_jobs(k, f"X{k - 1}")
        for j in jobs:
            ctx.add_job(j, 1)
        ctx.add_job(Job(f"X{k}", "residual", 1,
                        tuple(ChunkRef(j.name) for j in jobs),
                        cost_hint=float(n)), 2)
        ctx.add_job(Job(f"C{k}", "check", 1, (ChunkRef(f"X{k}"),)), 3)

    # initial iteration 0 (bound inputs: A/b/diag per chunk + x0)
    jobs0 = _sweep_jobs(0, None)
    graph.add_segment(jobs0)
    for i, j in enumerate(jobs0):
        graph.bind_input(j.name, ChunkedData([
            A_rows.get_data_chunk(i), b_c.get_data_chunk(i),
            d_c.get_data_chunk(i), xc.get_data_chunk(0)]))
    graph.add_segment([Job("X0", "residual", 1,
                           tuple(ChunkRef(j.name) for j in jobs0),
                           cost_hint=float(n))])
    graph.add_segment([Job("C0", "check", 1, (ChunkRef("X0"),))])

    # tuned timings -> scheduler: seed the master's per-function time table
    # from the autotune cache (full-sweep time scaled by the chunk's row
    # share) and calibrate the cost model with observed kernel rates, so
    # strategy="cost" prices jobs with measurements instead of roofline
    # guesses (DESIGN.md §7).  Only on TPU: there the cache holds real
    # kernel timings; off-TPU it holds interpret-mode proxies, orders of
    # magnitude slower than the jitted jnp kernels these jobs execute.
    observed = {}
    if on_tpu():
        t_full = get_tuner().observed_s("jacobi_sweep", (n, n), b.dtype,
                                        nearest=True)
        if t_full is not None and t_full > 0:
            for i in range(n_chunks):
                rows = float(bounds[i + 1] - bounds[i])
                observed[f"sweep{i}"] = t_full * rows / n
    cost_params = (calibrated_cost_params() if strategy == "cost" and on_tpu()
                   else None)

    cluster = cluster or VirtualCluster(n_schedulers=1, max_workers=n_chunks)
    ex = LocalExecutor(cluster, reg, mode=mode, strategy=strategy,
                       cost_params=cost_params,
                       observed_fn_times=observed or None)

    # warm the jitted user kernels (compile outside the timed region, as for
    # the tailored baseline)
    x_w = jnp.zeros_like(b)
    parts = []
    for i in range(n_chunks):
        rf = reg[f"sweep{i}"]
        parts.append(rf.fn(ChunkedData([A_rows.get_data_chunk(i),
                                        b_c.get_data_chunk(i),
                                        d_c.get_data_chunk(i),
                                        DataChunk(x_w)])))
    _residual_kernel([p.get_data_chunk(0).data for p in parts],
                     [p.get_data_chunk(1).data for p in parts]
                     )[1].block_until_ready()

    # bind per-chunk static inputs for dynamically added sweep jobs as they
    # appear: the executor reads bound_inputs at dispatch; pre-bind for all
    # possible iterations lazily via a hook on add_dynamic
    orig_add = graph.add_dynamic

    def add_dynamic(job, seg_idx, *, current):
        orig_add(job, seg_idx, current=current)
        if job.fn and str(job.fn).startswith("sweep"):
            i = int(str(job.fn)[5:])
            graph.bind_input(job.name, ChunkedData([
                A_rows.get_data_chunk(i), b_c.get_data_chunk(i),
                d_c.get_data_chunk(i)]))
    graph.add_dynamic = add_dynamic

    t0 = time.perf_counter()
    results, report = ex.run(graph)
    dt = time.perf_counter() - t0
    k = state["iter"]
    x = np.asarray(results[f"X{k}"].get_data_chunk(0).data)
    res = float(jnp.linalg.norm(b - A @ jnp.asarray(x)))  # exact, untimed
    return JacobiResult(x, k + 1, res, dt,
                        extra={"report": report.summary(), "mode": mode,
                               "moved_bytes": report.moved_bytes})


# ---------------------------------------------------------------------------
# 3. SPMD-fused iterative segment (beyond-paper)
# ---------------------------------------------------------------------------


def jacobi_spmd(A, b, *, iters: int = 500, tol: float = 0.0,
                mesh=None) -> JacobiResult:
    diag = jnp.diag(A)

    def body(carry):
        x, _ = carry
        r = b - A @ x                 # the iteration's ONLY matvec
        return x + r / diag, jnp.linalg.norm(r)

    def cond(carry):
        return carry[1] > tol         # lagged residual ‖b - A·x_{k-1}‖

    if mesh is None:
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    ex = SpmdExecutor(mesh, FunctionRegistry())
    spec = IterativeSpec(body=lambda c: body(c), cond=cond, max_iters=iters)
    x0 = (jnp.zeros_like(b), jnp.asarray(jnp.inf))
    # warmup/compile
    ex.run_iterative(spec, x0)
    t0 = time.perf_counter()
    (x, _), n_it = ex.run_iterative(spec, x0)
    x.block_until_ready()
    dt = time.perf_counter() - t0
    res = float(jnp.linalg.norm(b - A @ x))   # exact, outside the timed loop
    return JacobiResult(np.asarray(x), n_it, res, dt)
