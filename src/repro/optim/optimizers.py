"""Sharded optimizers: AdamW and Adafactor (factored second moment).

AdamW keeps fp32 m/v (12 B/param of state with the fp32 master); Adafactor
factors the second moment over the last two dims (O(n+m) instead of O(nm))
and keeps no momentum by default — the T5X recipe that makes 405B-class
training fit 16 GB/chip meshes (see configs/llama3_405b.py).

Optimizer states inherit the parameter sharding (same logical axes), so
ZeRO-style partitioning falls out of the parameter PartitionSpecs for free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptimizerSpec", "init_opt_state", "opt_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    kind: str = "adamw"             # adamw | adafactor | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999               # adafactor: decay exponent base
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    # adafactor
    factored_min: int = 128         # factor dims only when both >= this
    # grad compression (beyond-paper distributed-optimisation trick):
    # gradients are cast to bf16 before the cross-replica reduction with an
    # fp32 error-feedback residual kept device-local.
    compress_grads: bool = False


def cosine_schedule(step, *, base_lr: float, warmup: int = 100,
                    total: int = 10_000, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _init_adamw(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def _update_adamw(spec, grads, state, params, lr):
    c = state["count"] + 1
    b1, b2 = spec.b1, spec.b2

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mh = m2 / (1 - b1 ** c.astype(jnp.float32))
        vh = v2 / (1 - b2 ** c.astype(jnp.float32))
        step = mh / (jnp.sqrt(vh) + spec.eps)
        if spec.weight_decay:
            step = step + spec.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "count": c}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------


def _factored(shape, min_size) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_size and shape[-2] >= min_size


def _init_adafactor(params, spec):
    def one(p):
        if _factored(p.shape, spec.factored_min):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(one, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "count": jnp.zeros((), jnp.int32)}


def _update_adafactor(spec, grads, state, params, lr):
    c = state["count"] + 1
    # time-dependent decay (Adafactor schedule)
    beta2 = 1.0 - c.astype(jnp.float32) ** -0.8

    def upd(g, st, p):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + 1e-30
        if "vr" in st:
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.mean(vr, axis=-1, keepdims=True) + 1e-30)
            cfac = jax.lax.rsqrt(vc + 1e-30)
            step = gf * rfac[..., None] * cfac[..., None, :]
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            step = gf * jax.lax.rsqrt(v + 1e-30)
            new_st = {"v": v}
        # update clipping (Adafactor's d=1.0 RMS clip)
        rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-30)
        step = step / jnp.maximum(1.0, rms)
        if spec.weight_decay:
            step = step + spec.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["f"])
    outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_f = treedef.unflatten([o[1] for o in outs])
    return new_p, {"f": new_f, "count": c}


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def init_opt_state(spec: OptimizerSpec, params):
    if spec.kind == "adamw":
        return _init_adamw(params)
    if spec.kind == "adafactor":
        return _init_adafactor(params, spec)
    if spec.kind == "sgd":
        return {"count": jnp.zeros((), jnp.int32)}
    raise ValueError(f"unknown optimizer {spec.kind}")


def opt_update(spec: OptimizerSpec, grads, state, params, lr=None):
    """Returns (new_params, new_state, metrics)."""
    lr = lr if lr is not None else spec.lr
    gnorm = global_norm(grads)
    if spec.clip_norm:
        grads, _ = clip_by_global_norm(grads, spec.clip_norm)
    if spec.kind == "adamw":
        new_p, new_s = _update_adamw(spec, grads, state, params, lr)
    elif spec.kind == "adafactor":
        new_p, new_s = _update_adafactor(spec, grads, state, params, lr)
    elif spec.kind == "sgd":
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        new_s = {"count": state["count"] + 1}
    else:
        raise ValueError(spec.kind)
    return new_p, new_s, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
