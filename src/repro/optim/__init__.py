from .optimizers import (OptimizerSpec, init_opt_state, opt_update,
                         cosine_schedule, global_norm, clip_by_global_norm)
