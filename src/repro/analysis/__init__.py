from .hlo import HloAnalysis, analyze_hlo
from .roofline import RooflineTerms, roofline_from_compiled, V5E
