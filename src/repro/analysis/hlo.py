"""Post-optimisation HLO text analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so for
scan-over-layers models the reported FLOPs/bytes are ~1/L of the truth.
This module parses ``compiled.as_text()`` into computations, attributes
FLOPs (dot / matmul custom-calls), an HBM-traffic estimate and collective
bytes per computation, then walks the call graph multiplying ``while``
bodies by their trip counts (parsed from the loop-bound constant in the
condition computation, overridable).

Parsing details handled: operands are name references (shapes resolved via
a per-computation symbol table, HLO is SSA); tuple-typed ops (while);
CPU-backend oneDNN/dot custom-calls counted as matmuls.

Validated against ``cost_analysis()`` on unrolled models
(tests/test_analysis.py): dot FLOPs match exactly; the traffic estimate is
an upper-bound model (every materialising op reads operands / writes
output to HBM) that is *consistent* across perf iterations, which is what
hillclimbing needs.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

__all__ = ["HloAnalysis", "analyze_hlo", "CollectiveStats", "xla_cost_analysis"]


def xla_cost_analysis(compiled) -> dict:
    """Normalise ``jax.stages.Compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return a list with one dict per partition; newer ones
    return the dict directly.  Returns ``{}`` when unavailable.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "copy-start", "copy-done",
}

_MATMUL_CC = ("matmul", "dot", "gemm", "cublas", "onednn")


def _shape_bytes(type_str: str) -> int:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += b * n
    return int(total)


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_type: str
    operand_names: list[str]
    attrs: str

    out_bytes: int = 0
    in_bytes: int = 0
    flops: float = 0.0
    calls: list[str] = dataclasses.field(default_factory=list)
    body: str | None = None
    cond: str | None = None


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpInfo] = dataclasses.field(default_factory=list)
    types: dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    bytes_by_kind: dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


@dataclasses.dataclass
class HloAnalysis:
    flops: float
    traffic_bytes: float
    collectives: CollectiveStats
    while_trips: dict[str, int]
    by_computation: dict[str, dict]
    matmul_flops: float = 0.0

    def summary(self) -> str:
        c = self.collectives
        return (f"flops={self.flops:.3e} traffic={self.traffic_bytes:.3e}B "
                f"collective={c.total_bytes:.3e}B "
                + " ".join(f"{k}:{v}" for k, v in c.counts.items() if v))


def _split_type_rest(rhs: str) -> tuple[str, str]:
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[:i + 1], rhs[i + 1:].strip()
    sp = rhs.find(" ")
    if sp < 0:
        return rhs, ""
    return rhs[:sp], rhs[sp + 1:].strip()


def _parse_op(line: str) -> OpInfo | None:
    line = line.strip().rstrip(",")
    m = re.match(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$", line)
    if not m:
        return None
    name, rhs = m.groups()
    out_type, rest = _split_type_rest(rhs)
    om = re.match(r"^([\w\-]+)\((.*)$", rest)
    if not om:
        return None
    opcode, tail = om.groups()
    depth, i = 1, 0
    while i < len(tail) and depth:
        if tail[i] == "(":
            depth += 1
        elif tail[i] == ")":
            depth -= 1
        i += 1
    operands, attrs = tail[:i - 1], tail[i:]
    info = OpInfo(name=name, opcode=opcode, out_type=out_type,
                  operand_names=_OPERAND_NAME_RE.findall(operands),
                  attrs=attrs)
    info.out_bytes = _shape_bytes(out_type)
    cm = _CALLS_RE.search(attrs)
    if cm:
        info.calls.append(cm.group(1))
    bm = _BODY_RE.search(attrs)
    if bm:
        info.body = bm.group(1)
    cm2 = _COND_RE.search(attrs)
    if cm2:
        info.cond = cm2.group(1)
    if opcode == "constant":
        info.attrs = "constant(" + operands + ")" + attrs
    return info


def _dot_flops(op: OpInfo, types: dict[str, str]) -> float:
    out_n = math.prod(_shape_dims(op.out_type)) if _shape_dims(op.out_type) else 1
    m = _CONTRACT_RE.search(op.attrs)
    contract = 1
    lhs_type = types.get(op.operand_names[0], "") if op.operand_names else ""
    lhs_dims = _shape_dims(lhs_type)
    if m:
        idxs = [int(i) for i in m.group(1).split(",")] if m.group(1) else []
        for i in idxs:
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    elif lhs_dims:
        contract = lhs_dims[-1]
    return 2.0 * out_n * contract


def _cc_matmul_flops(op: OpInfo, types: dict[str, str]) -> float:
    """Matmul-ish custom-call (oneDNN on CPU, cublas on GPU): out (.., M, N),
    lhs (.., M, K) => 2·prod(out)·K."""
    out_dims = _shape_dims(op.out_type)
    if not op.operand_names:
        return 0.0
    lhs_dims = _shape_dims(types.get(op.operand_names[0], ""))
    if not out_dims or not lhs_dims:
        return 0.0
    return 2.0 * math.prod(out_dims) * lhs_dims[-1]


def _parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = ""
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped or
                                           stripped.startswith("ENTRY")):
                m = _COMP_HEAD_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1))
                    if stripped.startswith("ENTRY"):
                        entry = m.group(1)
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op(stripped)
        if op is None:
            continue
        cur.ops.append(op)
        cur.types[op.name] = op.out_type
    if cur is not None:
        comps[cur.name] = cur
    if not entry and comps:
        entry = list(comps)[-1]
    return comps, entry


def _finalize_ops(comp: Computation) -> None:
    for op in comp.ops:
        op.in_bytes = sum(_shape_bytes(comp.types.get(n, ""))
                          for n in op.operand_names)
        if op.opcode == "dot":
            op.flops = _dot_flops(op, comp.types)
        elif op.opcode == "custom-call" and any(
                t in op.attrs.lower() for t in _MATMUL_CC):
            op.flops = _cc_matmul_flops(op, comp.types)
        elif op.opcode == "convolution":
            # flops = 2 * out_elems * (in_channels/feature_group * prod(kernel_spatial))
            out_n = math.prod(_shape_dims(op.out_type) or [0])
            rhs_dims = _shape_dims(comp.types.get(op.operand_names[1], "")) \
                if len(op.operand_names) > 1 else []
            k = math.prod(rhs_dims[:-1]) if rhs_dims else 0
            op.flops = 2.0 * out_n * k


def _trip_count(cond_comp: Computation | None, default: int) -> int:
    """Loop bound: the largest integer constant in the condition
    computation.  Exact for lax.scan-lowered loops."""
    if cond_comp is None:
        return default
    consts = []
    for op in cond_comp.ops:
        consts += [int(x) for x in _CONST_RE.findall(op.attrs)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else default


def analyze_hlo(text: str, *, default_trips: int = 1,
                trip_overrides: dict[str, int] | None = None) -> HloAnalysis:
    comps, entry = _parse_computations(text)
    for comp in comps.values():
        _finalize_ops(comp)
    trip_overrides = trip_overrides or {}

    # multipliers: walk the call graph from ENTRY.  ``hbm`` marks whether a
    # computation's ops materialise buffers (while bodies: yes; fusion /
    # reduce-apply bodies: no — their traffic is charged at the call site).
    mult: dict[str, float] = {}
    hbm_mult: dict[str, float] = {}
    while_trips: dict[str, int] = {}
    visiting: set[str] = set()

    def visit(name: str, m: float, hbm: bool):
        if name not in comps or name in visiting:
            return
        visiting.add(name)
        mult[name] = mult.get(name, 0.0) + m
        if hbm:
            hbm_mult[name] = hbm_mult.get(name, 0.0) + m
        for op in comps[name].ops:
            if op.opcode == "while":
                trips = trip_overrides.get(op.body or "",
                                           trip_overrides.get(op.name, None))
                if trips is None:
                    trips = _trip_count(comps.get(op.cond or ""), default_trips)
                if op.body:
                    while_trips[op.body] = trips
                    visit(op.body, m * trips, hbm)
                if op.cond:
                    visit(op.cond, m * (trips + 1), False)
            elif op.opcode == "conditional":
                for callee in op.calls:
                    visit(callee, m, hbm)
            else:
                for callee in op.calls:
                    visit(callee, m, False)
        visiting.discard(name)

    visit(entry, 1.0, True)

    flops = 0.0
    matmul_flops = 0.0
    traffic = 0.0
    coll_counts = {k: 0 for k in COLLECTIVES}
    coll_bytes = {k: 0.0 for k in COLLECTIVES}
    by_comp: dict[str, dict] = {}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        hm = hbm_mult.get(name, 0.0)
        if m == 0.0:
            continue
        cflops = 0.0
        cmm = 0.0
        ctraffic = 0.0
        for op in comp.ops:
            cflops += op.flops
            if op.opcode in ("dot", "convolution") or (
                    op.opcode == "custom-call" and op.flops):
                cmm += op.flops
            if op.opcode in _SKIP_TRAFFIC_OPS or op.opcode == "while":
                continue
            if op.opcode == "dynamic-slice":
                # reads only the sliced region (+ writes it)
                ctraffic += 2 * op.out_bytes
            elif op.opcode == "dynamic-update-slice":
                # in-place read-modify-write of the touched region only
                # (XLA aliases the buffer inside while loops); the update
                # operand is the second operand
                upd = (_shape_bytes(comp.types.get(op.operand_names[1], ""))
                       if len(op.operand_names) > 1 else op.out_bytes)
                ctraffic += 2 * upd
            elif op.opcode in ("gather", "scatter"):
                small = min(op.out_bytes, op.in_bytes)
                ctraffic += 2 * small
            else:
                ctraffic += op.in_bytes + op.out_bytes
            if op.opcode in COLLECTIVES:
                coll_counts[op.opcode] += max(int(m), 1 if m > 0 else 0)
                if op.opcode == "all-reduce":
                    b = 2.0 * op.in_bytes
                elif op.opcode == "all-gather":
                    b = float(op.out_bytes)
                else:
                    b = float(op.in_bytes)
                coll_bytes[op.opcode] += m * b
        flops += m * cflops
        matmul_flops += m * cmm
        traffic += hm * ctraffic
        by_comp[name] = {"mult": m, "hbm_mult": hm, "flops": cflops,
                         "traffic": ctraffic}

    return HloAnalysis(flops=flops, traffic_bytes=traffic,
                       collectives=CollectiveStats(coll_counts, coll_bytes),
                       while_trips=while_trips, by_computation=by_comp,
                       matmul_flops=matmul_flops)
