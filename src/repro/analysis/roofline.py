"""Three-term roofline model from a compiled dry-run artifact.

    compute_s    = HLO_FLOPs / (chips x peak_FLOPs)
    memory_s     = HLO_bytes / (chips x HBM_bw)
    collective_s = collective_bytes / (chips x link_bw)

HLO terms come from the HLO-text analyzer (scan-corrected, per-device after
SPMD partitioning: as_text() of a partitioned module reports per-device
shapes, so terms are divided by ONE chip's peaks, not the fleet's).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from .hlo import HloAnalysis, analyze_hlo

__all__ = ["HW", "V5E", "RooflineTerms", "roofline_from_compiled"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # B/s per chip
    ici_bw: float              # B/s per link
    hbm_bytes: float           # capacity per chip


V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
         hbm_bytes=16 * 2**30)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    traffic_bytes: float
    collective_bytes: float
    model_flops: float = 0.0
    analysis: Any = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-per-chip / peak, achieved at the bound step time —
        i.e. projected MFU if the dominant term is the only limiter."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops_per_chip / self.step_s) / self._hw.peak_flops

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "traffic_bytes": self.traffic_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
        }


def roofline_from_compiled(compiled, *, hw: HW = V5E, n_chips: int,
                           model_flops: float = 0.0,
                           trip_overrides: dict | None = None,
                           default_trips: int = 1) -> RooflineTerms:
    """``compiled``: jax.stages.Compiled for an SPMD-partitioned module.

    The partitioned HLO is per-device, so terms use single-chip peaks;
    ``model_flops`` is the GLOBAL useful-FLOPs figure (6·N·D etc.) and is
    divided by ``n_chips`` for the per-chip fraction.
    """
    text = compiled.as_text()
    an = analyze_hlo(text, default_trips=default_trips,
                     trip_overrides=trip_overrides)
    terms = RooflineTerms(
        compute_s=an.flops / hw.peak_flops,
        memory_s=an.traffic_bytes / hw.hbm_bw,
        collective_s=an.collectives.total_bytes / hw.ici_bw,
        flops=an.flops,
        traffic_bytes=an.traffic_bytes,
        collective_bytes=an.collectives.total_bytes,
        model_flops=model_flops,
        analysis=an,
    )
    terms._hw = hw
    terms.model_flops_per_chip = model_flops / max(n_chips, 1)
    return terms
