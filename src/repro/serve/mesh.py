"""Serve-side device mesh + paged-cache sharding (DESIGN.md §13).

One :class:`~repro.serve.engine.PagedEngine` instance spans a device mesh
``(dp, tp)`` with axes ``("data", "model")``:

* **tensor parallel** (``"model"`` axis) — the KV page pools shard over
  their ``kv_heads`` axis, so one model replica's decode splits per-KV-head
  attention across devices (GQA groups are device-local; only the output
  projection reduces across the axis).  Params stay replicated — this is
  honest TP of the cache + compute, not model replication.
* **data parallel** (``"data"`` axis) — batch slots and the page pool
  partition over device groups.  Each group owns a contiguous slot range
  and a private page range behind its own ``PageAllocator``
  (:class:`~repro.serve.scheduler.DeviceGroup`), so allocation, prefix
  caching, COW and preemption never cross a group boundary.

The constraints themselves live in the model code as ``logical(...)``
annotations (``gather_pages``, ``_write_kv_paged``/``_write_kv_chunk_paged``)
that are no-ops outside a ``use_rules`` context — single-device serving
compiles byte-identical HLO to before.  A mesh of total size 1 resolves
every rule to a trivial (fully-replicated) spec, so mesh==1 is bit-identical
to mesh==None by construction (asserted in tests/test_serve_sharded.py).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import compat_make_mesh
from repro.parallel.sharding import DEFAULT_RULES, ShardingRules

__all__ = ["MeshSpec", "build_serve_mesh", "serve_rules", "shard_paged_cache",
           "per_device_pool_bytes"]


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Parsed ``--mesh TP,DP``: tensor-parallel × data-parallel extents."""

    tp: int = 1
    dp: int = 1

    @property
    def size(self) -> int:
        return self.tp * self.dp

    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        parts = [p.strip() for p in str(text).split(",")]
        if len(parts) != 2:
            raise ValueError(f"--mesh wants 'TP,DP' (e.g. '2,1'), got "
                             f"{text!r}")
        try:
            tp, dp = int(parts[0]), int(parts[1])
        except ValueError:
            raise ValueError(f"--mesh wants two integers 'TP,DP', got "
                             f"{text!r}") from None
        if tp < 1 or dp < 1:
            raise ValueError(f"mesh extents must be >= 1, got tp={tp} dp={dp}")
        return cls(tp=tp, dp=dp)


def build_serve_mesh(spec: MeshSpec) -> Mesh:
    """Mesh ``(dp, tp)`` over axes ``("data", "model")`` — the same axis
    names training uses, so ``DEFAULT_RULES`` applies unchanged."""
    n_dev = len(jax.devices())
    if spec.size > n_dev:
        raise ValueError(
            f"mesh {spec.tp}x{spec.dp} needs {spec.size} devices, "
            f"{n_dev} visible — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={spec.size} "
            f"before jax initialises")
    return compat_make_mesh((spec.dp, spec.tp), ("data", "model"))


def serve_rules() -> dict:
    """Logical-axis rules for serving: DEFAULT_RULES already carries the
    serve axes (``kv_heads`` -> model, ``slots``/``pages`` -> data)."""
    return dict(DEFAULT_RULES)


def _put(x, rules: ShardingRules, names):
    return jax.device_put(
        x, NamedSharding(rules.mesh, rules.spec_for(names, x.shape)))


def _shard_block(bc, rules: ShardingRules, *, stacked: bool):
    """Shard one block's cache leaves.  Attention pools ``(…, P, KV, ps, D)``
    shard pages->data, kv_heads->model; SSM blocks are dense per-slot state
    whose batch axis shards slots->data.  ``stacked`` prepends a group axis."""
    lead = [None] if stacked else []
    if isinstance(bc, dict) and "self" in bc:
        names = lead + ["pages", "kv_heads", None, None]
        return {**bc, "self": {k: _put(v, rules, names)
                               for k, v in bc["self"].items()}}

    def ssm_leaf(x):
        ax = 1 if stacked else 0
        names = [None] * x.ndim
        if x.ndim > ax:
            names[ax] = "slots"
        return _put(x, rules, names)

    return jax.tree.map(ssm_leaf, bc)


def shard_paged_cache(cache, rules: ShardingRules):
    """Place an ``init_paged_cache`` tree onto the mesh.  Non-dividing axes
    (odd page counts, kv_heads < tp) fall back to replication leaf-by-leaf
    — ``spec_for`` drops them — so this never fails, it just shards less."""
    return {
        "groups": [_shard_block(bc, rules, stacked=True)
                   for bc in cache["groups"]],
        "tail": [_shard_block(bc, rules, stacked=False)
                 for bc in cache["tail"]],
        "len": _put(cache["len"], rules, ["slots"]),
    }


def per_device_pool_bytes(cache) -> int:
    """Max bytes of attention page pool resident on any one device — the
    per-device KV budget the ``serve_sharded`` BENCH row compares (TP=2
    halves it when kv_heads divides; 1 device == total pool bytes)."""
    per_dev: dict = {}
    for part in ("groups", "tail"):
        for bc in cache[part]:
            if not (isinstance(bc, dict) and "self" in bc):
                continue
            for arr in bc["self"].values():
                for sh in arr.addressable_shards:
                    per_dev[sh.device] = (per_dev.get(sh.device, 0)
                                          + sh.data.nbytes)
    return max(per_dev.values()) if per_dev else 0
