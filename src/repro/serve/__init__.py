from .engine import Engine, SamplingParams, count_generated
from .scheduler import (DEFAULT_BUCKETS, HyParRequestTracker, Request,
                        RequestQueue, RequestResult, ServeScheduler,
                        SlotState)

__all__ = [
    "Engine", "SamplingParams", "count_generated",
    "Request", "RequestResult", "RequestQueue", "SlotState",
    "ServeScheduler", "HyParRequestTracker", "DEFAULT_BUCKETS",
]
