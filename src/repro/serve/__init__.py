from .engine import (Engine, PagedEngine, SamplingParams, chunk_buckets_for,
                     chunk_plan, count_generated)
from .prefix import PrefixCache
from .scheduler import (DEFAULT_BUCKETS, CostModelParams, DeviceGroup,
                        HyParRequestTracker, PageAllocator, Request,
                        RequestQueue, RequestResult, ServeScheduler,
                        SlotState)

__all__ = [
    "Engine", "PagedEngine", "SamplingParams", "count_generated",
    "chunk_plan", "chunk_buckets_for",
    "Request", "RequestResult", "RequestQueue", "SlotState",
    "ServeScheduler", "HyParRequestTracker", "PageAllocator", "PrefixCache",
    "DeviceGroup", "CostModelParams", "DEFAULT_BUCKETS",
]
