from .engine import Engine, SamplingParams
