from .engine import (Engine, PagedEngine, SamplingParams, chunk_buckets_for,
                     chunk_plan, count_generated)
from .prefix import PrefixCache
from .scheduler import (DEFAULT_BUCKETS, HyParRequestTracker, PageAllocator,
                        Request, RequestQueue, RequestResult, ServeScheduler,
                        SlotState)

__all__ = [
    "Engine", "PagedEngine", "SamplingParams", "count_generated",
    "chunk_plan", "chunk_buckets_for",
    "Request", "RequestResult", "RequestQueue", "SlotState",
    "ServeScheduler", "HyParRequestTracker", "PageAllocator", "PrefixCache",
    "DEFAULT_BUCKETS",
]
