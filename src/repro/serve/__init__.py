from .engine import (Engine, PagedEngine, SamplingParams, chunk_buckets_for,
                     chunk_plan, count_generated)
from .prefix import PrefixCache
from .scheduler import (DEFAULT_BUCKETS, TERMINAL_OUTCOMES, CostModelParams,
                        DeviceGroup, HyParRequestTracker, PageAllocator,
                        Request, RequestOutcome, RequestQueue, RequestResult,
                        ServeScheduler, SlotState)

__all__ = [
    "Engine", "PagedEngine", "SamplingParams", "count_generated",
    "chunk_plan", "chunk_buckets_for",
    "Request", "RequestResult", "RequestOutcome", "TERMINAL_OUTCOMES",
    "RequestQueue", "SlotState",
    "ServeScheduler", "HyParRequestTracker", "PageAllocator", "PrefixCache",
    "DeviceGroup", "CostModelParams", "DEFAULT_BUCKETS",
]
