"""Batched serving engine: prefill + decode with slot-based continuous
batching.

The decode fleet is the HyPar picture one level up (DESIGN.md §4, §8): each
*slot* is a job whose KV cache is retained device-local (``no_send_back``);
a finished request frees its slot and a waiting request is prefilled into
it (``insert``), without disturbing the other slots — dynamic job creation
at serving time.  The request-level scheduler that drives this lives in
``repro.serve.scheduler``.

Compilation contract: the engine owns exactly three jitted programs —
batched prefill, single-step decode, and the slot splice — each compiled
once per input-shape signature and reused for every request.  Slot
insertion reuses the *same* prefill program at the ``(1, S)`` signature, so
N inserts of same-length (bucketed) prompts cost one compilation total.
``trace_count(name)`` exposes the per-program trace counters the
compile-counter test asserts on.

Per-slot positions: after the first prefill the cache ``len`` is a ``(B,)``
vector, one length per slot, so a short prompt inserted into a batch that
has already decoded far ahead attends, RoPEs, and writes its KV at *its
own* position rather than the global cache length.  The vector form is
kept even while all slots are uniform — deliberately: interrupted and
uninterrupted batches then run the SAME compiled decode program, which is
what makes surviving slots bit-identical under continuous batching.  The
cost is one vmapped KV-write slice per slot instead of one batched slice;
raw ``decode_step`` users (training, parity tests) keep the scalar path.

Sharding comes from the ambient ``use_rules`` context: the KV cache batch
axis maps to ("pod","data"), the KV sequence axis to "model"
(flash-decoding with all-reduce softmax merges; long_500k shards sequence
over every axis).
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache, layer_plan
from repro.models.layers import apply_norm
from repro.models.transformer import _run_stack  # encoder reuse

__all__ = ["Engine", "SamplingParams", "count_generated"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => no top-k filter
    stop_token: int = -1           # -1 => never stop early


def count_generated(out: np.ndarray, stop_token: int) -> int:
    """Real generated tokens in a ``generate`` result: stop-token padding
    rows emitted after a sequence terminated do not count (the first stop
    token itself does — the model produced it)."""
    out = np.asarray(out)
    if stop_token < 0:
        return int(out.size)
    total = 0
    for row in out:
        hits = np.flatnonzero(row == stop_token)
        total += int(hits[0]) + 1 if hits.size else row.size
    return total


class Engine:
    """Owns jitted prefill/decode/splice programs for one model + max_len."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int, max_len: int,
                 donate_cache: bool = True):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self._trace_counts: collections.Counter = collections.Counter()

        def _prefill(params, cache, tokens, embeds, enc_embeds, last_idx):
            self._trace_counts["prefill"] += 1
            enc_out = None
            if cfg.family == "encdec":
                plan = layer_plan(cfg)
                e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
                e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
                e, _ = _run_stack(cfg, plan.enc_pattern,
                                  tuple(params["enc_groups"]), (), (), None,
                                  e, jnp.arange(e.shape[1]))
                enc_out = apply_norm(cfg, params["enc_norm_f"], e)
            logits, cache = decode_step(cfg, params, cache, tokens,
                                        enc_out=enc_out, embeds=embeds)
            # logits at the *true* last prompt token (bucketed prompts are
            # right-padded; the pad tail must not pick the sampled logits)
            last = jax.lax.dynamic_slice_in_dim(logits, last_idx, 1, axis=1)
            B = (tokens if tokens is not None else embeds).shape[0]
            cache = {**cache, "len": jnp.broadcast_to(cache["len"], (B,))}
            return last, cache, enc_out

        def _decode(params, cache, tokens, enc_out):
            self._trace_counts["decode"] += 1
            return decode_step(cfg, params, cache, tokens, enc_out=enc_out)

        def _splice(cache, mini_cache, enc_out, mini_enc, slot, true_len):
            self._trace_counts["splice"] += 1
            new_groups = [
                jax.tree.map(lambda f, o: _splice_batch(f, o, slot), gf, go)
                for gf, go in zip(cache["groups"], mini_cache["groups"])]
            new_tail = [
                jax.tree.map(lambda f, o: _splice_batch(f, o, slot), tf, to)
                for tf, to in zip(cache["tail"], mini_cache["tail"])]
            lens = jnp.broadcast_to(jnp.asarray(cache["len"]), (batch,))
            lens = lens.at[slot].set(true_len)
            new_enc = enc_out
            if enc_out is not None:
                new_enc = jax.lax.dynamic_update_slice_in_dim(
                    enc_out, mini_enc.astype(enc_out.dtype), slot, axis=0)
            return ({"groups": new_groups, "tail": new_tail, "len": lens},
                    new_enc)

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._splice = jax.jit(_splice,
                               donate_argnums=(0,) if donate_cache else ())
        enc_len = 1 if cfg.family == "encdec" else 0
        self._fresh_b1 = jax.jit(
            functools.partial(init_cache, cfg, 1, max_len, enc_len=enc_len))
        self._enc_out = None
        self.cache = None

    def trace_count(self, name: str) -> int:
        """How many times program ``name`` (prefill|decode|splice) has been
        traced (= compiled signatures) so far."""
        return self._trace_counts[name]

    # -- lifecycle -------------------------------------------------------------
    def fresh_cache(self):
        enc_len = 0
        if self.cfg.family == "encdec":
            enc_len = 1  # cross K/V recomputed from enc_out, no cache needed
        return init_cache(self.cfg, self.batch, self.max_len, enc_len=enc_len)

    def ensure_batch(self, *, enc_len: int | None = None) -> None:
        """Initialise an empty live batch (all slots free, zero lengths) so
        insert-driven serving can start without a full-batch prefill.  For
        encdec models ``enc_len`` sizes the encoder-output buffer the per-slot
        inserts splice into."""
        if self.cache is None:
            cache = self.fresh_cache()
            cache["len"] = jnp.zeros((self.batch,), jnp.int32)
            self.cache = cache
        if self.cfg.family == "encdec" and self._enc_out is None:
            if enc_len is None:
                raise ValueError("encdec ensure_batch() needs enc_len to size "
                                 "the encoder-output buffer")
            self._enc_out = jnp.zeros(
                (self.batch, enc_len, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))

    def prefill(self, tokens=None, *, embeds=None, enc_embeds=None):
        """tokens: (batch, S). Returns last-position logits (batch, 1, V)."""
        self.cache = self.fresh_cache()
        S = (tokens if tokens is not None else embeds).shape[1]
        logits, self.cache, self._enc_out = self._prefill(
            self.params, self.cache, tokens, embeds, enc_embeds, S - 1)
        return logits

    def decode(self, tokens):
        """tokens: (batch, 1) — one step for every slot."""
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          self._enc_out)
        return logits

    # -- sampling ----------------------------------------------------------------
    @staticmethod
    @functools.partial(jax.jit, static_argnames=("sp",))
    def _sample(logits, key, sp: SamplingParams):
        lg = logits[:, -1, :].astype(jnp.float32)
        if sp.top_k:
            thresh = jax.lax.top_k(lg, sp.top_k)[0][:, -1:]
            lg = jnp.where(lg < thresh, -jnp.inf, lg)
        if sp.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / sp.temperature).astype(jnp.int32)

    def generate(self, tokens, *, max_new: int, sp: SamplingParams = SamplingParams(),
                 key=None, enc_embeds=None) -> np.ndarray:
        """Greedy/temperature generation for a full batch.  Returns
        (batch, max_new) generated ids (stop_token-padded after stop)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits = self.prefill(tokens, enc_embeds=enc_embeds)
        out = []
        done = np.zeros((tokens.shape[0],), bool)
        cur = None
        for i in range(max_new):
            key, sub = jax.random.split(key)
            cur = self._sample(logits, sub, sp)
            ids = np.asarray(cur)
            if sp.stop_token >= 0:
                ids = np.where(done, sp.stop_token, ids)
                done |= ids == sp.stop_token
            out.append(ids)
            if done.all():
                out.extend([np.full_like(ids, sp.stop_token)] *
                           (max_new - len(out)))
                break
            logits = self.decode(jnp.asarray(ids)[:, None])
        return np.stack(out, axis=1)

    # -- continuous batching -----------------------------------------------------
    def insert(self, slot: int, tokens_1xS, *, true_len: int | None = None,
               enc_embeds=None):
        """Prefill a single request into slot ``slot`` without disturbing the
        other slots (slot-local cache splice).

        ``tokens_1xS`` may be right-padded to a bucket length; ``true_len``
        is the unpadded prompt length (defaults to the full width).  The
        slot's cache length is set to ``true_len`` so subsequent decode
        steps position, mask, and write at the request's own offset.

        Returns the logits of the true last prompt token, (1, 1, V), so the
        caller can sample the request's first generated token immediately
        (time-to-first-token is the prefill, not the next batch step).
        """
        if self.cache is None:
            raise RuntimeError("insert() needs a live batch; call prefill() "
                               "first")
        S = tokens_1xS.shape[1]
        true_len = S if true_len is None else int(true_len)
        if not 0 < true_len <= S:
            raise ValueError(f"true_len {true_len} outside (0, {S}]")
        if not 0 <= slot < self.batch:
            raise ValueError(f"slot {slot} outside [0, {self.batch})")
        if self.cfg.family == "encdec":
            if enc_embeds is None:
                raise ValueError(
                    "inserting into an encdec engine requires enc_embeds — "
                    "the slot's encoder output must be spliced alongside its "
                    "KV cache")
            if self._enc_out is None:
                raise RuntimeError("encdec insert() needs a live batch with "
                                   "encoder output; call prefill() first")
            if enc_embeds.shape[1] != self._enc_out.shape[1]:
                raise ValueError(
                    f"enc_embeds length {enc_embeds.shape[1]} != batch "
                    f"encoder length {self._enc_out.shape[1]}")
        logits, mini_cache, mini_enc = self._prefill(
            self.params, self._fresh_b1(), tokens_1xS, None, enc_embeds,
            true_len - 1)
        self.cache, self._enc_out = self._splice(
            self.cache, mini_cache, self._enc_out, mini_enc, slot, true_len)
        return logits


def _splice_batch(full, one, slot):
    """Insert ``one`` (batch=1 leaf) into ``full`` at batch index ``slot``.
    Cache leaves have batch as the first axis after the optional group axis."""
    if full.ndim == one.ndim and full.shape == one.shape:
        return full  # scalar bookkeeping leaves
    # group-stacked leaves: (G, B, ...) vs (G, 1, ...)
    if full.ndim >= 2 and one.shape[0] == full.shape[0] and one.shape[1] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)
    if one.shape[0] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)
    return full
