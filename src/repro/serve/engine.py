"""Batched serving engine: prefill + decode with slot-based continuous
batching.

The decode fleet is the HyPar picture one level up (DESIGN.md §4, §8): each
*slot* is a job whose KV cache is retained device-local (``no_send_back``);
a finished request frees its slot and a waiting request is prefilled into
it (``insert``), without disturbing the other slots — dynamic job creation
at serving time.  The request-level scheduler that drives this lives in
``repro.serve.scheduler``.

Compilation contract: the engine owns exactly three jitted programs —
batched prefill, single-step decode, and the slot splice — each compiled
once per input-shape signature and reused for every request.  Slot
insertion reuses the *same* prefill program at the ``(1, S)`` signature, so
N inserts of same-length (bucketed) prompts cost one compilation total.
``trace_count(name)`` exposes the per-program trace counters the
compile-counter test asserts on.

Per-slot positions: after the first prefill the cache ``len`` is a ``(B,)``
vector, one length per slot, so a short prompt inserted into a batch that
has already decoded far ahead attends, RoPEs, and writes its KV at *its
own* position rather than the global cache length.  The vector form is
kept even while all slots are uniform — deliberately: interrupted and
uninterrupted batches then run the SAME compiled decode program, which is
what makes surviving slots bit-identical under continuous batching.  The
cost is one vmapped KV-write slice per slot instead of one batched slice;
raw ``decode_step`` users (training, parity tests) keep the scalar path.

Sharding comes from the ambient ``use_rules`` context: the KV cache batch
axis maps to ("pod","data"), the KV sequence axis to "model"
(flash-decoding with all-reduce softmax merges; long_500k shards sequence
over every axis).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingRules, use_rules
from repro.models.transformer import (chunk_prefill_step, decode_step,
                                      init_cache, init_paged_cache,
                                      layer_plan)
from repro.models.layers import apply_norm
from repro.models.transformer import _run_stack  # encoder reuse

__all__ = ["Engine", "PagedEngine", "SamplingParams", "count_generated",
           "chunk_plan", "chunk_buckets_for"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => no top-k filter
    stop_token: int = -1           # -1 => never stop early


def count_generated(out: np.ndarray, stop_token: int) -> int:
    """Real generated tokens in a ``generate`` result: stop-token padding
    rows emitted after a sequence terminated do not count (the first stop
    token itself does — the model produced it)."""
    out = np.asarray(out)
    if stop_token < 0:
        return int(out.size)
    total = 0
    for row in out:
        hits = np.flatnonzero(row == stop_token)
        total += int(hits[0]) + 1 if hits.size else row.size
    return total


class Engine:
    """Owns jitted prefill/decode/splice programs for one model + max_len."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int, max_len: int,
                 donate_cache: bool = True):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self._trace_counts: collections.Counter = collections.Counter()

        def _prefill(params, cache, tokens, embeds, enc_embeds, last_idx):
            self._trace_counts["prefill"] += 1
            enc_out = None
            if cfg.family == "encdec":
                plan = layer_plan(cfg)
                e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
                e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
                e, _ = _run_stack(cfg, plan.enc_pattern,
                                  tuple(params["enc_groups"]), (), (), None,
                                  e, jnp.arange(e.shape[1]))
                enc_out = apply_norm(cfg, params["enc_norm_f"], e)
            logits, cache = decode_step(cfg, params, cache, tokens,
                                        enc_out=enc_out, embeds=embeds,
                                        valid_len=last_idx + 1)
            # logits at the *true* last prompt token (bucketed prompts are
            # right-padded; the pad tail must not pick the sampled logits —
            # and for SSM layers the pad must not decay into the state)
            last = jax.lax.dynamic_slice_in_dim(logits, last_idx, 1, axis=1)
            B = (tokens if tokens is not None else embeds).shape[0]
            cache = {**cache, "len": jnp.broadcast_to(cache["len"], (B,))}
            return last, cache, enc_out

        def _decode(params, cache, tokens, enc_out):
            self._trace_counts["decode"] += 1
            return decode_step(cfg, params, cache, tokens, enc_out=enc_out)

        def _splice(cache, mini_cache, enc_out, mini_enc, slot, true_len):
            self._trace_counts["splice"] += 1
            new_groups = [
                jax.tree.map(lambda f, o: _splice_batch(f, o, slot), gf, go)
                for gf, go in zip(cache["groups"], mini_cache["groups"])]
            new_tail = [
                jax.tree.map(lambda f, o: _splice_batch(f, o, slot), tf, to)
                for tf, to in zip(cache["tail"], mini_cache["tail"])]
            lens = jnp.broadcast_to(jnp.asarray(cache["len"]), (batch,))
            lens = lens.at[slot].set(true_len)
            new_enc = enc_out
            if enc_out is not None:
                new_enc = jax.lax.dynamic_update_slice_in_dim(
                    enc_out, mini_enc.astype(enc_out.dtype), slot, axis=0)
            return ({"groups": new_groups, "tail": new_tail, "len": lens},
                    new_enc)

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._splice = jax.jit(_splice,
                               donate_argnums=(0,) if donate_cache else ())
        enc_len = 1 if cfg.family == "encdec" else 0
        self._fresh_b1 = jax.jit(
            functools.partial(init_cache, cfg, 1, max_len, enc_len=enc_len))
        self._enc_out = None
        self.cache = None

    def trace_count(self, name: str) -> int:
        """How many times program ``name`` (prefill|decode|splice) has been
        traced (= compiled signatures) so far."""
        return self._trace_counts[name]

    def probe_device(self) -> bool:
        """Serve-layer health probe (DESIGN.md §14): one tiny jitted op must
        execute on the device and transfer back.  Returns False instead of
        raising so group failover can keep a group quarantined and retry —
        a probe is exactly the place failure is expected."""
        try:
            x = jnp.ones((2,), jnp.int32)
            return int(jax.block_until_ready(jnp.sum(x))) == 2
        except Exception:
            return False

    # -- lifecycle -------------------------------------------------------------
    def fresh_cache(self):
        enc_len = 0
        if self.cfg.family == "encdec":
            enc_len = 1  # cross K/V recomputed from enc_out, no cache needed
        return init_cache(self.cfg, self.batch, self.max_len, enc_len=enc_len)

    def ensure_batch(self, *, enc_len: int | None = None) -> None:
        """Initialise an empty live batch (all slots free, zero lengths) so
        insert-driven serving can start without a full-batch prefill.  For
        encdec models ``enc_len`` sizes the encoder-output buffer the per-slot
        inserts splice into."""
        if self.cache is None:
            cache = self.fresh_cache()
            cache["len"] = jnp.zeros((self.batch,), jnp.int32)
            self.cache = cache
        if self.cfg.family == "encdec" and self._enc_out is None:
            if enc_len is None:
                raise ValueError("encdec ensure_batch() needs enc_len to size "
                                 "the encoder-output buffer")
            self._enc_out = jnp.zeros(
                (self.batch, enc_len, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))

    def prefill(self, tokens=None, *, embeds=None, enc_embeds=None):
        """tokens: (batch, S). Returns last-position logits (batch, 1, V)."""
        self.cache = self.fresh_cache()
        S = (tokens if tokens is not None else embeds).shape[1]
        logits, self.cache, self._enc_out = self._prefill(
            self.params, self.cache, tokens, embeds, enc_embeds, S - 1)
        return logits

    def decode(self, tokens):
        """tokens: (batch, 1) — one step for every slot."""
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          self._enc_out)
        return logits

    # -- sampling ----------------------------------------------------------------
    @staticmethod
    @functools.partial(jax.jit, static_argnames=("sp",))
    def _sample(logits, key, sp: SamplingParams):
        lg = logits[:, -1, :].astype(jnp.float32)
        if sp.top_k:
            thresh = jax.lax.top_k(lg, sp.top_k)[0][:, -1:]
            lg = jnp.where(lg < thresh, -jnp.inf, lg)
        if sp.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / sp.temperature).astype(jnp.int32)

    def generate(self, tokens, *, max_new: int, sp: SamplingParams = SamplingParams(),
                 key=None, enc_embeds=None) -> np.ndarray:
        """Greedy/temperature generation for a full batch.  Returns
        (batch, max_new) generated ids (stop_token-padded after stop)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits = self.prefill(tokens, enc_embeds=enc_embeds)
        out = []
        done = np.zeros((tokens.shape[0],), bool)
        cur = None
        for i in range(max_new):
            key, sub = jax.random.split(key)
            cur = self._sample(logits, sub, sp)
            ids = np.asarray(cur)
            if sp.stop_token >= 0:
                ids = np.where(done, sp.stop_token, ids)
                done |= ids == sp.stop_token
            out.append(ids)
            if done.all():
                out.extend([np.full_like(ids, sp.stop_token)] *
                           (max_new - len(out)))
                break
            logits = self.decode(jnp.asarray(ids)[:, None])
        return np.stack(out, axis=1)

    # -- continuous batching -----------------------------------------------------
    def insert(self, slot: int, tokens_1xS, *, true_len: int | None = None,
               enc_embeds=None):
        """Prefill a single request into slot ``slot`` without disturbing the
        other slots (slot-local cache splice).

        ``tokens_1xS`` may be right-padded to a bucket length; ``true_len``
        is the unpadded prompt length (defaults to the full width).  The
        slot's cache length is set to ``true_len`` so subsequent decode
        steps position, mask, and write at the request's own offset.

        Returns the logits of the true last prompt token, (1, 1, V), so the
        caller can sample the request's first generated token immediately
        (time-to-first-token is the prefill, not the next batch step).
        """
        if self.cache is None:
            raise RuntimeError("insert() needs a live batch; call prefill() "
                               "first")
        S = tokens_1xS.shape[1]
        true_len = S if true_len is None else int(true_len)
        if not 0 < true_len <= S:
            raise ValueError(f"true_len {true_len} outside (0, {S}]")
        if not 0 <= slot < self.batch:
            raise ValueError(f"slot {slot} outside [0, {self.batch})")
        if self.cfg.family == "encdec":
            if enc_embeds is None:
                raise ValueError(
                    "inserting into an encdec engine requires enc_embeds — "
                    "the slot's encoder output must be spliced alongside its "
                    "KV cache")
            if self._enc_out is None:
                raise RuntimeError("encdec insert() needs a live batch with "
                                   "encoder output; call prefill() first")
            if enc_embeds.shape[1] != self._enc_out.shape[1]:
                raise ValueError(
                    f"enc_embeds length {enc_embeds.shape[1]} != batch "
                    f"encoder length {self._enc_out.shape[1]}")
        logits, mini_cache, mini_enc = self._prefill(
            self.params, self._fresh_b1(), tokens_1xS, None, enc_embeds,
            true_len - 1)
        self.cache, self._enc_out = self._splice(
            self.cache, mini_cache, self._enc_out, mini_enc, slot, true_len)
        return logits


# ---------------------------------------------------------------------------
# Paged KV cache + chunked prefill (DESIGN.md §9)
# ---------------------------------------------------------------------------


def chunk_buckets_for(prefill_chunk: int, page_size: int) -> tuple[int, ...]:
    """Length buckets for the FINAL (partial) chunk of a prompt: power-of-two
    multiples of the page size up to the full chunk length.  One jitted
    chunk-prefill program compiles per bucket, so the compile count is
    ``len(buckets)`` regardless of how many prompts are served."""
    buckets = {prefill_chunk}
    b = page_size
    while b < prefill_chunk:
        buckets.add(b)
        b *= 2
    return tuple(sorted(buckets))


def chunk_plan(true_len: int, prefill_chunk: int,
               buckets: Sequence[int], *,
               start: int = 0) -> list[tuple[int, int, int]]:
    """Split a prompt into page-aligned chunks: full ``prefill_chunk``-sized
    chunks, then the remainder padded up to the smallest fitting bucket.
    Returns ``[(start, bucket_len, valid_in_chunk), ...]``.

    ``start`` (a chunk-aligned offset, prefix-cache hits) begins the plan at
    a later position; because full chunks are laid at multiples of
    ``prefill_chunk``, the result is exactly the suffix of the ``start=0``
    plan — the bit-exactness contract prefix sharing relies on (the final
    chunk, whose logits seed the first sampled token, is identical to the
    one a full prefill would run)."""
    if true_len <= 0:
        raise ValueError(f"true_len {true_len} must be positive")
    if not 0 <= start < true_len:
        raise ValueError(f"start {start} outside [0, {true_len})")
    if start % prefill_chunk:
        raise ValueError(f"start {start} must be chunk-aligned "
                         f"(prefill_chunk {prefill_chunk}) so the plan is a "
                         f"suffix of the full-prefill plan")
    plan = []
    while true_len - start > prefill_chunk:
        plan.append((start, prefill_chunk, prefill_chunk))
        start += prefill_chunk
    rem = true_len - start
    fitting = [b for b in buckets if b >= rem]
    if not fitting:
        raise ValueError(f"no chunk bucket fits remainder {rem} "
                         f"(buckets {tuple(buckets)})")
    plan.append((start, min(fitting), rem))
    return plan


class PagedEngine:
    """Serving engine over a paged KV cache with chunked prefill.

    Attention layers share one page pool ``(num_pages, KV, page_size, D)``
    per k/v (group-stacked like dense caches); a slot's cache is whatever
    pages the scheduler's allocator assigned it, recorded in a *host-side*
    page table ``(batch, max_pages_per_slot)`` that is passed into every
    jitted program.  Page 0 is reserved as the trash page: free (and
    mid-prefill) slots' table rows point at it, so the always-full-batch
    decode program can write their dead K/V somewhere harmless without
    masking — live slots never alias it (allocator hands out pages ≥ 1).

    Prefill is chunked: ``prefill_chunk``-sized page-aligned chunks run
    through ONE jitted chunk program per chunk-length bucket
    (``trace_count("chunk_prefill")`` = #buckets used), each attending over
    the slot's previously-written pages plus itself, so the scheduler can
    interleave live-batch decode steps between the chunks of a long prompt
    instead of stalling on it.  During a multi-chunk prefill the slot's
    LIVE table row stays on the trash page (interleaved decodes of the
    still-empty slot must not touch the real pages); the chunk program gets
    the real page row as an argument, and ``commit_slot`` installs it once
    the last chunk has run.  Surviving slots stay bit-identical under all
    of this: chunk writes land only in the inserting slot's own pages, and
    every other slot's gathered view depends only on its own table row.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch: int, max_len: int,
                 page_size: int = 16, num_pages: int | None = None,
                 prefill_chunk: int = 64, donate_cache: bool = True,
                 mesh=None, attn_impl: str = "auto"):
        if cfg.family == "encdec":
            raise NotImplementedError("paged serving for encdec models "
                                      "(cross-attention buffers)")
        if prefill_chunk % page_size:
            raise ValueError(f"prefill_chunk {prefill_chunk} must be a "
                             f"multiple of page_size {page_size}")
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self.page_size = page_size
        # paged flash-decode attention impl (DESIGN.md §15): "auto" runs
        # the Pallas kernel on TPU and the gather_pages oracle elsewhere;
        # "interpret" forces the kernel body through the Pallas interpreter
        # (tests), "ref" pins the gather path
        self.attn_impl = attn_impl
        self.chunk_len = prefill_chunk
        self.max_pages = -(-max_len // page_size)       # per-slot table width
        # default pool: the dense engine's footprint (batch × max_len) plus
        # the trash page — callers shrink it to oversubscribe, or keep the
        # bytes and raise ``batch`` instead (more slots, same memory)
        self.num_pages = (1 + batch * self.max_pages if num_pages is None
                          else num_pages)
        self.chunk_buckets = chunk_buckets_for(prefill_chunk, page_size)
        self._trace_counts: collections.Counter = collections.Counter()
        # host-side page table; all-zero rows = trash page (slot empty)
        self.page_table = np.zeros((batch, self.max_pages), np.int32)
        # prefix caching shares pages between slots, which only the paged
        # attention pools support: SSM layers keep per-SLOT dense state that
        # a chunk prefill rebuilds position by position — there is no page
        # of it to hand a second request
        plan = layer_plan(cfg)
        self.supports_prefix_cache = "ssm" not in plan.pattern + plan.tail
        # multi-device serving (DESIGN.md §13): with a mesh, the page pools
        # shard kv_heads->model (TP) and pages/slots->data (DP groups); the
        # three jitted programs trace inside a use_rules context so the
        # model code's logical() annotations become real constraints.  A
        # mesh of total size 1 resolves every rule to replication — the
        # single-device code path, bit for bit.
        self.mesh = mesh
        self._rules = None
        if mesh is not None:
            from .mesh import serve_rules
            self._rules = ShardingRules(mesh=mesh, rules=serve_rules())

        def _decode(params, cache, tokens, page_table, update_mask):
            self._trace_counts["decode"] += 1
            return decode_step(cfg, params, cache, tokens, pages=page_table,
                               page_size=page_size, update_mask=update_mask,
                               paged_impl=attn_impl)

        def _chunk(params, cache, tokens, pages_row, slot, start, valid_len):
            self._trace_counts["chunk_prefill"] += 1
            return chunk_prefill_step(cfg, params, cache, tokens, slot=slot,
                                      start=start, valid_len=valid_len,
                                      pages_row=pages_row,
                                      page_size=page_size)

        def _copy(cache, src, dst):
            self._trace_counts["copy_page"] += 1

            def cp_block(bc, axis):
                # attention pool blocks only — SSM blocks hold per-slot
                # state, no pages to copy (cf. _page_view_block)
                if not (isinstance(bc, dict) and "self" in bc):
                    return bc

                def cp(pool):
                    blk = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=axis)
                    return jax.lax.dynamic_update_slice_in_dim(pool, blk, dst,
                                                               axis=axis)

                return {**bc,
                        "self": {k: cp(v) for k, v in bc["self"].items()}}

            return {"groups": [cp_block(bc, 1) for bc in cache["groups"]],
                    "tail": [cp_block(bc, 0) for bc in cache["tail"]],
                    "len": cache["len"]}

        donate = (1,) if donate_cache else ()
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._chunk = jax.jit(_chunk, donate_argnums=donate)
        self._copy = jax.jit(_copy, donate_argnums=(0,) if donate_cache
                             else ())
        # device copy of the page table, refreshed only when a slot commits
        # or frees — decode steps between table changes reuse it instead of
        # paying a host->device transfer per step
        self._pt_device = None
        self.cache = None

    def trace_count(self, name: str) -> int:
        """Trace (= compiled-signature) count of program ``name``
        (chunk_prefill|decode)."""
        return self._trace_counts[name]

    def probe_device(self) -> bool:
        """Serve-layer health probe (DESIGN.md §14): one tiny op must run
        on the mesh (or the default device) and transfer back.  Returns
        False instead of raising — group failover keeps the group
        quarantined and retries on the next probe interval."""
        try:
            with self._rules_ctx():
                x = jnp.ones((2,), jnp.int32)
                return int(jax.block_until_ready(jnp.sum(x))) == 2
        except Exception:
            return False

    def _rules_ctx(self):
        """Ambient sharding rules for tracing the jitted programs — a
        nullcontext without a mesh, so the single-device path is untouched."""
        if self._rules is None:
            return contextlib.nullcontext()
        return use_rules(self.mesh, self._rules.rules)

    # -- lifecycle -------------------------------------------------------------
    def ensure_batch(self, *, enc_len: int | None = None) -> None:
        """Initialise an empty live batch (all slots free, zero lengths,
        every table row on the trash page)."""
        if self.cache is None:
            cache = init_paged_cache(self.cfg, self.batch,
                                     num_pages=self.num_pages,
                                     page_size=self.page_size)
            if self._rules is not None:
                from .mesh import shard_paged_cache
                cache = shard_paged_cache(cache, self._rules)
            self.cache = cache

    def per_device_pool_bytes(self) -> int:
        """Max attention-pool bytes resident on any one device (equals the
        total pool bytes on a single device; a TP=2 mesh halves it when
        kv_heads divides)."""
        self.ensure_batch()
        from .mesh import per_device_pool_bytes
        return per_device_pool_bytes(self.cache)

    def total_pool_bytes(self) -> int:
        """Attention page-pool bytes across the whole mesh (0 for pure-SSM
        models — their dense per-slot state is not paged)."""
        self.ensure_batch()
        total = 0
        for part in ("groups", "tail"):
            for bc in self.cache[part]:
                if isinstance(bc, dict) and "self" in bc:
                    total += sum(int(a.nbytes) for a in bc["self"].values())
        return total

    def pages_needed(self, true_len: int, max_new: int) -> int:
        """Pages a request needs to hold ``true_len`` prompt tokens plus
        ``max_new`` generated ones: the padded prefill span or the prompt +
        generation budget, whichever reaches further.

        Two reservation disciplines build on this (``ServeScheduler``):

        * ``reserve="lifetime"`` calls it with the full generation budget at
          admission — an admitted request can never hit pool exhaustion, at
          the cost of reserving pages that sit empty until decode reaches
          them;
        * ``reserve="demand"`` calls it with ``max_new=1`` (the prompt span
          plus room for the first decode write) and appends further decode
          pages lazily via :meth:`append_page`, preempting on exhaustion.
        """
        plan = chunk_plan(true_len, self.chunk_len, self.chunk_buckets)
        span = max(plan[-1][0] + plan[-1][1], true_len + max_new)
        return -(-span // self.page_size)

    # -- chunked prefill -------------------------------------------------------
    def prefill_chunk(self, slot: int, tokens_1xC, page_ids, start: int,
                      valid_in_chunk: int):
        """Run one chunk through the slot's pages (``page_ids``: the slot's
        full allocation, host list).  Returns the logits at the chunk's true
        last token — only the final chunk's are meaningful."""
        self.ensure_batch()
        ids = self._check_page_row(slot, page_ids)
        row = np.zeros((1, self.max_pages), np.int32)
        row[0, :len(ids)] = ids
        with self._rules_ctx():
            logits, self.cache = self._chunk(self.params, self.cache,
                                             tokens_1xC, row, slot, start,
                                             valid_in_chunk)
        return logits

    def _check_page_row(self, slot: int, page_ids) -> list[int]:
        """Fail fast on a bad table row: the trash page (id 0) mid-row would
        silently truncate the nonzero-prefix page count ``append_page``
        depends on (a later append would overwrite a live mapping), an
        out-of-range id would index the pool out of bounds on device, and an
        over-long row would overflow the per-slot table width."""
        ids = [int(p) for p in page_ids]
        if len(ids) > self.max_pages:
            raise ValueError(f"slot {slot}: {len(ids)} pages exceed the "
                             f"per-slot table width {self.max_pages}")
        bad = [p for p in ids if not 0 < p < self.num_pages]
        if bad:
            raise ValueError(f"slot {slot}: page id(s) {bad} outside "
                             f"(0, {self.num_pages}) — 0 is the reserved "
                             f"trash page")
        return ids

    def commit_slot(self, slot: int, page_ids) -> None:
        """Install the slot's pages into the live table — decode reads (and
        writes) go through them from the next step on."""
        ids = self._check_page_row(slot, page_ids)
        row = np.zeros((self.max_pages,), np.int32)
        row[:len(ids)] = ids
        self.page_table[slot] = row
        self._pt_device = None

    def append_page(self, slot: int, page_id: int) -> None:
        """Reserve-on-demand decode growth: append one page to a COMMITTED
        slot's live table row, just before the decode write that crosses
        into it.  The row's current page count is its nonzero prefix —
        ``commit_slot`` writes a prefix and appends only ever extend it, and
        the allocator never hands out the trash page (id 0)."""
        if page_id <= 0:
            raise ValueError(f"page {page_id} is reserved (trash page) or "
                             f"invalid — appends take allocator pages >= 1")
        if page_id >= self.num_pages:
            raise ValueError(f"page {page_id} outside the pool "
                             f"(num_pages {self.num_pages}) — a foreign id "
                             f"would index the device pool out of bounds")
        n = int(np.count_nonzero(self.page_table[slot]))
        if n == 0:
            raise ValueError(f"slot {slot} is not committed (row on the "
                             f"trash page); append_page only grows live "
                             f"slots")
        if n >= self.max_pages:
            raise ValueError(f"slot {slot} table is full "
                             f"({self.max_pages} pages)")
        self.page_table[slot, n] = page_id
        self._pt_device = None

    def copy_page(self, src: int, dst: int) -> None:
        """Copy-on-write primitive: duplicate page ``src``'s K/V block into
        ``dst`` across every attention pool (one jitted program, traced
        once).  The caller (scheduler) then remaps the writing slot's table
        row from the shared original to the private copy."""
        for name, p in (("src", src), ("dst", dst)):
            if not 0 < p < self.num_pages:
                raise ValueError(f"copy_page {name} {p} outside "
                                 f"(0, {self.num_pages})")
        if src == dst:
            raise ValueError(f"copy_page onto itself (page {src})")
        self.ensure_batch()
        with self._rules_ctx():
            self.cache = self._copy(self.cache, np.int32(src), np.int32(dst))

    def remap_slot_page(self, slot: int, idx: int, page_id: int) -> None:
        """Replace ONE live table-row entry (COW remap: shared original ->
        private copy).  Only committed rows can be remapped — a mid-prefill
        slot's live row is parked on the trash page, and its real row is
        (re)installed wholesale by ``commit_slot``."""
        if not 0 < page_id < self.num_pages:
            raise ValueError(f"page {page_id} outside (0, {self.num_pages})")
        if not 0 <= idx < self.max_pages:
            raise ValueError(f"row index {idx} outside [0, {self.max_pages})")
        if self.page_table[slot, idx] == 0:
            raise ValueError(f"slot {slot} row index {idx} is not live "
                             f"(trash page) — remap only swaps existing "
                             f"mappings")
        self.page_table[slot, idx] = page_id
        self._pt_device = None

    def free_slot(self, slot: int) -> None:
        """Retire (or preempt) the slot: its table row points back at the
        trash page, so interleaved decode writes of the parked slot land
        somewhere harmless.  The pages themselves go back to the
        scheduler's allocator — preempt-safe because reads of every other
        slot depend only on that slot's own table row."""
        self.page_table[slot] = 0
        self._pt_device = None

    def insert(self, slot: int, tokens, *, true_len: int | None = None,
               page_ids=None, max_new: int = 0):
        """Convenience one-call insert: run every chunk back-to-back (no
        decode interleaving — the scheduler drives chunks itself for that)
        and commit the pages.  ``tokens``: (S,) or (1, S) prompt."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        true_len = len(toks) if true_len is None else int(true_len)
        if page_ids is None:
            raise ValueError("insert() needs the slot's allocated page_ids")
        need = self.pages_needed(true_len, max_new)
        if len(page_ids) < need:
            raise ValueError(f"slot {slot} got {len(page_ids)} pages, needs "
                             f"{need}")
        logits = None
        for start, blen, vlen in chunk_plan(true_len, self.chunk_len,
                                            self.chunk_buckets):
            ck = np.zeros((1, blen), np.int32)
            ck[0, :vlen] = toks[start:start + vlen]
            logits = self.prefill_chunk(slot, jnp.asarray(ck), page_ids,
                                        start, vlen)
        self.commit_slot(slot, page_ids)
        return logits

    # -- decode ----------------------------------------------------------------
    def decode(self, tokens, live_mask=None):
        """tokens: (batch, 1) — one step for every slot, page-table reads
        and writes.  ``live_mask`` (batch,) bool: slots whose per-slot SSM
        state may advance — mid-prefill slots must be masked out, or the
        interleaved decode would corrupt the state their next chunk
        continues from (their attention K/V needs no mask: the live page
        table parks them on the trash page).  Defaults to all-live."""
        self.ensure_batch()
        if self._pt_device is None:
            if self._rules is not None:
                from jax.sharding import NamedSharding
                spec = self._rules.spec_for(["slots", None],
                                            self.page_table.shape)
                self._pt_device = jax.device_put(
                    self.page_table, NamedSharding(self.mesh, spec))
            else:
                self._pt_device = jnp.asarray(self.page_table)
        if live_mask is None:
            live_mask = np.ones((self.batch,), bool)
        with self._rules_ctx():
            logits, self.cache = self._decode(self.params, self.cache, tokens,
                                              self._pt_device,
                                              np.asarray(live_mask, bool))
        return logits

    _sample = Engine._sample


def _splice_batch(full, one, slot):
    """Insert ``one`` (batch=1 leaf) into ``full`` at batch index ``slot``.
    Cache leaves have batch as the first axis after the optional group axis."""
    if full.ndim == one.ndim and full.shape == one.shape:
        return full  # scalar bookkeeping leaves
    # group-stacked leaves: (G, B, ...) vs (G, 1, ...)
    if full.ndim >= 2 and one.shape[0] == full.shape[0] and one.shape[1] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)
    if one.shape[0] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)
    return full
