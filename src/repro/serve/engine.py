"""Batched serving engine: prefill + decode with slot-based continuous
batching.

The decode fleet is the HyPar picture one level up (DESIGN.md §4): each
*slot* is a job whose KV cache is retained device-local (``no_send_back``);
a finished request frees its slot and a waiting request is prefilled into
it (``insert``), without disturbing the other slots — dynamic job creation
at serving time.

Sharding comes from the ambient ``use_rules`` context: the KV cache batch
axis maps to ("pod","data"), the KV sequence axis to "model"
(flash-decoding with all-reduce softmax merges; long_500k shards sequence
over every axis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache, layer_plan
from repro.models.layers import apply_norm
from repro.models.transformer import _run_stack  # encoder reuse

__all__ = ["Engine", "SamplingParams"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # 0 => greedy
    top_k: int = 0                 # 0 => no top-k filter
    stop_token: int = -1           # -1 => never stop early


class Engine:
    """Owns jitted prefill/decode programs for one model + max_len."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int, max_len: int,
                 donate_cache: bool = True):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len

        def _prefill(params, cache, tokens, embeds, enc_embeds):
            enc_out = None
            if cfg.family == "encdec":
                plan = layer_plan(cfg)
                e = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
                e = e + params["enc_pos"][: e.shape[1]].astype(e.dtype)[None]
                e, _ = _run_stack(cfg, plan.enc_pattern,
                                  tuple(params["enc_groups"]), (), (), None,
                                  e, jnp.arange(e.shape[1]))
                enc_out = apply_norm(cfg, params["enc_norm_f"], e)
            logits, cache = decode_step(cfg, params, cache, tokens,
                                        enc_out=enc_out, embeds=embeds)
            return logits[:, -1:], cache, enc_out

        def _decode(params, cache, tokens, enc_out):
            return decode_step(cfg, params, cache, tokens, enc_out=enc_out)

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._enc_out = None
        self.cache = None

    # -- lifecycle -------------------------------------------------------------
    def fresh_cache(self):
        enc_len = 0
        if self.cfg.family == "encdec":
            enc_len = 1  # cross K/V recomputed from enc_out, no cache needed
        return init_cache(self.cfg, self.batch, self.max_len, enc_len=enc_len)

    def prefill(self, tokens=None, *, embeds=None, enc_embeds=None):
        """tokens: (batch, S). Returns last-position logits (batch, 1, V)."""
        self.cache = self.fresh_cache()
        logits, self.cache, self._enc_out = self._prefill(
            self.params, self.cache, tokens, embeds, enc_embeds)
        return logits

    def decode(self, tokens):
        """tokens: (batch, 1) — one step for every slot."""
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          self._enc_out)
        return logits

    # -- sampling ----------------------------------------------------------------
    @staticmethod
    @functools.partial(jax.jit, static_argnames=("sp",))
    def _sample(logits, key, sp: SamplingParams):
        lg = logits[:, -1, :].astype(jnp.float32)
        if sp.top_k:
            thresh = jax.lax.top_k(lg, sp.top_k)[0][:, -1:]
            lg = jnp.where(lg < thresh, -jnp.inf, lg)
        if sp.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / sp.temperature).astype(jnp.int32)

    def generate(self, tokens, *, max_new: int, sp: SamplingParams = SamplingParams(),
                 key=None, enc_embeds=None) -> np.ndarray:
        """Greedy/temperature generation for a full batch.  Returns
        (batch, max_new) generated ids (stop_token-padded after stop)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits = self.prefill(tokens, enc_embeds=enc_embeds)
        out = []
        done = np.zeros((tokens.shape[0],), bool)
        cur = None
        for i in range(max_new):
            key, sub = jax.random.split(key)
            cur = self._sample(logits, sub, sp)
            ids = np.asarray(cur)
            if sp.stop_token >= 0:
                ids = np.where(done, sp.stop_token, ids)
                done |= ids == sp.stop_token
            out.append(ids)
            if done.all():
                out.extend([np.full_like(ids, sp.stop_token)] *
                           (max_new - len(out)))
                break
            logits = self.decode(jnp.asarray(ids)[:, None])
        return np.stack(out, axis=1)

    # -- continuous batching -----------------------------------------------------
    def insert(self, slot: int, tokens_1xS) -> None:
        """Prefill a single request into slot ``slot`` without disturbing the
        other slots (slot-local cache splice)."""
        mini = Engine(self.cfg, self.params, batch=1, max_len=self.max_len,
                      donate_cache=False)
        mini.prefill(tokens_1xS)

        def splice(full, one):
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)

        def splice_tree(full_tree, one_tree):
            return jax.tree.map(
                lambda f, o: splice(f, o) if f.ndim >= 1 and o.ndim == f.ndim
                and f.shape[1:] == o.shape[1:] else f,
                full_tree, one_tree)

        # per-slot caches share every axis except batch; "len" is global —
        # per-slot lengths are tracked host-side by the caller
        new_groups = []
        for gfull, gone in zip(self.cache["groups"], mini.cache["groups"]):
            new_groups.append(jax.tree.map(
                lambda f, o: _splice_batch(f, o, slot), gfull, gone))
        new_tail = []
        for tfull, tone in zip(self.cache["tail"], mini.cache["tail"]):
            new_tail.append(jax.tree.map(
                lambda f, o: _splice_batch(f, o, slot), tfull, tone))
        self.cache = {"groups": new_groups, "tail": new_tail,
                      "len": self.cache["len"]}


def _splice_batch(full, one, slot: int):
    """Insert ``one`` (batch=1 leaf) into ``full`` at batch index ``slot``.
    Cache leaves have batch as the first axis after the optional group axis."""
    if full.ndim == one.ndim and full.shape == one.shape:
        return full  # scalar bookkeeping leaves
    # group-stacked leaves: (G, B, ...) vs (G, 1, ...)
    if full.ndim >= 2 and one.shape[0] == full.shape[0] and one.shape[1] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=1)
    if one.shape[0] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one, slot, axis=0)
    return full
