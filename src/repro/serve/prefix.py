"""Prefix cache over the paged KV pool: copy-on-write page sharing
(DESIGN.md §11).

Requests repeating the same prompt prefix (system prompts, few-shot
headers) each pay a full chunked prefill into private pages today, when
the page pool + host page table make read-only sharing nearly free — the
serving analogue of the paper's co-resident jobs sharing node-local data
instead of carrying private copies.

The cache is a host-side map from *page-aligned token prefixes* to pool
pages.  Keys are hash-chained per page::

    key_i = blake2b(key_{i-1} || tokens[i*ps : (i+1)*ps])

so a key identifies the page's tokens AND everything before them — two
prompts share page ``i`` only if they agree on the whole prefix through
it, which is exactly when the page's K/V (a per-position pure function of
the tokens) is identical.  ``lookup`` walks the chain and returns the
longest cached run; a broken link ends the chain (a deeper entry can
never be reached without its parent, which is why eviction goes
deepest-first).

Reference discipline: every entry holds ONE :class:`PageAllocator`
reference of its own (taken at ``insert`` via ``share``), on top of
whatever slot references exist — so a cached page of a retired request
stays resident for future hits, and a hit maps new slots onto it with
further ``share`` calls.  ``evict_for`` drops only entries whose page has
refcount 1 (cache-only — no slot still reads it); ``flush`` drops
everything, releasing the cache's refs (pages shared with live slots
stay outstanding under the slots' refs).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator

import numpy as np

__all__ = ["PrefixCache"]

_SEED = b"repro/prefix/v1"


@dataclasses.dataclass
class _Entry:
    page: int                       # pool page holding this prefix page's KV
    depth: int                      # 1-based chain position (eviction order)
    last_use: int                   # cache tick of the last lookup/insert


class PrefixCache:
    """Hash-chained map from page-aligned prompt prefixes to pool pages."""

    def __init__(self, page_size: int, *, admit_after: int = 1):
        if page_size <= 0:
            raise ValueError(f"page_size {page_size} must be positive")
        if admit_after < 1:
            raise ValueError(f"admit_after {admit_after} must be >= 1")
        self.page_size = page_size
        self.admit_after = admit_after
        self._entries: dict[bytes, _Entry] = {}
        self._seen: dict[bytes, int] = {}   # host-side sight counts, no refs
        self._tick = 0
        self.n_inserted = 0
        self.n_evicted = 0
        self.n_insert_deferred = 0

    def __len__(self) -> int:
        return len(self._entries)

    def pages(self) -> set[int]:
        """Pool pages the cache currently holds a reference on (invariant
        checks: outstanding == slot-mapped ∪ cache-held)."""
        return {e.page for e in self._entries.values()}

    def _keys(self, tokens) -> Iterator[bytes]:
        toks = np.asarray(tokens, np.int32).reshape(-1)
        h = _SEED
        for i in range(len(toks) // self.page_size):
            page = toks[i * self.page_size:(i + 1) * self.page_size]
            h = hashlib.blake2b(h + page.tobytes(), digest_size=16).digest()
            yield h

    def lookup(self, tokens) -> list[int]:
        """Longest cached page chain covering a prefix of ``tokens`` (pool
        page ids, chain order).  The caller decides how much of it is
        *usable* (the scheduler floors to a chunk boundary for bit-exact
        final-chunk logits) and takes its own ``share`` refs."""
        self._tick += 1
        chain: list[int] = []
        for key in self._keys(tokens):
            e = self._entries.get(key)
            if e is None:
                break
            e.last_use = self._tick
            chain.append(e.page)
        return chain

    def insert(self, tokens, page_ids, allocator) -> int:
        """Cache every full page of ``tokens`` through the owning slot's
        ``page_ids``; each NEW entry takes one allocator reference (the
        cache's own hold).  An existing key keeps its original page — a
        racing duplicate prefill does not steal the chain (both pages hold
        identical K/V; the earlier one already serves hits).

        With ``admit_after=k`` a new key is only admitted on its k-th
        sighting; earlier sightings just bump a host-side count (no
        allocator references taken, ``n_insert_deferred`` incremented).
        Once one key in a walk is deferred, every deeper key is deferred
        too — an entry must never exist without its parent, or lookup
        could hand out an unreachable chain after the parent is admitted
        later with a DIFFERENT page.  Returns the number of entries
        added."""
        self._tick += 1
        added = 0
        chain_broken = False
        for i, key in enumerate(self._keys(tokens)):
            if i >= len(page_ids):
                break
            e = self._entries.get(key)
            if e is not None:
                e.last_use = self._tick
                continue
            n_seen = self._seen.get(key, 0) + 1
            if chain_broken or n_seen < self.admit_after:
                self._seen[key] = n_seen
                self.n_insert_deferred += 1
                chain_broken = True
                continue
            self._seen.pop(key, None)
            allocator.share([page_ids[i]])
            self._entries[key] = _Entry(page=int(page_ids[i]), depth=i + 1,
                                        last_use=self._tick)
            added += 1
        self.n_inserted += added
        return added

    def evict_for(self, allocator, n_free_target: int) -> int:
        """Evict cache-only entries (page refcount 1 — no slot maps it)
        until the allocator has ``n_free_target`` free pages, deepest-first
        then least-recently-used.  Deepest-first can never orphan a child
        behind an evicted parent, so every surviving entry stays reachable
        through ``lookup``.  Returns the number of pages freed."""
        if allocator.n_free >= n_free_target:
            return 0
        cands = [(key, e) for key, e in self._entries.items()
                 if allocator.refcount(e.page) == 1]
        cands.sort(key=lambda kv: (-kv[1].depth, kv[1].last_use))
        freed = 0
        for key, e in cands:
            if allocator.n_free >= n_free_target:
                break
            del self._entries[key]
            allocator.free([e.page])
            freed += 1
        self.n_evicted += freed
        return freed

    def flush(self, allocator) -> int:
        """Drop every entry, releasing the cache's references.  A page still
        mapped by a live slot stays outstanding under the slot's refs; a
        cache-only page returns to the free list.  Returns the number of
        entries dropped."""
        n = len(self._entries)
        for e in self._entries.values():
            allocator.free([e.page])
        self._entries.clear()
        self._seen.clear()
        return n
