"""Request-level serving scheduler: the HyPar job model one level up.

DESIGN.md §8.  The decode fleet maps onto the paper's runtime roles:

* a *slot* of the batched :class:`~repro.serve.engine.Engine` is a worker —
  its share of the KV cache is a device-local retained result
  (``no_send_back``),
* an admitted request is a *dynamic job*, spawned at runtime by a
  ``control`` function (paper §3.3 — "each job can add a finite number of
  new jobs"),
* continuous batching (prefill-into-free-slot, decode the live batch,
  retire finished slots) is the scheduler's select-and-assign loop,
* losing a slot's KV (worker failure) invalidates the retained result; the
  request is re-queued and re-prefilled — lineage recovery exactly as
  DESIGN.md §6 applies to retained results.

Two operating modes share every code path except placement:

* **direct** — free slots are filled first-come-first-served,
* **hypar** (:class:`HyParRequestTracker`) — each request goes through the
  core machinery: a dynamic job added via :class:`ControlContext`, placed
  by :class:`MasterScheduler` (``greedy`` or ``cost`` strategy, decode-time
  EWMA fed back via ``observe``), its generated tokens recorded in
  :class:`ResultStore` as a worker-retained result and released on
  delivery.

Host-side per-slot state (`SlotState`: position, remaining budget, stop
status) mirrors the engine's per-slot cache lengths — the bookkeeping
``Engine.insert`` used to promise but never implemented.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from collections import deque
from typing import Any, Callable, Iterable, Sequence

import jax
import numpy as np

from repro.core.job import ChunkedData, Job, JobGraph, ParallelSegment
from repro.core.registry import ControlContext, FunctionKind, FunctionRegistry
from repro.core.scheduler import (CostModelParams, MasterScheduler,
                                  ResultStore, VirtualCluster)
from repro.core.store import JobStore

from .engine import Engine, PagedEngine, SamplingParams, chunk_plan
from .prefix import PrefixCache

__all__ = [
    "Request", "RequestResult", "RequestOutcome", "TERMINAL_OUTCOMES",
    "RequestQueue", "SlotState", "PageAllocator",
    "PrefixCache", "DeviceGroup", "CostModelParams", "ServeScheduler",
    "HyParRequestTracker", "DEFAULT_BUCKETS",
]

# prompt-length buckets: prompts are right-padded to the next bucket so the
# slot-prefill program compiles once per bucket, not once per length
DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)


# ---------------------------------------------------------------------------
# Requests & results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray              # (S,) int32 prompt
    max_new: int                    # realised generation length
    arrival_s: float = 0.0          # scheduler-clock arrival time
    enc_embeds: Any = None          # encdec: (1, T, d) encoder input
    # declared generation cap: what ADMISSION must budget for.  Clients
    # declare a conservative cap (vLLM's max_tokens) while most requests
    # stop far short of it — full-lifetime reservation pays pages for the
    # cap, reserve-on-demand pays only for tokens actually generated.
    # None => the realised length is the cap (PR-4 behaviour).
    budget_new: int | None = None
    # deadlines (DESIGN.md §14), both relative to ``arrival_s``: the client
    # stops caring about the first token after ``ttft_deadline_s`` and about
    # the whole answer after ``total_deadline_s``.  None => no deadline.
    # Admission sheds requests whose EWMA-predicted TTFT already exceeds the
    # TTFT deadline; the loop retires requests past their total deadline
    # with the ``expired`` outcome.
    ttft_deadline_s: float | None = None
    total_deadline_s: float | None = None

    @property
    def declared_new(self) -> int:
        """Generation cap admission reserves/validates against (>= the
        realised ``max_new``)."""
        return (self.max_new if self.budget_new is None
                else max(self.max_new, self.budget_new))


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: list[int]               # generated ids (incl. the stop token)
    arrival_s: float
    token_s: list[float]            # completion time of each token
    finish_s: float

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def ttft_s(self) -> float:
        """Time to first token, measured from arrival (queueing included)."""
        return self.token_s[0] - self.arrival_s

    @property
    def step_latencies_s(self) -> list[float]:
        """Inter-token latencies after the first token."""
        return [b - a for a, b in zip(self.token_s, self.token_s[1:])]


#: the closed set of terminal request outcomes (DESIGN.md §14).  Every
#: request that enters the scheduler ends in EXACTLY one of these —
#: ``ServeScheduler._record_outcome`` raises on a second recording, so the
#: no-request-left-behind guarantee is structural, not best-effort.
TERMINAL_OUTCOMES = ("completed", "shed_queue", "shed_deadline",
                     "expired", "failed")


@dataclasses.dataclass(frozen=True)
class RequestOutcome:
    """One request's terminal fate on the scheduler clock.

    * ``completed`` — finished normally; its :class:`RequestResult` is in
      ``sched.results``,
    * ``shed_queue`` — refused at admission for capacity (full queue, or a
      request that can never fit the engine; ``detail`` says which),
    * ``shed_deadline`` — refused because its TTFT deadline was already
      unmeetable (EWMA load prediction, or the deadline passed while
      queued),
    * ``expired`` — admitted but its total deadline passed before it
      finished; partial work is discarded,
    * ``failed`` — evicted by faults more times than ``max_restarts``
      allows.
    """

    rid: int
    outcome: str
    finish_s: float
    detail: str = ""


class RequestQueue:
    """FIFO admission queue.  ``max_pending`` is the admission-control knob:
    a full queue sheds the request (``submit`` returns False) instead of
    growing without bound — the caller decides whether to retry."""

    def __init__(self, max_pending: int | None = None):
        self.max_pending = max_pending
        self._q: deque[Request] = deque()
        self._next_rid = 0
        self.n_submitted = 0
        # typed shed counters (DESIGN.md §14): WHY a request was refused,
        # not just that one was — `n_rejected` stays as their sum
        self.shed_queue_full = 0
        self.shed_never_fits = 0
        self.shed_deadline = 0

    @property
    def n_rejected(self) -> int:
        """Total shed requests — the sum of the typed counters."""
        return (self.shed_queue_full + self.shed_never_fits
                + self.shed_deadline)

    def reset_shed(self) -> None:
        self.shed_queue_full = 0
        self.shed_never_fits = 0
        self.shed_deadline = 0

    def next_rid(self) -> int:
        rid, self._next_rid = self._next_rid, self._next_rid + 1
        return rid

    def submit(self, req: Request) -> bool:
        if self.max_pending is not None and len(self._q) >= self.max_pending:
            self.shed_queue_full += 1
            return False
        self._q.append(req)
        self.n_submitted += 1
        return True

    def push_front(self, req: Request) -> None:
        """Re-queue a request whose retained KV was lost (fault recovery);
        it bypasses admission — the request was already admitted once."""
        self._q.appendleft(req)

    def pop(self) -> Request | None:
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)


# ---------------------------------------------------------------------------
# Per-slot host-side state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SlotState:
    """Host-side mirror of one engine slot: position, remaining budget and
    stop status — the per-slot bookkeeping the engine's per-slot cache
    lengths are kept in sync with.  Under a paged engine the slot also
    tracks its page allocation and the chunks of an in-progress prefill."""

    slot: int
    request: Request | None = None
    pos: int = 0                    # tokens in the slot's cache
    budget: int = 0                 # generated tokens still allowed
    next_token: int = 0             # fed to the next decode step
    finished: bool = False
    tokens: list[int] = dataclasses.field(default_factory=list)
    token_s: list[float] = dataclasses.field(default_factory=list)
    page_ids: list[int] = dataclasses.field(default_factory=list)
    # chunked prefill in flight: remaining (start, bucket_len, valid) chunks
    pending_chunks: list[tuple[int, int, int]] = \
        dataclasses.field(default_factory=list)
    # reserve-on-demand bookkeeping: admission order (LIFO preemption
    # tiebreak), the token stream the in-flight prefill is replaying
    # (prompt, or prompt + retained tokens on a resume), the suspended
    # record being resumed, and how many tokens were already generated when
    # the request was (re)admitted (resume-progress floor)
    admit_seq: int = 0
    prefill_tokens: np.ndarray | None = None
    resume: "_Suspended | None" = None
    resume_base: int = 0

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        return self.request is not None and bool(self.pending_chunks)


@dataclasses.dataclass
class _Suspended:
    """Host-side remains of a preempted request: the generated tokens (and
    their timestamps — TTFT was already measured) survive the loss of the
    device-side KV/SSM state, which is rebuilt on resume by chunked
    re-prefill of prompt + retained tokens (recompute, not swap)."""

    tokens: list[int]
    token_s: list[float]
    n_preempts: int = 1


class PageAllocator:
    """Host-side free list + per-page reference counts over the shared KV
    page pool.

    Page 0 is the engine's reserved trash page and is never handed out.
    Pages come out of ``alloc`` exclusively owned (refcount 1) and may gain
    further read-only references via :meth:`share` — prefix-cache hits map
    extra slots (and the cache itself) onto one physical page.  The paged
    write paths' invariant is therefore **writable iff refcount == 1**: a
    write into a shared page must copy-on-write first (the scheduler's
    job).  ``free`` releases one reference per listed page; a page returns
    to the free list only when its LAST reference drops, so for unshared
    pages the semantics are exactly the old exclusive ones (including the
    double-free error).  ``alloc`` returns ``None`` when the pool cannot
    cover the request — the admission signal: the request stays queued
    until retirements free pages.

    ``watermark`` free pages are held back from *admission* allocations
    (:meth:`admit`): under reserve-on-demand the pool's slack is what decode
    appends draw from, and admitting into the last free pages converts every
    subsequent page-boundary crossing into a preemption.  Appends themselves
    (``alloc``) may dip below the watermark — they are the demand the
    headroom exists for.
    """

    def __init__(self, num_pages: int, *, n_reserved: int = 1,
                 watermark: int = 0):
        if num_pages <= n_reserved:
            raise ValueError(f"pool of {num_pages} pages has no usable pages "
                             f"beyond the {n_reserved} reserved")
        if watermark < 0:
            raise ValueError(f"watermark {watermark} must be >= 0")
        self.num_pages = num_pages
        self.n_reserved = n_reserved
        self.watermark = watermark
        # stack popped from the end => ascending page ids first
        self._free = list(range(num_pages - 1, n_reserved - 1, -1))
        self._ref: dict[int, int] = {}   # outstanding page -> refcount >= 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_outstanding(self) -> int:
        return len(self._ref)

    @property
    def outstanding(self) -> frozenset[int]:
        """Snapshot of the pages currently owned by at least one holder
        (invariant checks: must equal the union of every slot's
        ``page_ids`` and the prefix cache's held pages)."""
        return frozenset(self._ref)

    def refcount(self, page: int) -> int:
        """References on ``page`` (0 => free / never allocated)."""
        return self._ref.get(int(page), 0)

    def writable(self, page: int) -> bool:
        """A page may be written in place only while exactly one reference
        exists — any write into a shared page must copy-on-write first."""
        return self._ref.get(int(page), 0) == 1

    def alloc(self, n: int) -> list[int] | None:
        if n <= 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def admit(self, n: int) -> list[int] | None:
        """Admission-path allocation: refuses to leave fewer than
        ``watermark`` pages free.  Decode appends use plain :meth:`alloc`."""
        if len(self._free) - n < self.watermark:
            return None
        return self.alloc(n)

    def share(self, pages: Iterable[int]) -> None:
        """Take one additional (read-only) reference on each outstanding
        page — a prefix-cache hit mapping a new slot onto shared pages, or
        the cache itself retaining a retired request's prefix."""
        pages = [int(p) for p in pages]
        missing = [p for p in pages if p not in self._ref]
        if missing:
            raise ValueError(f"cannot share unallocated page(s) "
                             f"{sorted(set(missing))}")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: Iterable[int]) -> None:
        """Release one reference per listed page (a page listed twice
        releases two).  The WHOLE batch is validated before any mutation:
        an over-free (more releases than references — double free or
        foreign page) raises with the allocator untouched, instead of
        half-freed mid-loop with the conservation invariant broken for the
        rest of the run."""
        pages = [int(p) for p in pages]
        counts = collections.Counter(pages)
        bad = sorted(p for p, c in counts.items()
                     if self._ref.get(p, 0) < c)
        if bad:
            raise ValueError(f"page(s) {bad} have fewer references than "
                             f"frees requested (double free or foreign "
                             f"page); nothing was freed")
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)


@dataclasses.dataclass
class DeviceGroup:
    """One data-parallel partition of the serving engine (DESIGN.md §13).

    A group owns a contiguous range of batch slots and a private, contiguous
    range of the page pool behind its own :class:`PageAllocator` (and its
    own :class:`PrefixCache`) — allocation, sharing, COW and preemption
    never cross a group boundary, so every per-group invariant is exactly
    the single-allocator invariant of before.  The allocator's
    ``[n_reserved, num_pages)`` free range doubles as the group's page
    range; the engine-global trash page 0 is shared by all groups (it is
    never allocator-owned, so it cannot carry a cross-group reference).

    ``ewma_step_s`` is the group's decode-time EWMA — the queue-depth term
    of the admission router's cost score (the paper's dynamic placement at
    device-group granularity).
    """

    gid: int
    slot_ids: tuple[int, ...]
    allocator: PageAllocator | None
    prefix: PrefixCache | None = None
    ewma_step_s: float = 0.0
    occupied_slot_steps: int = 0
    # failover state machine (DESIGN.md §14): healthy -> unhealthy on
    # injection or ``unhealthy_after`` watchdog trips (in-flight requests
    # evicted, pages quarantined at zero outstanding) -> healthy again once
    # a probe passes.  ``down_step`` is the scheduler-call stamp the probe
    # interval counts from.
    healthy: bool = True
    watchdog_trips: int = 0
    down_step: int = 0
    # flaky-group rejoin backoff (ROADMAP 5c): the probe interval for this
    # group is ``probe_interval_steps * probe_backoff``.  The multiplier
    # doubles (capped at ``rejoin_backoff_cap``) on every failed probe and
    # on every re-failure shortly after a rejoin, so a flapping group stops
    # soaking the scheduler in constant-cadence probe/evict churn; it
    # resets once the group fails fresh after a long stable stretch.
    probe_backoff: int = 1
    up_step: int = 0          # step_calls stamp of the last rejoin
    backoff_wall: float | None = None   # clock stamp of the last re-arm

    @property
    def page_lo(self) -> int:
        return self.allocator.n_reserved

    @property
    def page_hi(self) -> int:
        return self.allocator.num_pages

    def observe(self, per_slot_step_s: float, alpha: float = 0.3) -> None:
        """Fold one decode step's per-live-slot time into the EWMA."""
        if self.ewma_step_s == 0.0:
            self.ewma_step_s = per_slot_step_s
        else:
            self.ewma_step_s = ((1 - alpha) * self.ewma_step_s
                                + alpha * per_slot_step_s)


# ---------------------------------------------------------------------------
# HyPar integration
# ---------------------------------------------------------------------------


class HyParRequestTracker:
    """Runs each admitted request through the core job machinery.

    Slots are pre-spawned :class:`Worker`\\ s (wid == slot at start); a
    request becomes a dynamic ``Job`` spawned by the registered
    ``serve.admit`` *control* function, placed by :class:`MasterScheduler`
    (so ``greedy``/``cost`` strategies pick the slot), its generated tokens
    recorded as a ``no_send_back`` (worker-retained) result in
    :class:`ResultStore` and released on delivery.  A failed slot loses its
    retained results (``invalidate_worker``), its worker's cluster slot is
    freed and a replacement is spawned — the serving instance of the
    recovery contract of DESIGN.md §6.
    """

    ADMIT_FN = "serve.admit"
    DECODE_FN = "serve.decode"

    #: key prefix for suspended-request rows in the durable job store —
    #: keeps serve recovery state apart from any other ``requests`` users
    #: sharing the same sqlite file (e.g. a ProcessExecutor run)
    STORE_PREFIX = "serve.suspended:"

    def __init__(self, n_slots: int, *, strategy: str = "greedy",
                 cost_params: CostModelParams | None = None,
                 devices: Sequence[Any] | None = None,
                 flops_per_token: float = 0.0,
                 registry: FunctionRegistry | None = None,
                 jobstore: "JobStore | None" = None):
        devices = list(devices if devices is not None else jax.devices())
        self.n_slots = n_slots
        self.cluster = VirtualCluster(devices, max_workers=n_slots)
        for _ in range(n_slots):
            self.cluster.spawn_worker()
        self.graph = JobGraph([ParallelSegment([])])
        self.store = ResultStore(self.cluster)
        self.master = MasterScheduler(self.graph, self.cluster,
                                      strategy=strategy,
                                      cost_params=cost_params)
        self.registry = registry or FunctionRegistry()
        self.registry.register(self.ADMIT_FN, self._admit_control,
                               kind=FunctionKind.CONTROL, name=self.ADMIT_FN)
        self.flops_per_token = flops_per_token
        self.slot_to_wid = {i: i for i in range(n_slots)}
        self.wid_to_slot = {i: i for i in range(n_slots)}
        self._job_of: dict[int, Job] = {}
        self._pending_jobs: list[Job] = []
        self.jobstore = jobstore
        self.n_recovered = 0
        self.n_preempted = 0

    # -- control function: dynamic job creation (paper §3.3) -------------------
    def _admit_control(self, inputs: ChunkedData, ctx: ControlContext) -> ChunkedData:
        for job in self._pending_jobs:
            ctx.add_job(job, 0)     # current segment: decode starts now
        self._pending_jobs = []
        return inputs

    # -- scheduler hooks -------------------------------------------------------
    def place(self, req: Request, free_slots: Sequence[int]) -> int:
        """Choose the slot for one admitted request via MasterScheduler."""
        return self.place_batch([req], free_slots)[req.rid]

    def place_batch(self, reqs: Sequence[Request],
                    free_slots: Sequence[int], *,
                    slot_choices: dict[int, Sequence[int]] | None = None,
                    ) -> dict[int, int]:
        """Place a whole admission wave with ONE ``plan_segment`` call.

        The per-request placement of PR 3 paid the full master-scheduler
        round (control-fn dispatch, graph insertion, plan) once per admitted
        request — ~25% serve overhead vs direct on the CPU smoke trace.  A
        fill wave admits up to ``len(free_slots)`` requests at once, so the
        jobs are created together, spawned through one control-fn call, and
        planned as one segment batch (``plan_segment`` was always batched —
        the serving path just never used it that way).  Returns
        ``{rid: slot}``.

        ``slot_choices`` (``{rid: allowed slots}``) restricts each request
        to a subset of ``free_slots`` — under device groups the admission
        router already charged a specific group's allocator for the
        request's pages, so the slot MUST come from that group (a foreign
        slot would read pages its group's device shard does not hold).  The
        master's pick is kept when it lands inside the subset, else the
        fallback stays within it.
        """
        if len(reqs) > len(free_slots):
            raise ValueError(f"wave of {len(reqs)} requests exceeds "
                             f"{len(free_slots)} free slots")
        jobs = [Job(name=f"req{r.rid}", fn=self.DECODE_FN, n_threads=1,
                    no_send_back=True,
                    cost_hint=self.flops_per_token * r.max_new)
                for r in reqs]
        self._pending_jobs = list(jobs)
        ctx = ControlContext(self.graph, current_segment=0)
        self.registry[self.ADMIT_FN].fn(ChunkedData(), ctx)
        for j, seg in ctx.added:
            self.graph.add_dynamic(j, seg, current=0)

        free = set(free_slots)
        loads = {wid: (0 if slot in free else 1)
                 for slot, wid in self.slot_to_wid.items()}
        placements = self.master.plan_segment(jobs, self.store, loads=loads)
        assign: dict[int, int] = {}
        remaining = set(free_slots)
        for req, placement in zip(reqs, placements):
            allowed = remaining
            if slot_choices is not None and req.rid in slot_choices:
                allowed = set(slot_choices[req.rid]) & remaining
                if not allowed:
                    raise ValueError(f"request {req.rid}: no free slot left "
                                     f"in its device group")
            slot = self.wid_to_slot.get(placement.worker.wid)
            if slot not in allowed:
                # master picked a busy/taken/unmapped/foreign-group worker:
                # fall back to the first remaining allowed slot and keep ITS
                # worker binding — rebinding the picked worker here would
                # leave two slots mapped to one wid and a later fail() would
                # invalidate the busy slot's results
                slot = sorted(allowed)[0]
            remaining.discard(slot)
            assign[req.rid] = slot
            self._job_of[req.rid] = placement.job
        return assign

    def finish(self, req: Request, slot: int, tokens: np.ndarray) -> None:
        """Record the request's output as a worker-retained result."""
        job = self._job_of[req.rid]
        worker = self.cluster.workers[self.slot_to_wid[slot]]
        self.store.put(job, ChunkedData.from_arrays([np.asarray(tokens)]),
                       worker)
        worker.jobs_done += 1

    def retire(self, req: Request) -> None:
        """Result delivered: release the retained data, GC the dynamic job
        and drop any durable resume state — the request is over."""
        self.drop_suspended(req.rid)
        job = self._job_of.pop(req.rid, None)
        if job is None:
            return
        self.store.release(job.name)
        self.graph.remove_job(job.name)

    def abandon(self, rid: int) -> None:
        """The request ends WITHOUT a result (expired / failed / shed after
        suspension): its dynamic job — if one is still placed — leaves the
        graph with nothing recorded, and its durable resume row is dropped.
        ``retire``'s no-result sibling."""
        self.drop_suspended(rid)
        job = self._job_of.pop(rid, None)
        if job is not None:
            self.graph.remove_job(job.name)

    # -- durable resume state (DESIGN.md §12) ----------------------------------
    def persist_suspended(self, rid: int, tokens: Sequence[int],
                          token_s: Sequence[float],
                          n_preempts: int) -> None:
        """Write a suspended request's host-retained tokens to the durable
        job store.  The device KV is already gone (that is what suspension
        means); with this row even the *master's* host copy is expendable —
        a restarted serving process re-seeds its suspended table from the
        store and resumes by the usual chunked recompute."""
        if self.jobstore is None:
            return
        self.jobstore.put_request(
            f"{self.STORE_PREFIX}{rid}",
            {"tokens": np.asarray(tokens, np.int64),
             "token_s": np.asarray(token_s, np.float64),
             "n_preempts": np.asarray(n_preempts, np.int64)})

    def drop_suspended(self, rid: int) -> None:
        if self.jobstore is not None:
            self.jobstore.delete_request(f"{self.STORE_PREFIX}{rid}")

    def restore_suspended(self) -> dict[int, tuple[list[int], list[float], int]]:
        """Read every persisted suspended-request record back:
        ``{rid: (tokens, token_s, n_preempts)}``.  Rids are stable across a
        master restart when requests are resubmitted in the original order
        (``RequestQueue`` numbers from zero)."""
        if self.jobstore is None:
            return {}
        out: dict[int, tuple[list[int], list[float], int]] = {}
        for key, fields in self.jobstore.get_requests().items():
            if not key.startswith(self.STORE_PREFIX):
                continue
            rid = int(key[len(self.STORE_PREFIX):])
            out[rid] = ([int(t) for t in fields["tokens"]],
                        [float(t) for t in fields["token_s"]],
                        int(np.asarray(fields["n_preempts"]).reshape(-1)[0]))
        return out

    def preempt(self, req: Request) -> None:
        """The request's pages were reclaimed: its dynamic job returns to
        the master queue.  No result was recorded yet (``finish`` runs at
        completion), so the job simply leaves the graph; when the request
        resumes, the next ``place_batch`` wave re-spawns and re-places it —
        the same re-queue path ``fail`` uses, minus the worker replacement
        (the worker is healthy; only its page budget was taken)."""
        job = self._job_of.pop(req.rid, None)
        if job is not None:
            self.graph.remove_job(job.name)
        self.n_preempted += 1

    def observe(self, step_s: float, n_live: int) -> None:
        """Feed per-request decode-step time into the cost model's EWMA."""
        if n_live > 0:
            self.master.observe(self.DECODE_FN, step_s / n_live)

    def fail(self, slot: int, *, rid: int | None = None) -> list[str]:
        """Worker failure: retained results lost, cluster slot freed, a
        replacement worker spawned and bound to the slot."""
        wid = self.slot_to_wid[slot]
        worker = self.cluster.workers[wid]
        worker.fail()
        lost = self.store.invalidate_worker(wid)
        if rid is not None:
            job = self._job_of.pop(rid, None)
            if job is not None:     # in-flight job dies with its worker
                self.graph.remove_job(job.name)
        del self.wid_to_slot[wid]
        repl = self.cluster.spawn_worker()
        self.slot_to_wid[slot] = repl.wid
        self.wid_to_slot[repl.wid] = slot
        self.n_recovered += 1
        return lost


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------


class ServeScheduler:
    """Continuous-batching loop over an :class:`Engine`.

    Slot lifecycle: a free slot pulls the next admitted request, prefills it
    in place (``Engine.insert`` — compiled once per prompt bucket) and
    samples its first token; every ``step()`` decodes the whole live batch
    once; a slot whose request hit its budget or stop token is retired and
    immediately refillable.  All request-visible timing (arrival, TTFT,
    per-token) is measured on ``clock``.

    Paged engines choose a reservation discipline (DESIGN.md §10):

    * ``reserve="lifetime"`` — the PR-4 behaviour: a request reserves its
      full prompt+budget page span at admission and can never be preempted;
    * ``reserve="demand"`` — vLLM-style: admission reserves only the prompt
      span (plus one decode write), decode pages are appended lazily at
      page boundaries, and pool exhaustion preempts the lowest-priority
      running slot (``preempt_policy``: ``fewest`` generated tokens with
      LIFO tiebreak, or plain ``lifo``); the victim's generated tokens are
      retained host-side and the request resumes — queue front — by chunked
      re-prefill of prompt + retained tokens (recompute, not swap; the SSM
      state is rebuilt by the same chunk path).  ``admit_watermark`` holds
      back free pages from admissions as append headroom, and
      ``resume_floor`` (default: one page of tokens) keeps a resumed
      request from being re-preempted before it makes progress.

    ``prefix_cache=True`` (paged engines, DESIGN.md §11) additionally maps
    cache-hit prompt prefixes onto shared pool pages — admission prefills
    only the remainder (at best one chunk), the allocator refcounts shared
    pages, and any write into one copy-on-writes first.  Models with SSM
    layers keep the knob but stay uncached (per-slot dense state has no
    pages to share).
    """

    def __init__(self, engine: Engine, *,
                 sp: SamplingParams = SamplingParams(),
                 queue: RequestQueue | None = None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 tracker: HyParRequestTracker | None = None,
                 key=None,
                 clock: Callable[[], float] = time.perf_counter,
                 reserve: str = "lifetime",
                 preempt_policy: str = "fewest",
                 admit_watermark: int = 0,
                 resume_floor: int | None = None,
                 pool_pages: int | None = None,
                 prefix_cache: bool = False,
                 prefix_admit: int = 1,
                 device_groups: int = 1,
                 cost_params: CostModelParams | None = None,
                 enforce_deadlines: bool = True,
                 watchdog_budget_s: float | None = None,
                 unhealthy_after: int = 3,
                 probe_interval_steps: int = 5,
                 rejoin_backoff_cap: int = 16,
                 max_restarts: int | None = None,
                 chaos: Any = None):
        if reserve not in ("lifetime", "demand"):
            raise ValueError(f"unknown reserve discipline {reserve!r}")
        if preempt_policy not in ("fewest", "lifo"):
            raise ValueError(f"unknown preempt policy {preempt_policy!r}")
        if watchdog_budget_s is not None and watchdog_budget_s <= 0:
            raise ValueError(f"watchdog_budget_s {watchdog_budget_s} must "
                             f"be positive (None disables the watchdog)")
        if unhealthy_after < 1:
            raise ValueError(f"unhealthy_after {unhealthy_after} must be "
                             f">= 1")
        if probe_interval_steps < 1:
            raise ValueError(f"probe_interval_steps {probe_interval_steps} "
                             f"must be >= 1")
        if rejoin_backoff_cap < 1:
            raise ValueError(f"rejoin_backoff_cap {rejoin_backoff_cap} must "
                             f"be >= 1 (1 disables the backoff)")
        if max_restarts is not None and max_restarts < 0:
            raise ValueError(f"max_restarts {max_restarts} must be >= 0 "
                             f"(None = unlimited)")
        if admit_watermark and reserve != "demand":
            # the watermark is decode-append headroom — a concept only
            # reserve-on-demand has.  Under lifetime reservation _fits
            # screens against the raw pool while admit() would hold pages
            # back, so a request could pass screening yet be deferred
            # forever: reject the combination instead of livelocking.
            raise ValueError("admit_watermark requires reserve='demand' "
                             "(lifetime reservation has no decode appends "
                             "to hold headroom for)")
        self.engine = engine
        self.sp = sp
        self.queue = queue if queue is not None else RequestQueue()
        # clamp oversized buckets to the cache size instead of dropping them:
        # a prompt whose next bucket exceeds max_len may still fit the cache
        # (prompt + budget <= max_len) and must stay placeable
        self.buckets = tuple(sorted({min(b, engine.max_len) for b in buckets
                                     if b > 0}))
        if not self.buckets:
            raise ValueError(f"no prompt bucket fits max_len={engine.max_len}")
        self.paged = isinstance(engine, PagedEngine)
        if reserve == "demand" and not self.paged:
            raise ValueError("reserve='demand' needs a PagedEngine — the "
                             "dense per-slot cache has nothing to append")
        self.reserve = reserve
        self.demand = reserve == "demand"
        self.preempt_policy = preempt_policy
        # resume-progress floor: a resumed request may not be preempted
        # again until it has generated this many NEW tokens — without it a
        # tight pool can starve one request with preempt/resume ping-pong.
        # One page of decode progress is the natural default: by then the
        # resume has at least paid for the page it appends.
        self.resume_floor = (resume_floor if resume_floor is not None
                             else (engine.page_size if self.paged else 0))
        # prefix caching (DESIGN.md §11): admission maps a cache-hit prompt
        # prefix onto SHARED pool pages and prefills only the remainder;
        # writes into a shared page copy-on-write first.  Requires paged
        # attention — and silently stays off for models with SSM layers,
        # whose per-slot dense state has no pages to share (the knob is
        # accepted so sweeps stay uniform; ``prefix_cache_active`` says
        # whether sharing is actually on)
        if prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires a PagedEngine — dense "
                             "per-slot caches have no pages to share")
        # device groups (DESIGN.md §13): slots and the usable page range
        # partition into contiguous, as-even-as-possible runs; each group
        # gets a PRIVATE PageAllocator over its run (num_pages/n_reserved
        # double as the range bounds, so every per-group conservation and
        # refcount invariant is the single-allocator one) and, when enabled,
        # its own prefix cache (pages never shared across groups).
        # ``pool_pages`` still restricts the TOTAL usable pool below the
        # engine's physical one — the oversubscription knob the soak sweeps.
        if device_groups < 1:
            raise ValueError(f"device_groups {device_groups} must be >= 1")
        if device_groups > 1 and not self.paged:
            raise ValueError("device_groups > 1 requires a PagedEngine — "
                             "group ownership partitions the page pool")
        if device_groups > engine.batch:
            raise ValueError(f"device_groups {device_groups} exceeds the "
                             f"{engine.batch} batch slots (every group needs "
                             f"at least one)")
        self.admit_watermark = admit_watermark
        self.groups: list[DeviceGroup] = []
        if self.paged:
            usable = (engine.num_pages if pool_pages is None
                      else min(pool_pages, engine.num_pages))
            slot_parts = np.array_split(np.arange(engine.batch),
                                        device_groups)
            page_parts = np.array_split(np.arange(1, usable), device_groups)
            for gid in range(device_groups):
                pages_g = page_parts[gid]
                if len(pages_g) == 0:
                    raise ValueError(f"pool of {usable} usable pages cannot "
                                     f"cover {device_groups} device groups "
                                     f"(group {gid} would own none)")
                alloc = PageAllocator(int(pages_g[-1]) + 1,
                                      n_reserved=int(pages_g[0]),
                                      watermark=admit_watermark)
                pref = (PrefixCache(engine.page_size,
                                    admit_after=prefix_admit)
                        if prefix_cache and engine.supports_prefix_cache
                        else None)
                self.groups.append(DeviceGroup(
                    gid=gid,
                    slot_ids=tuple(int(s) for s in slot_parts[gid]),
                    allocator=alloc, prefix=pref))
        else:
            self.groups.append(DeviceGroup(
                gid=0, slot_ids=tuple(range(engine.batch)), allocator=None))
        self._slot_group = {s: g for g in self.groups for s in g.slot_ids}
        self.cost_params = cost_params or CostModelParams()
        self.tracker = tracker
        self.clock = clock
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.slots = [SlotState(i) for i in range(engine.batch)]
        self.results: list[RequestResult] = []
        self.n_steps = 0
        self.occupied_slot_steps = 0
        # reserve-on-demand state: suspended (preempted) requests by rid,
        # admission sequence numbers, and the preemption counters the bench
        # rows report
        self._suspended: dict[int, _Suspended] = {}
        self._admit_seq = 0
        self.n_preempted = 0
        self.n_admit_deferred = 0
        self.resume_tokens_recomputed = 0
        # prefix-cache counters (bench row extras)
        self.n_prefix_lookups = 0
        self.n_prefix_hits = 0
        self.pages_shared = 0
        self.n_cow_copies = 0
        self.n_cache_insert_deferred = 0
        # robustness layer (DESIGN.md §14): terminal outcomes, deadline
        # enforcement, the step watchdog and group failover
        self.enforce_deadlines = enforce_deadlines
        self.watchdog_budget_s = watchdog_budget_s
        self.unhealthy_after = unhealthy_after
        self.probe_interval_steps = probe_interval_steps
        self.rejoin_backoff_cap = rejoin_backoff_cap
        self.max_restarts = max_restarts
        self.chaos = chaos
        self.outcomes: dict[int, RequestOutcome] = {}
        self._restarts: dict[int, int] = {}
        self.watchdog_trips = 0
        self.n_expired = 0
        self.n_failed = 0
        self.n_group_failovers = 0
        self.n_group_rejoins = 0
        # wall-clock seconds unhealthy groups spent waiting for their next
        # probe — grows with the backoff multiplier when a group flaps
        self.rejoin_backoff_s = 0.0
        # tokens from completed requests that met every declared deadline —
        # the numerator of the serve_overload goodput metric
        self.goodput_tokens = 0
        # monotone count of step() CALLS — unlike n_steps it advances even
        # when nothing decodes (queue waiting on an unhealthy group), so
        # probe scheduling and chaos plans cannot stall with the loop
        self.step_calls = 0
        # EWMAs behind deadline admission: wall time per decode step and the
        # interval between retirements (how fast slots free up)
        self._ewma_step_s = 0.0
        self._ewma_retire_s = 0.0
        self._last_retire_s: float | None = None

    @property
    def allocator(self) -> PageAllocator | None:
        """Single-group compatibility accessor (the pre-§13 attribute).
        With multiple device groups there is no one allocator — use
        ``self.groups[g].allocator``; this raises instead of silently
        returning group 0's."""
        if len(self.groups) == 1:
            return self.groups[0].allocator
        raise RuntimeError(f"{len(self.groups)} device groups — no single "
                           f"allocator; use sched.groups[g].allocator")

    @property
    def prefix(self) -> PrefixCache | None:
        """Single-group compatibility accessor; see :attr:`allocator`."""
        if len(self.groups) == 1:
            return self.groups[0].prefix
        raise RuntimeError(f"{len(self.groups)} device groups — no single "
                           f"prefix cache; use sched.groups[g].prefix")

    @property
    def prefix_cache_active(self) -> bool:
        return any(g.prefix is not None for g in self.groups)

    def restore_suspended(self) -> int:
        """Re-seed the suspended-request table from the tracker's durable
        job store (master restart, DESIGN.md §12).  Call after constructing
        the scheduler and BEFORE resubmitting: requests resubmitted in the
        original order get their original rids back, so a restored record
        turns their admission into a resume — chunked recompute of prompt +
        retained tokens instead of regenerating from scratch.  Returns the
        number of records restored.  No-op without a demand-mode tracker
        backed by a store."""
        if self.tracker is None or not self.demand:
            return 0
        n = 0
        for rid, (tokens, token_s, n_pre) in \
                self.tracker.restore_suspended().items():
            if rid in self._suspended or not tokens:
                continue
            self._suspended[rid] = _Suspended(tokens=tokens, token_s=token_s,
                                              n_preempts=n_pre)
            n += 1
        return n

    # -- submission ------------------------------------------------------------
    def submit(self, tokens, max_new: int, *, enc_embeds=None,
               arrival_s: float | None = None,
               budget_new: int | None = None,
               ttft_deadline_s: float | None = None,
               total_deadline_s: float | None = None) -> int | None:
        """Admit one request.  Returns its rid, or None when shed — the
        queue is full, the request can never fit the engine (prompt bucket
        + declared budget vs ``max_len``), or its TTFT deadline is already
        unmeetable under current load (``sched.outcomes[rid]`` says
        which)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        req = Request(rid=self.queue.next_rid(), tokens=tokens,
                      max_new=max_new, enc_embeds=enc_embeds,
                      budget_new=budget_new,
                      arrival_s=self.clock() if arrival_s is None
                      else arrival_s,
                      ttft_deadline_s=ttft_deadline_s,
                      total_deadline_s=total_deadline_s)
        return req.rid if self._admit(req) else None

    def _admit(self, req: Request) -> bool:
        """The one admission gate (``submit()`` and timed replay): the
        never-fits screen, deadline-aware load shedding, then the bounded
        queue.  Every refusal records its typed terminal outcome."""
        if not self._fits(req):
            self.queue.shed_never_fits += 1
            self._record_outcome(req.rid, "shed_queue", detail="never_fits")
            return False
        if self.enforce_deadlines and req.ttft_deadline_s is not None:
            if self.clock() - req.arrival_s > req.ttft_deadline_s:
                # certain lateness: the wait alone already blew the deadline,
                # no estimate involved
                self.queue.shed_deadline += 1
                self._record_outcome(req.rid, "shed_deadline",
                                     detail="TTFT deadline already passed")
                return False
            if (self._queue_ahead() > 0
                    and self._predicted_ttft_s(req) > req.ttft_deadline_s):
                # predicted lateness: only sheds when there is actual backlog.
                # An idle scheduler must admit even with a pessimistic EWMA —
                # the step EWMA is only updated by live decode waves, so an
                # idle system that shed on stale evidence (e.g. warmup steps
                # that paid compiles) would never run a step to correct it
                # and would shed every request forever.
                self.queue.shed_deadline += 1
                self._record_outcome(req.rid, "shed_deadline",
                                     detail="predicted TTFT over deadline")
                return False
        if not self.queue.submit(req):
            self._record_outcome(req.rid, "shed_queue", detail="queue_full")
            return False
        return True

    def _queue_ahead(self) -> int:
        """Requests a new admission would wait behind: queue depth plus
        itself, minus slots free on healthy groups right now."""
        free = sum(1 for g in self.groups if g.healthy
                   for s in g.slot_ids if self.slots[s].free)
        return max(len(self.queue) + 1 - free, 0)

    def _predicted_ttft_s(self, req: Request) -> float:
        """EWMA estimate of ``req``'s TTFT were it admitted now: time it
        has already waited, the queue draining ahead of it (one retirement
        frees one slot), and its own prefill span.  Zero-initialised EWMAs
        make this start permissive — shedding is LOAD-based and needs
        observed evidence, unlike the structural never-fits screen."""
        ahead = self._queue_ahead()
        if self.paged:
            n_chunks = -(-max(len(req.tokens), 1) // self.engine.chunk_len)
        else:
            n_chunks = 1
        return ((self.clock() - req.arrival_s)
                + ahead * self._ewma_retire_s
                + (n_chunks + 1) * self._ewma_step_s)

    def _record_outcome(self, rid: int, outcome: str,
                        detail: str = "") -> None:
        """Record a request's terminal outcome — exactly once.  A second
        recording raises: the chaos soak's no-request-left-behind guarantee
        is enforced structurally, not asserted after the fact."""
        if outcome not in TERMINAL_OUTCOMES:
            raise ValueError(f"unknown outcome {outcome!r} (want one of "
                             f"{TERMINAL_OUTCOMES})")
        prev = self.outcomes.get(rid)
        if prev is not None:
            raise RuntimeError(
                f"request {rid} reached a second terminal outcome "
                f"{outcome!r} after {prev.outcome!r}")
        self.outcomes[rid] = RequestOutcome(rid=rid, outcome=outcome,
                                            finish_s=self.clock(),
                                            detail=detail)

    def _terminate(self, req: Request, outcome: str,
                   detail: str = "") -> None:
        """Terminal path for a request that will never run again (expired /
        failed / shed after suspension): drop its resume state — host and
        durable — and its tracker job, bump the matching counter, record
        the outcome."""
        self._suspended.pop(req.rid, None)
        if self.tracker is not None:
            self.tracker.abandon(req.rid)
        if outcome == "expired":
            self.n_expired += 1
        elif outcome == "failed":
            self.n_failed += 1
        elif outcome == "shed_deadline":
            self.queue.shed_deadline += 1
        self._record_outcome(req.rid, outcome, detail=detail)

    def _fits(self, req: Request) -> bool:
        """Can this request ever be placed.  Dense: a prompt bucket exists
        and prompt + declared budget stay inside the per-slot cache.
        Paged: its declared-budget page reservation fits the per-slot table
        width and the pool (transient exhaustion is NOT a rejection — the
        request waits for retirements; this check is only the never-fits
        test)."""
        cap = req.declared_new
        if self.paged:
            if len(req.tokens) + cap > self.engine.max_len:
                return False
            need = self.engine.pages_needed(len(req.tokens), cap)
            pool_need = need
            if self.demand:
                # livelock guard: a resume re-prefills up to prompt +
                # max_new - 1 tokens, whose padded chunk span can exceed the
                # lifetime reservation by up to one chunk bucket — the
                # request is only admissible if its worst-case resume (plus
                # the admission watermark) still fits the pool, or a
                # preempted request could be deferred forever
                need = max(need, self.engine.pages_needed(
                    len(req.tokens) + max(cap - 1, 0), 1))
                pool_need = need + self.admit_watermark
            # a request lives entirely inside ONE device group's page range,
            # so the never-fits test is against the LARGEST group's capacity
            group_cap = max(g.allocator.num_pages - g.allocator.n_reserved
                            for g in self.groups)
            return (need <= self.engine.max_pages
                    and pool_need <= group_cap)
        return (self._bucket_len(len(req.tokens)) is not None
                and len(req.tokens) + cap <= self.engine.max_len)

    def _bucket_len(self, n: int) -> int | None:
        for b in self.buckets:
            if b >= n:
                return b
        return None

    # -- slot lifecycle --------------------------------------------------------
    def _sample(self, logits) -> np.ndarray:
        self._key, sub = jax.random.split(self._key)
        return np.asarray(Engine._sample(logits, sub, self.sp))

    def _insert(self, req: Request, slot: int) -> None:
        S = len(req.tokens)
        bucket = self._bucket_len(S)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :S] = req.tokens
        if self.engine.cfg.family == "encdec":
            self.engine.ensure_batch(enc_len=req.enc_embeds.shape[1])
        else:
            self.engine.ensure_batch()
        logits = self.engine.insert(slot, padded, true_len=S,
                                    enc_embeds=req.enc_embeds)
        self._admit_seq += 1
        self.slots[slot].admit_seq = self._admit_seq
        self._first_token(self.slots[slot], req, logits)

    def _first_token(self, st: SlotState, req: Request, logits) -> None:
        """Prefill done (one-shot or final chunk): sample the request's
        first token — time-to-first-token is measured here."""
        tok = int(self._sample(logits)[0])
        now = self.clock()
        st.request, st.pos, st.budget = req, len(req.tokens), req.max_new
        st.tokens, st.token_s = [tok], [now]
        st.next_token, st.finished = tok, False
        st.pos += 1
        st.budget -= 1
        if st.budget <= 0 or (self.sp.stop_token >= 0
                              and tok == self.sp.stop_token):
            st.finished = True

    def _prefill_stream(self, req: Request) -> np.ndarray:
        """The token stream an admission would prefill: the prompt, or —
        resuming a preempted request — prompt + all-but-the-last retained
        token (the last was never fed to decode and becomes ``next_token``
        again once the state is rebuilt)."""
        sus = self._suspended.get(req.rid) if self.demand else None
        if sus is not None:
            return np.concatenate(
                [req.tokens, np.asarray(sus.tokens[:-1], np.int32)])
        return req.tokens

    def _shared_prefix(self, g: DeviceGroup, stream) -> list[int]:
        """Cache-hit pages of GROUP ``g``'s prefix cache usable for this
        prefill stream, floored to a CHUNK boundary strictly below the
        stream end.

        The floor is the bit-exactness contract: K/V values are
        per-position pure functions of the tokens (identical however the
        prefill was chunked), but a chunk's LOGITS depend on where the
        cache-block/self-block softmax split falls — so the final chunk,
        whose logits seed the first sampled token, must be the same chunk a
        full prefill would run.  Flooring the shared span to a multiple of
        ``chunk_len`` below the last chunk's start makes the hit plan an
        exact suffix of the no-cache plan.  A corollary: the serving paths
        never write into the shared span (prefill resumes at the floor,
        decode writes land past the stream end), so COW triggers are
        defensive enforcement of writable-iff-refcount==1, not a steady-
        state cost."""
        if g.prefix is None:
            return []
        chain = g.prefix.lookup(stream)
        if not chain:
            return []
        ps, C = self.engine.page_size, self.engine.chunk_len
        last_chunk = (len(stream) - 1) // C      # the reference plan's tail
        usable_chunks = min((len(chain) * ps) // C, last_chunk)
        return chain[:usable_chunks * (C // ps)]

    def _start_prefill(self, req: Request, slot: int, page_ids: list[int],
                       shared: list[int], stream: np.ndarray) -> None:
        """Paged path: record the chunk plan; chunks run one per ``step()``
        (interleaved with live-batch decode) via ``_advance_prefill``.

        ``shared`` pages (prefix-cache hit, admission already took the
        slot's references) cover the head of ``stream``; the chunk plan
        starts at the shared boundary, so a hit's prefill costs only the
        remainder — at best one chunk (the non-aligned tail).  A resumed
        request (preempted earlier, generated tokens retained in
        ``_suspended``) re-prefills prompt + all-but-the-last retained
        token through the SAME per-bucket chunk programs."""
        self.engine.ensure_batch()
        st = self.slots[slot]
        st.request, st.page_ids = req, list(shared) + list(page_ids)
        self._admit_seq += 1
        st.admit_seq = self._admit_seq
        sus = self._suspended.pop(req.rid, None) if self.demand else None
        st.resume = sus
        st.resume_base = len(sus.tokens) if sus else 0
        st.prefill_tokens = stream
        start = len(shared) * self.engine.page_size
        if sus:
            self.resume_tokens_recomputed += len(stream) - start
        if self._slot_group[slot].prefix is not None:
            self.n_prefix_lookups += 1
            if shared:
                self.n_prefix_hits += 1
                self.pages_shared += len(shared)
        st.pending_chunks = chunk_plan(len(stream),
                                       self.engine.chunk_len,
                                       self.engine.chunk_buckets,
                                       start=start)
        st.tokens, st.token_s, st.finished = [], [], False

    def _advance_prefill(self, st: SlotState) -> None:
        """Run the next chunk of a mid-prefill slot; on the final chunk,
        commit the slot's pages into the live page table and sample the
        first token (fresh request) or restore the retained generation state
        (resume — the final chunk's logits were already sampled once, before
        the preemption, so they are discarded)."""
        start, bucket, valid = st.pending_chunks.pop(0)
        toks = st.prefill_tokens
        ps = self.engine.page_size
        g = self._slot_group[st.slot]
        # writable-iff-refcount==1 enforcement: a chunk write spanning a
        # SHARED page (divergent prefill) must copy-on-write first.  With
        # chunk-floored sharing the plan starts past every shared page, so
        # this is defensive — it fires only if sharing was forged outside
        # the admission path
        first = start // ps
        last = min(-(-(start + bucket) // ps), len(st.page_ids))
        for pidx in range(first, last):
            if not g.allocator.writable(st.page_ids[pidx]):
                if not self._cow_page(st, pidx):
                    raise RuntimeError(
                        f"pool exhausted during copy-on-write of prefill "
                        f"chunk page {pidx} (slot {st.slot})")
        ck = np.zeros((1, bucket), np.int32)
        ck[0, :valid] = toks[start:start + valid]
        logits = self.engine.prefill_chunk(st.slot, ck, st.page_ids, start,
                                           valid)
        if not st.pending_chunks:
            self.engine.commit_slot(st.slot, st.page_ids)
            if g.prefix is not None:
                # cache every full page of the stream — read-only from here
                # on (decode writes land past the stream end)
                before = g.prefix.n_insert_deferred
                g.prefix.insert(toks, st.page_ids, g.allocator)
                self.n_cache_insert_deferred += (g.prefix.n_insert_deferred
                                                 - before)
            if st.resume is not None:
                self._finish_resume(st)
            else:
                self._first_token(st, st.request, logits)

    def _finish_resume(self, st: SlotState) -> None:
        """Final resume chunk done: the cache again holds prompt +
        generated[:-1], exactly the state at preemption.  Restore the
        host-side bookkeeping; the next decode step feeds the last retained
        token as if the preemption never happened."""
        sus, req = st.resume, st.request
        st.resume = None
        st.tokens = list(sus.tokens)
        st.token_s = list(sus.token_s)
        st.pos = len(req.tokens) + len(st.tokens)
        st.budget = req.max_new - len(st.tokens)
        st.next_token = st.tokens[-1]
        st.finished = False

    def _admission_pages(self, req: Request, stream) -> int:
        """Total pages the head request needs to be admitted (shared +
        private).  Lifetime: the full prompt + DECLARED budget reservation
        (it cannot know the realised length up front).  Demand: the
        (padded) prefill span of the stream (prompt + retained tokens) plus
        room for the first decode write — every admission is then
        guaranteed at least one token of progress before it can possibly
        self-preempt, which is what makes the preempt/resume loop
        terminate."""
        if not self.demand:
            return self.engine.pages_needed(len(req.tokens),
                                            req.declared_new)
        return self.engine.pages_needed(len(stream), 1)

    def _admit_pages(self, g: DeviceGroup, n: int) -> list[int] | None:
        """Admission allocation from GROUP ``g`` with prefix-cache fallback:
        when the free list cannot cover it, evict cache-only entries
        (deepest-first) and retry once."""
        pages = g.allocator.admit(n)
        if pages is None and g.prefix is not None:
            if g.prefix.evict_for(g.allocator, n + g.allocator.watermark):
                pages = g.allocator.admit(n)
        return pages

    def _alloc_pages(self, g: DeviceGroup, n: int) -> list[int] | None:
        """Decode-append / COW allocation from GROUP ``g`` (may dip below
        the watermark), with the same cache-eviction fallback."""
        pages = g.allocator.alloc(n)
        if pages is None and g.prefix is not None:
            if g.prefix.evict_for(g.allocator, n):
                pages = g.allocator.alloc(n)
        return pages

    def _route_order(self, groups: Sequence[DeviceGroup],
                     need: int) -> list[DeviceGroup]:
        """Cost-model admission routing across device groups (the paper's
        dynamic job placement at device-group granularity): groups whose
        free pages cover the request outright come first, then by
        queue-depth × decode-EWMA cost (busy slots × per-slot step time,
        seeded with the cost model's dispatch overhead until the EWMA
        warms), free pages breaking ties.  Gid last keeps the order
        deterministic."""
        def score(g: DeviceGroup):
            busy = sum(1 for s in g.slot_ids
                       if self.slots[s].request is not None)
            step_s = g.ewma_step_s or self.cost_params.dispatch_s
            n_free = g.allocator.n_free if g.allocator is not None else 0
            return (0 if n_free >= need else 1, busy * step_s, -n_free, g.gid)
        return sorted(groups, key=score)

    def _fill_free_slots(self) -> None:
        """Admit a wave: pull queued requests while slots (dense) or slots +
        pages (paged) allow, place the WHOLE wave through the tracker in one
        ``plan_segment`` call, then insert (dense) or begin chunked prefill
        (paged).  Paged admission is FIFO: when no group's pool can cover
        the head request's reservation, filling stops until retirements
        free pages (no smaller request overtakes — no starvation of long
        prompts).  Under reserve-on-demand an exhausted pool may instead
        preempt one running victim for the head request — never more than
        one, and only when the victim's pages actually cover the shortfall
        (anti-thrash guard).

        With multiple device groups, each head request is routed to a group
        by :meth:`_route_order` (free pages + queue-depth EWMA — the cost
        model's placement at device-group granularity); its shared-prefix
        hit, page allocation and eventual slot all come from THAT group, so
        page ownership never crosses a group boundary."""
        # unhealthy groups are quarantined out of admission entirely: their
        # free slots don't exist until a probe rejoins them
        free_by_gid = {g.gid: ([s for s in g.slot_ids if self.slots[s].free]
                               if g.healthy else [])
                       for g in self.groups}
        all_free = [s for ss in free_by_gid.values() for s in ss]
        # wave entries: (req, group, reserved slot, pages, shared, stream)
        wave: list[tuple] = []
        while any(free_by_gid.values()) and len(self.queue):
            req = self.queue.pop()
            if not self._fits(req):      # raw queue.submit bypassed admission
                self.queue.shed_never_fits += 1
                self._record_outcome(req.rid, "shed_queue",
                                     detail="never_fits")
                continue
            if self._deadline_drop_queued(req):
                continue
            if not self.paged:
                g = self.groups[0]
                wave.append((req, g, free_by_gid[g.gid].pop(0),
                             None, [], None))
                continue
            stream = self._prefill_stream(req)
            need_total = self._admission_pages(req, stream)
            cands = self._route_order(
                [g for g in self.groups if free_by_gid[g.gid]], need_total)
            placed = False
            for g in cands:
                shared = self._shared_prefix(g, stream)
                if shared:
                    # the slot's references on its hit pages — taken BEFORE
                    # the private allocation, so eviction inside it cannot
                    # reclaim them out from under the admission
                    g.allocator.share(shared)
                pages = self._admit_pages(g, need_total - len(shared))
                if pages is not None:
                    wave.append((req, g, free_by_gid[g.gid].pop(0),
                                 pages, shared, stream))
                    placed = True
                    break
                if shared:               # release the hit refs taken above
                    g.allocator.free(shared)
            if not placed and self.demand and req.rid in self._suspended:
                # only a RESUME may preempt to admit: it already earned
                # its place once and sits at the queue front, so letting
                # it displace a lesser-progressed runner prevents
                # starvation — whereas fresh arrivals preempting grown
                # runners is the recompute-thrash spiral (they wait for
                # retirements instead, like any FIFO admission).  One
                # victim, in the best-scored group only (anti-thrash).
                g = cands[0]
                shared = self._shared_prefix(g, stream)
                if shared:
                    g.allocator.share(shared)
                need = need_total - len(shared)
                # deadline guard: a resume that cannot meet its own total
                # deadline anyway may not displace an on-track runner —
                # trading a request that will count for one that won't
                victim = self._choose_victim(
                    g, shortfall=need + self.admit_watermark
                    - g.allocator.n_free,
                    spare_on_track=self._hopeless(req))
                if victim is not None:
                    self._preempt(victim)
                    pages = self._admit_pages(g, need)
                    if pages is not None:
                        wave.append((req, g, free_by_gid[g.gid].pop(0),
                                     pages, shared, stream))
                        placed = True
                if not placed and shared:
                    g.allocator.free(shared)
            if not placed:               # every pool exhausted: wait
                self.n_admit_deferred += 1
                self.queue.push_front(req)
                break
        if not wave:
            return
        if self.tracker is not None:
            # each request must land in the group whose allocator its pages
            # came from — restrict the master's choice to that group's slots
            choices = ({req.rid: g.slot_ids for req, g, *_ in wave}
                       if len(self.groups) > 1 else None)
            assign = self.tracker.place_batch([w[0] for w in wave], all_free,
                                              slot_choices=choices)
        else:
            assign = {w[0].rid: w[2] for w in wave}
        for req, g, slot0, pages, shared, stream in wave:
            slot = assign[req.rid]
            if self.paged:
                self._start_prefill(req, slot, pages, shared, stream)
            else:
                self._insert(req, slot)

    # -- deadlines (DESIGN.md §14) ---------------------------------------------
    def _deadline_drop_queued(self, req: Request) -> bool:
        """Deadline screen for a POPPED queue entry, moments before pages
        are charged for it: a request whose total deadline passed while it
        waited expires; a FRESH request (no retained first token — a
        resume's TTFT is already history) whose TTFT deadline passed is
        shed.  Returns True when the request was dropped."""
        if not self.enforce_deadlines:
            return False
        now = self.clock()
        waited = now - req.arrival_s
        if (req.total_deadline_s is not None
                and waited > req.total_deadline_s):
            self._terminate(req, "expired",
                            detail="total deadline passed in queue")
            return True
        if (req.ttft_deadline_s is not None
                and req.rid not in self._suspended
                and waited > req.ttft_deadline_s):
            self._terminate(req, "shed_deadline",
                            detail="TTFT deadline passed in queue")
            return True
        return False

    def _deadline_class(self, st: SlotState, now: float) -> int:
        """Victim-priority class of a RUNNING slot: 0 — hopeless (its total
        deadline cannot be met even undisturbed, at the current step EWMA),
        1 — no deadline declared, 2 — on track.  Victim selection takes
        hopeless requests first and on-track ones last: evicting work that
        will count to admit work that won't is the inversion deadline-aware
        preemption exists to prevent."""
        req = st.request
        # getattr: victim-policy tests fake requests with bare objects
        deadline = getattr(req, "total_deadline_s", None)
        if not self.enforce_deadlines or req is None or deadline is None:
            return 1
        eta = now + max(st.budget, 0) * self._ewma_step_s
        return 0 if eta > req.arrival_s + deadline else 2

    def _hopeless(self, req: Request) -> bool:
        """Can this QUEUED request no longer meet its total deadline even
        if admitted immediately and never disturbed (EWMA estimate)?"""
        if not self.enforce_deadlines or req.total_deadline_s is None:
            return False
        done = (len(self._suspended[req.rid].tokens)
                if req.rid in self._suspended else 0)
        if self.paged:
            n_chunks = -(-max(len(req.tokens) + max(done - 1, 0), 1)
                         // self.engine.chunk_len)
        else:
            n_chunks = 1
        eta = (self.clock()
               + (n_chunks + req.max_new - done) * self._ewma_step_s)
        return eta > req.arrival_s + req.total_deadline_s

    # -- reserve-on-demand: preemption -----------------------------------------
    def _floor_ok(self, st: SlotState) -> bool:
        """Resume-progress floor: a resumed request is not a preemption
        victim again until it has generated ``resume_floor`` NEW tokens."""
        return (st.resume_base == 0
                or len(st.tokens) - st.resume_base >= self.resume_floor)

    def _choose_victim(self, g: DeviceGroup, *, shortfall: int = 1,
                       spare_on_track: bool = False) -> SlotState | None:
        """Pick the lowest-priority running slot of GROUP ``g`` to preempt,
        or None — a victim's pages only help an allocation from the same
        group's pool.

        Candidates are live decoding slots (mid-prefill slots hold work
        nothing has been sampled from yet).  Deadline class ranks first —
        hopeless requests (total deadline unmeetable) are preempted before
        deadline-free ones, and on-track ones last (``spare_on_track``
        excludes them outright: set when the beneficiary itself cannot meet
        its deadline).  Within a class, policy ``fewest``: fewest generated
        tokens — the cheapest recompute — with LIFO (latest admitted) as
        the tiebreak; ``lifo``: latest admitted outright.
        Guards: the victim's pages must actually cover ``shortfall`` (the
        pages still missing after the free pool — preempting someone and
        STILL failing the allocation is pure thrash), and the victim must
        pass the resume-progress floor.  When no slot is eligible, the
        caller that cannot proceed without a page self-preempts
        (``_ensure_decode_pages``) — the one case that overrides the
        floor, since the alternative is a write into an unowned page."""
        now = self.clock()
        cands = [s for s in (self.slots[i] for i in g.slot_ids)
                 if s.request is not None and not s.prefilling
                 and not s.finished and self._floor_ok(s)
                 and self._n_exclusive(s) >= shortfall]
        if spare_on_track:
            cands = [s for s in cands if self._deadline_class(s, now) < 2]
        if not cands:
            return None
        if self.preempt_policy == "lifo":
            return min(cands, key=lambda s: (self._deadline_class(s, now),
                                             -s.admit_seq))
        return min(cands, key=lambda s: (self._deadline_class(s, now),
                                         len(s.tokens), -s.admit_seq))

    def _n_exclusive(self, st: SlotState) -> int:
        """Pages preempting this slot would actually return to the free
        list: only its EXCLUSIVELY held ones.  Freeing a shared page merely
        drops one reference — the prefix cache (or another slot) still
        holds it — so counting raw ``page_ids`` would overstate a victim's
        yield and re-introduce the preempt-and-still-fail thrash the
        shortfall guard exists to prevent."""
        alloc = self._slot_group[st.slot].allocator
        return sum(1 for p in st.page_ids if alloc.writable(p))

    def _suspend(self, st: SlotState) -> None:
        """Record the slot's generated tokens as the resume state of its
        request (preemption, or worker failure under demand mode)."""
        prev = self._suspended.get(st.request.rid)
        sus = _Suspended(
            tokens=list(st.tokens), token_s=list(st.token_s),
            n_preempts=(prev.n_preempts + 1 if prev else 1))
        self._suspended[st.request.rid] = sus
        if self.tracker is not None:
            self.tracker.persist_suspended(st.request.rid, sus.tokens,
                                           sus.token_s, sus.n_preempts)

    def _clear_slot(self, st: SlotState) -> None:
        """Reset a slot's host-side bookkeeping to free (pages must already
        be released)."""
        st.request, st.finished = None, False
        st.tokens, st.token_s, st.pending_chunks = [], [], []
        st.resume, st.resume_base, st.prefill_tokens = None, 0, None

    def _preempt(self, st: SlotState) -> None:
        """Reclaim the slot's pages: retain the generated tokens host-side,
        free the pages (the slot parks on the trash page) and put the
        request back at the queue FRONT so it resumes — by chunked
        re-prefill — as soon as pages free up."""
        req = st.request
        self._suspend(st)
        self.n_preempted += 1
        if self.tracker is not None:
            self.tracker.preempt(req)
        self._release_slot(st)
        self._clear_slot(st)
        self.queue.push_front(req)

    def _evict_request(self, st: SlotState, *,
                       count_restart: bool = False) -> int | None:
        """Fault-path eviction — the shared tail of ``fail_slot``, a step
        watchdog trip and group failover.  The slot's device state is
        gone/untrusted; its request goes back through recovery: under
        reserve-on-demand a decoding slot's generated tokens suspend
        (recompute-on-resume keeps TTFT and tokens), a slot evicted
        mid-resume puts its retained record back, anything else re-queues
        from scratch.  ``count_restart`` charges the per-request restart
        budget: a request evicted more than ``max_restarts`` times ends
        ``failed`` instead of re-queued (a poison request cannot cycle
        through fault recovery forever).  Returns the rid, or None for a
        free slot."""
        req = st.request
        if req is None:
            return None
        if self.demand and st.tokens and not st.prefilling \
                and not st.finished:
            self._suspend(st)
        elif self.demand and st.resume is not None:
            # evicted mid-resume-prefill: the retained tokens are still the
            # suspended record — put it back for the next resume attempt
            self._suspended[req.rid] = st.resume
            if self.tracker is not None:
                self.tracker.persist_suspended(
                    req.rid, st.resume.tokens, st.resume.token_s,
                    st.resume.n_preempts)
        self._release_slot(st)
        self._clear_slot(st)
        if count_restart and self.max_restarts is not None:
            self._restarts[req.rid] = self._restarts.get(req.rid, 0) + 1
            if self._restarts[req.rid] > self.max_restarts:
                self._terminate(req, "failed",
                                detail=f"restart budget "
                                       f"{self.max_restarts} exhausted")
                return req.rid
        self.queue.push_front(req)
        return req.rid

    def _ensure_decode_pages(self, live: list[SlotState]) -> list[SlotState]:
        """Reserve-on-demand: before the decode step, make sure every live
        slot owns the page its next KV write lands in (write index =
        ``pos - 1``), appending from the pool at page boundaries.  On
        exhaustion the victim policy picks who loses their pages; the
        appending slot is an ordinary candidate when eligible, and the
        forced fallback — floor notwithstanding — when no slot is (it
        cannot decode without the page).  Returns the slots that still
        hold a live request."""
        ps = self.engine.page_size
        # most-progressed slots claim free pages first: if the pool is
        # short, the policy wants the LEAST progressed slot to lose — this
        # order avoids append-then-get-preempted churn within one step
        order = sorted(live, key=lambda s: (-len(s.tokens), s.admit_seq))
        for st in order:
            g = self._slot_group[st.slot]
            while st.request is not None:
                widx = st.pos - 1        # next KV write position
                if widx >= len(st.page_ids) * ps:
                    pg = self._alloc_pages(g, 1)
                    if pg is not None:
                        st.page_ids.append(pg[0])
                        self.engine.append_page(st.slot, pg[0])
                        continue
                elif g.allocator.writable(st.page_ids[widx // ps]):
                    break
                elif self._cow_page(st, widx // ps):
                    # decode write would land in a SHARED page: copied and
                    # remapped, the slot now writes its private page
                    break
                victim = self._choose_victim(g)
                if victim is None:
                    victim = st          # floor protects only from OTHERS
                self._preempt(victim)
        return [s for s in live if s.request is not None]

    def _cow_page(self, st: SlotState, pidx: int) -> bool:
        """Copy-on-write: give the slot a private copy of its shared page
        ``pidx`` — allocate a fresh page, copy the pool block, swap the
        slot's mapping (``page_ids`` and, for a committed slot, the live
        table row) and release the slot's reference on the original (the
        other holders keep reading it untouched).  Returns False when the
        pool cannot supply the copy target — the caller preempts and
        retries."""
        g = self._slot_group[st.slot]
        pg = self._alloc_pages(g, 1)
        if pg is None:
            return False
        src, dst = st.page_ids[pidx], pg[0]
        self.engine.copy_page(src, dst)
        st.page_ids[pidx] = dst
        if not st.prefilling:
            # mid-prefill slots' live rows park on the trash page; their
            # real row is installed wholesale by commit_slot
            self.engine.remap_slot_page(st.slot, pidx, dst)
        g.allocator.free([src])
        self.n_cow_copies += 1
        return True

    def _release_slot(self, st: SlotState) -> None:
        """Hand the slot's pages back to its group's pool and point its
        page-table row at the trash page (paged engines only)."""
        if self.paged and st.page_ids:
            self._slot_group[st.slot].allocator.free(st.page_ids)
            self.engine.free_slot(st.slot)
            st.page_ids = []

    def _deadlines_met(self, req: Request, res: RequestResult) -> bool:
        """Did the request meet every deadline it declared — the goodput
        criterion (no deadline declared counts as met)."""
        if (req.ttft_deadline_s is not None
                and res.ttft_s > req.ttft_deadline_s):
            return False
        return (req.total_deadline_s is None
                or res.finish_s - req.arrival_s <= req.total_deadline_s)

    def _retire_finished(self) -> None:
        now = self.clock()
        for st in self.slots:
            if st.request is None or not st.finished:
                continue
            req = st.request
            res = RequestResult(rid=req.rid, prompt_len=len(req.tokens),
                                tokens=list(st.tokens),
                                arrival_s=req.arrival_s,
                                token_s=list(st.token_s), finish_s=now)
            self.results.append(res)
            self._record_outcome(req.rid, "completed")
            if self._deadlines_met(req, res):
                self.goodput_tokens += res.n_generated
            if self._last_retire_s is not None:
                dt = now - self._last_retire_s
                self._ewma_retire_s = (
                    dt if self._ewma_retire_s == 0.0
                    else 0.7 * self._ewma_retire_s + 0.3 * dt)
            self._last_retire_s = now
            if self.tracker is not None:
                self.tracker.finish(req, st.slot, np.asarray(st.tokens))
                self.tracker.retire(req)
            self._release_slot(st)
            self._clear_slot(st)

    def _expire_running(self) -> None:
        """Retire in-flight requests whose TOTAL deadline has passed: the
        slot frees immediately (its remaining decode steps would be pure
        waste — the client stopped listening), partial work is discarded
        and the ``expired`` outcome recorded."""
        now = self.clock()
        for st in self.slots:
            req = st.request
            if (req is None or req.total_deadline_s is None
                    or now - req.arrival_s <= req.total_deadline_s):
                continue
            self._release_slot(st)
            self._clear_slot(st)
            self._terminate(req, "expired",
                            detail="total deadline passed mid-flight")

    def fail_slot(self, slot: int) -> int | None:
        """Simulate losing a slot's device-local KV (worker failure).  Under
        full-lifetime reservation the in-flight request restarts from its
        prompt; under reserve-on-demand the generated tokens live host-side
        anyway (the preemption path retains them), so recovery reuses the
        resume machinery — the request recomputes prompt + retained tokens
        instead of regenerating from scratch.  Returns the rid."""
        st = self.slots[slot]
        if self.tracker is not None:
            self.tracker.fail(slot, rid=st.request.rid if st.request
                              else None)
        return self._evict_request(st, count_restart=True)

    # -- group failover (DESIGN.md §14) ----------------------------------------
    def fail_group(self, gid: int, *, reason: str = "injected") -> int:
        """Mark device group ``gid`` unhealthy and quarantine it: every
        in-flight request on its slots is evicted back through the recovery
        path (the next admission wave re-routes them to healthy groups —
        page ownership still never crosses a group boundary, the request
        simply re-prefills from the new group's pool), its prefix cache is
        flushed (KV resident on a failed device is untrusted), any
        chaos-held pages are released, and the allocator is leak-checked:
        a quarantined group must own ZERO outstanding pages.  Returns the
        number of evicted requests.  The group rejoins via
        :meth:`probe_group`, attempted automatically every
        ``probe_interval_steps * probe_backoff`` scheduler calls."""
        g = self.groups[gid]
        if not g.healthy:
            return 0
        g.healthy = False
        g.down_step = self.step_calls
        # flaky-group backoff (ROADMAP 5c): failing again shortly after a
        # rejoin doubles the probe interval (capped) instead of flapping at
        # constant cadence; a long stable stretch forgives the history and
        # a fresh incident starts back at the base cadence.
        stable_steps = self.probe_interval_steps * self.rejoin_backoff_cap
        if g.up_step and self.step_calls - g.up_step < stable_steps:
            g.probe_backoff = min(g.probe_backoff * 2,
                                  self.rejoin_backoff_cap)
        else:
            g.probe_backoff = 1
        g.backoff_wall = self.clock()
        n = 0
        for slot in g.slot_ids:
            st = self.slots[slot]
            if st.request is None:
                continue
            if self.tracker is not None:
                self.tracker.fail(slot, rid=st.request.rid)
            self._evict_request(st, count_restart=True)
            n += 1
        if g.prefix is not None:
            g.prefix.flush(g.allocator)
        if self.chaos is not None:
            self.chaos.release_pages(self, gid=gid)
        if g.allocator is not None and g.allocator.n_outstanding:
            raise RuntimeError(
                f"group {gid} failed ({reason}) with "
                f"{g.allocator.n_outstanding} pages still outstanding — "
                f"quarantine leak")
        self.n_group_failovers += 1
        return n

    def probe_group(self, gid: int) -> bool:
        """Health probe for an unhealthy group: the chaos gate (is the
        injected fault still active?), a real device round-trip through the
        engine, and the quarantine invariant (allocator fully drained).  On
        success the group rejoins admission with its trip counter cleared;
        on failure the probe interval re-arms and the backoff multiplier
        doubles (capped at ``rejoin_backoff_cap``), so a dead group is
        probed exponentially less often instead of at constant cadence."""
        g = self.groups[gid]
        if g.healthy:
            return True
        if g.backoff_wall is not None:
            # close the waiting window opened at the last re-arm: this is
            # the rejoin_backoff_s stat FakeClock soaks assert against
            self.rejoin_backoff_s += self.clock() - g.backoff_wall
            g.backoff_wall = None
        if ((self.chaos is not None
             and not self.chaos.group_healthy(self, gid))
                or not self.engine.probe_device()):
            g.down_step = self.step_calls
            g.probe_backoff = min(g.probe_backoff * 2,
                                  self.rejoin_backoff_cap)
            g.backoff_wall = self.clock()
            return False
        if g.allocator is not None and g.allocator.n_outstanding:
            raise RuntimeError(
                f"group {gid} cannot rejoin: {g.allocator.n_outstanding} "
                f"pages leaked while quarantined")
        g.healthy = True
        g.watchdog_trips = 0
        g.up_step = self.step_calls
        self.n_group_rejoins += 1
        return True

    def _probe_groups(self) -> None:
        for g in self.groups:
            if (not g.healthy and self.step_calls - g.down_step
                    >= self.probe_interval_steps * g.probe_backoff):
                self.probe_group(g.gid)

    # -- step watchdog (DESIGN.md §14) -----------------------------------------
    def _chaos_extra_s(self, gid: int) -> float:
        """Injected slow-step seconds for this group — added to the
        MEASURED step duration, not slept, so chaos soaks stay fast and the
        watchdog sees exactly what a wedged device would show it."""
        return (self.chaos.step_extra_s(self, gid)
                if self.chaos is not None else 0.0)

    def _watch_prefill(self, st: SlotState, dt: float) -> None:
        """Wall-clock budget around one prefill chunk: an over-budget slot
        is evicted back through the recovery path (the chunk may be wedged
        — its work is recomputed elsewhere) and its group moves toward
        unhealthy."""
        if self.watchdog_budget_s is None or st.request is None:
            return
        g = self._slot_group[st.slot]
        if dt + self._chaos_extra_s(g.gid) <= self.watchdog_budget_s:
            return
        self.watchdog_trips += 1
        g.watchdog_trips += 1
        self._evict_request(st, count_restart=True)
        if g.healthy and g.watchdog_trips >= self.unhealthy_after:
            self.fail_group(g.gid, reason="watchdog")

    def _watch_decode(self, live: list[SlotState], dt: float) -> None:
        """Wall-clock budget around the decode wave.  One decode call spans
        the whole batch, so finer attribution than per-group is impossible:
        every group with live slots in an over-budget wave takes a trip and
        evicts its least-progressed live slot (the cheapest recompute —
        possibly the wedged one; repeat offenders drive the group to
        unhealthy either way)."""
        if self.watchdog_budget_s is None:
            return
        for g in self.groups:
            mine = [s for s in live if s.slot in g.slot_ids]
            if not mine:
                continue
            if dt + self._chaos_extra_s(g.gid) <= self.watchdog_budget_s:
                continue
            self.watchdog_trips += 1
            g.watchdog_trips += 1
            victim = min((s for s in mine if s.request is not None
                          and not s.finished),
                         key=lambda s: (len(s.tokens), -s.admit_seq),
                         default=None)
            if victim is not None:
                self._evict_request(victim, count_restart=True)
            if g.healthy and g.watchdog_trips >= self.unhealthy_after:
                self.fail_group(g.gid, reason="watchdog")

    # -- the loop --------------------------------------------------------------
    def step(self) -> bool:
        """Fill free slots, advance one prefill chunk per mid-prefill slot,
        run one decode step over the live batch, retire finished requests.
        Returns False when nothing is in flight.

        Chunk interleaving policy: one chunk per prefilling slot per step,
        decode in between — a long prompt costs its chunk count in steps,
        but the live batch keeps emitting tokens throughout instead of
        stalling for the whole prompt (the utilisation loss the paper's
        overlapping-segments design warns about)."""
        self.step_calls += 1
        if self.chaos is not None:
            self.chaos.on_step(self)
        self._probe_groups()
        self._fill_free_slots()
        for st in self.slots:
            if st.prefilling:
                t0 = self.clock()
                self._advance_prefill(st)
                self._watch_prefill(st, self.clock() - t0)
        self._retire_finished()          # budget-1 requests end at prefill
        if self.enforce_deadlines:
            self._expire_running()
        live = [s for s in self.slots
                if s.request is not None and not s.prefilling]
        if self.demand and live:
            # reserve-on-demand: appends (or preemptions) BEFORE the decode
            # write that would cross into an unowned page
            live = self._ensure_decode_pages(live)
        prefilling = [s for s in self.slots if s.prefilling]
        if not live:
            return bool(prefilling) or len(self.queue) > 0
        t0 = self.clock()
        tokens = np.zeros((self.engine.batch, 1), np.int32)
        for st in live:
            tokens[st.slot, 0] = st.next_token
        if self.paged:
            # freeze mid-prefill (and free) slots' SSM state: only slots
            # decoding a real token may advance their per-slot buffers
            mask = np.zeros((self.engine.batch,), bool)
            for st in live:
                mask[st.slot] = True
            ids = self._sample(self.engine.decode(tokens, live_mask=mask))
        else:
            ids = self._sample(self.engine.decode(tokens))
        now = self.clock()
        self.n_steps += 1
        self.occupied_slot_steps += len(live) + len(prefilling)
        self._ewma_step_s = (now - t0 if self._ewma_step_s == 0.0
                             else 0.7 * self._ewma_step_s
                             + 0.3 * (now - t0))
        if self.tracker is not None:
            self.tracker.observe(now - t0, len(live))
        busy = {s.slot for s in live} | {s.slot for s in prefilling}
        for g in self.groups:
            n_busy = sum(1 for s in g.slot_ids if s in busy)
            g.occupied_slot_steps += n_busy
            g_live = sum(1 for s in live if s.slot in g.slot_ids)
            if g_live:
                g.observe((now - t0) / g_live)
        for st in live:
            tok = int(ids[st.slot])
            st.tokens.append(tok)
            st.token_s.append(now)
            st.next_token = tok
            st.pos += 1
            st.budget -= 1
            if st.budget <= 0 or (self.sp.stop_token >= 0
                                  and tok == self.sp.stop_token):
                st.finished = True
        # watchdog AFTER token bookkeeping: an evicted slot's suspended
        # record then includes this wave's token, so resume recomputes the
        # exact state and the output still bit-matches
        self._watch_decode(live, now - t0)
        self._retire_finished()
        return True

    def run(self, requests: Iterable[Request] | None = None,
            ) -> list[RequestResult]:
        """Drive to completion.  Without ``requests``, drains whatever is in
        the queue.  With ``requests`` (relative ``arrival_s`` stamps), does a
        timed open-loop replay: each request is submitted once the wall
        clock passes its arrival offset — the Poisson-trace mode of
        ``launch/serve.py``."""
        pending: deque[Request] = deque()
        if requests is not None:
            pending.extend(sorted(requests, key=lambda r: r.arrival_s))
        t0 = self.clock()
        while True:
            now = self.clock() - t0
            while pending and pending[0].arrival_s <= now:
                req = pending.popleft()
                req.arrival_s += t0      # rebase onto the scheduler clock
                self._admit(req)         # same admission as submit()
            if not self.step():
                if pending:
                    time.sleep(min(max(pending[0].arrival_s - now, 0.0),
                                   0.005))
                    continue
                if len(self.queue) == 0:
                    break
        return self.results

    def reset_metrics(self) -> None:
        """Clear results and counters after a warmup pass so a measured run
        on the SAME scheduler (and therefore the same compiled engine
        programs) starts from clean figures.  Slots must be drained first."""
        if any(not s.free for s in self.slots) or len(self.queue):
            raise RuntimeError("reset_metrics() with requests still in "
                               "flight")
        if self._suspended:
            raise RuntimeError(f"reset_metrics() with suspended requests "
                               f"{sorted(self._suspended)} — preempted "
                               f"requests must resume before the drain")
        self.results = []
        self.n_steps = 0
        self.occupied_slot_steps = 0
        self.queue.n_submitted = 0
        self.queue.reset_shed()
        self.n_preempted = 0
        self.n_admit_deferred = 0
        self.resume_tokens_recomputed = 0
        self.n_prefix_lookups = 0
        self.n_prefix_hits = 0
        self.pages_shared = 0
        self.n_cow_copies = 0
        self.n_cache_insert_deferred = 0
        self.outcomes = {}
        self._restarts = {}
        self.watchdog_trips = 0
        self.n_expired = 0
        self.n_failed = 0
        self.n_group_failovers = 0
        self.n_group_rejoins = 0
        self.rejoin_backoff_s = 0.0
        self.goodput_tokens = 0
        self._last_retire_s = None
        # _ewma_step_s / _ewma_retire_s survive, like the group EWMAs —
        # they are calibration, not run metrics
        for g in self.groups:
            g.occupied_slot_steps = 0     # EWMA step time survives — it is
            #                               calibration, not a run metric
            g.watchdog_trips = 0

    def flush_prefix_cache(self) -> int:
        """Drop every prefix-cache entry in every group, releasing the
        caches' page references (pages shared with live slots stay
        outstanding under the slots' refs).  Returns the number of entries
        dropped — used after warmup so a measured run starts from a cold
        cache, and at drain checks to prove zero leaked references."""
        return sum(g.prefix.flush(g.allocator) for g in self.groups
                   if g.prefix is not None)

    # -- metrics ---------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per decode step."""
        if self.n_steps == 0:
            return 0.0
        return self.occupied_slot_steps / (self.n_steps * self.engine.batch)

    @property
    def group_occupancy(self) -> list[float]:
        """Per-device-group mean busy-slot fraction — the cost-model
        router's balance evidence (both groups nonzero under load)."""
        if self.n_steps == 0:
            return [0.0 for _ in self.groups]
        return [g.occupied_slot_steps / (self.n_steps * len(g.slot_ids))
                for g in self.groups]
