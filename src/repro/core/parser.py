"""Parser for the paper's plain-text job-definition format (§3.3).

Grammar (from the paper's sample input file)::

    J1(1,0,0), J2(2,1,0);
    J3(2,2,R1[0..5],true), J4(2,2,R1[5..10],true), J5(3,0,R1 R2),
     J6(4,0,R1 R2);
    J7(5,1, R2 R3 R4 R5);

* segments separated by ``;`` (a trailing ``;`` is allowed),
* jobs within a segment separated by ``,`` *outside parentheses*,
* each job: ``Jn(fn_id, n_threads, chunk_spec[, true|false])`` with
    - ``fn_id``      int — function identifier registered with the workers,
    - ``n_threads``  int — 0 ⇒ all available cores (paper),
    - ``chunk_spec`` ``0`` (no input) | space-separated refs ``R1 R2`` |
                     sliced ref ``R1[0..5]`` (chunks [0,5)),
    - optional 4th arg  ``true``/``false`` — no_send_back (default false).
"""
from __future__ import annotations

import re

from .job import ChunkRef, GraphValidationError, Job, JobGraph, ParallelSegment

__all__ = ["parse_job_file", "parse_job_text", "format_job_text"]

_JOB_RE = re.compile(r"^(?P<name>[A-Za-z_]\w*)\s*\((?P<args>.*)\)$", re.S)
_REF_RE = re.compile(r"^R(?P<job>\w+?)(?:\[(?P<lo>\d+)\.\.(?P<hi>\d+)\])?$")


def _split_outside_parens(text: str, sep: str) -> list[str]:
    parts, depth, cur = [], 0, []
    for ch in text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise GraphValidationError(f"unbalanced ')' in {text!r}")
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth != 0:
        raise GraphValidationError(f"unbalanced '(' in {text!r}")
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _parse_refs(spec: str) -> tuple[ChunkRef, ...]:
    spec = spec.strip()
    if spec == "0":
        return ()
    refs = []
    for tok in spec.split():
        m = _REF_RE.match(tok)
        if not m:
            raise GraphValidationError(f"bad chunk reference {tok!r}")
        job = "J" + m.group("job") if m.group("job").isdigit() else m.group("job")
        if m.group("lo") is not None:
            refs.append(ChunkRef(job, int(m.group("lo")), int(m.group("hi"))))
        else:
            refs.append(ChunkRef(job))
    return tuple(refs)


def _parse_job(text: str) -> Job:
    m = _JOB_RE.match(text.strip())
    if not m:
        raise GraphValidationError(f"bad job definition {text!r}")
    name = m.group("name")
    args = _split_outside_parens(m.group("args"), ",")
    if not 3 <= len(args) <= 4:
        raise GraphValidationError(
            f"{name}: expected 3 or 4 arguments, got {len(args)} in {text!r}")
    try:
        fn_id = int(args[0])
    except ValueError:
        fn_id = args[0]  # allow symbolic function names as an extension
    n_threads = int(args[1])
    inputs = _parse_refs(args[2])
    nsb = False
    if len(args) == 4:
        if args[3].lower() not in ("true", "false"):
            raise GraphValidationError(f"{name}: bad no_send_back flag {args[3]!r}")
        nsb = args[3].lower() == "true"
    return Job(name=name, fn=fn_id, n_threads=n_threads, inputs=inputs,
               no_send_back=nsb)


def parse_job_text(text: str) -> JobGraph:
    # strip comments (# ... end-of-line) — an extension for readable files
    text = re.sub(r"#[^\n]*", "", text)
    segments = []
    for seg_text in _split_outside_parens(text.replace("\n", " "), ";"):
        jobs = [_parse_job(j) for j in _split_outside_parens(seg_text, ",")]
        segments.append(ParallelSegment(jobs))
    return JobGraph(segments)


def parse_job_file(path: str) -> JobGraph:
    with open(path) as f:
        return parse_job_text(f.read())


def format_job_text(graph: JobGraph) -> str:
    """Inverse of :func:`parse_job_text` (round-trip tested)."""
    out_lines = []
    for seg in graph.segments:
        jobs = []
        for j in seg.jobs:
            spec = " ".join(
                (f"R{r.job[1:]}" if r.job.startswith("J") and r.job[1:].isdigit()
                 else f"R{r.job}")
                + ("" if r.whole else f"[{r.lo}..{r.hi}]")
                for r in j.inputs) or "0"
            args = f"{j.fn},{j.n_threads},{spec}"
            if j.no_send_back:
                args += ",true"
            jobs.append(f"{j.name}({args})")
        out_lines.append(", ".join(jobs) + ";")
    return "\n".join(out_lines)
