"""HyPar core — the paper's hybrid-parallelisation job model in JAX.

Public API::

    from repro.core import (
        DataChunk, ChunkedData, ChunkRef, Job, ParallelSegment, JobGraph,
        FunctionRegistry, parse_job_text, parse_job_file,
        VirtualCluster, LocalExecutor, SpmdExecutor, IterativeSpec,
        FaultInjector, ChaosLocalExecutor,
    )
"""
from .job import (ChunkedData, ChunkRef, DataChunk, GraphValidationError, Job,
                  JobGraph, ParallelSegment)
from .registry import ControlContext, FunctionKind, FunctionRegistry
from .parser import format_job_text, parse_job_file, parse_job_text
from .scheduler import (CostModelParams, MasterScheduler, Placement,
                        ResultStore, SchedulerProc, VirtualCluster, Worker)
from .executor import (BaseExecutor, ExecutionReport, IterativeSpec,
                       LocalExecutor, SpmdExecutor)
from .fault import ChaosLocalExecutor, FaultInjector, Heartbeat
from .store import JobStore, job_key
from .procworker import ProcessExecutor, WorkerFunctionError

__all__ = [
    "ChunkedData", "ChunkRef", "DataChunk", "GraphValidationError", "Job",
    "JobGraph", "ParallelSegment", "ControlContext", "FunctionKind",
    "FunctionRegistry", "format_job_text", "parse_job_file", "parse_job_text",
    "BaseExecutor", "CostModelParams", "MasterScheduler", "Placement",
    "ResultStore", "SchedulerProc",
    "VirtualCluster", "Worker", "ExecutionReport", "IterativeSpec",
    "LocalExecutor", "SpmdExecutor", "ChaosLocalExecutor", "FaultInjector",
    "Heartbeat", "JobStore", "job_key", "ProcessExecutor",
    "WorkerFunctionError",
]
