"""Durable job store — sqlite-backed persistence for the cluster runtime.

The paper's §5 names the cost of ``no_send_back``: "in case a worker has to
be shut down, all results computed so far are lost and have to be
re-computed".  The executors recover by *recomputing* (lineage recovery);
this module removes the recomputation for anything that already finished by
persisting results keyed on **content identity** — the registered function
name plus a canonical hash of the input arrays — so a restarted run (or a
run that lost its master process entirely) resumes from ``done`` rows
instead of re-executing them (orco-style memoisation, SNIPPETS §1).

Three tables:

* ``jobs``    — one row per content-identity key: state machine
                ``pending → running → done`` (or ``lost`` when the owning
                worker dies mid-job), retry count, and the result payload —
                small results inline as an npz blob, large ones spilled to
                ``<store>.d/<key>.npz``.
* ``workers`` — executor/worker registrations with wall-clock
                ``last_heartbeat`` stamps; the master's monitor *discovers*
                dead workers by heartbeat expiry instead of being told via
                an explicit ``fail()`` call.
* ``requests``— serve-path host-retained state (generated tokens of
                suspended requests) so recompute-on-resume (DESIGN §10)
                survives a master restart, not just a worker death.

Deliberately **jax-free**: worker child processes import this module and
must not pay the multi-second jax import (nor touch a device).

Concurrency: WAL journal mode + busy_timeout makes concurrent writers from
the master and every worker process safe; within one process a single
connection is shared behind a lock (sqlite serialises at the VFS level
across processes, we serialise at the connection level within one).
"""
from __future__ import annotations

import hashlib
import io
import os
import sqlite3
import threading
import time
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

__all__ = ["JobStore", "job_key"]


def _canon(a: Any) -> np.ndarray:
    arr = np.asarray(a)
    return np.ascontiguousarray(arr)


def job_key(fn_name: str, inputs: Iterable[Any]) -> str:
    """Content identity of a job: registered function name + canonical hash
    of every input array (dtype, shape, raw bytes).  Two jobs with the same
    key compute the same result, whatever their graph-local names are —
    which is exactly what lets a *restarted* run hit rows written by a
    previous incarnation of the same graph."""
    h = hashlib.sha256()
    h.update(fn_name.encode())
    for a in inputs:
        arr = _canon(a)
        h.update(b"|")
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _pack(arrays: Sequence[Any]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{f"a{i}": _canon(a) for i, a in enumerate(arrays)})
    return buf.getvalue()


def _unpack(blob: bytes) -> list[np.ndarray]:
    with np.load(io.BytesIO(blob)) as z:
        return [z[f"a{i}"] for i in range(len(z.files))]


_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
    key        TEXT PRIMARY KEY,
    name       TEXT,
    fn         TEXT,
    state      TEXT NOT NULL DEFAULT 'pending',
    worker     INTEGER,
    retries    INTEGER NOT NULL DEFAULT 0,
    payload    BLOB,
    spill      TEXT,
    updated_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS workers (
    wid            INTEGER PRIMARY KEY,
    pid            INTEGER,
    started_at     REAL NOT NULL,
    last_heartbeat REAL NOT NULL,
    alive          INTEGER NOT NULL DEFAULT 1
);
CREATE TABLE IF NOT EXISTS requests (
    rid        TEXT PRIMARY KEY,
    payload    BLOB NOT NULL,
    updated_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS meta (
    k TEXT PRIMARY KEY,
    v TEXT
);
"""


class JobStore:
    """One sqlite file = one durable run.  Safe for one writer per process
    and many processes (WAL); every method is atomic."""

    STATES = ("pending", "running", "done", "lost")

    def __init__(self, path: str | os.PathLike, *,
                 spill_bytes: int = 1 << 20):
        self.path = os.fspath(path)
        self.spill_bytes = spill_bytes
        self.spill_dir = self.path + ".d"
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(self.path, timeout=30.0,
                                     check_same_thread=False)
        self._conn.execute("PRAGMA journal_mode=WAL")
        self._conn.execute("PRAGMA synchronous=NORMAL")
        self._conn.execute("PRAGMA busy_timeout=30000")
        with self._lock, self._conn:
            self._conn.executescript(_SCHEMA)

    def close(self) -> None:
        with self._lock:
            self._conn.close()

    # -- job state machine -------------------------------------------------
    def state(self, key: str) -> str | None:
        with self._lock:
            row = self._conn.execute(
                "SELECT state FROM jobs WHERE key=?", (key,)).fetchone()
        return row[0] if row else None

    def mark_running(self, key: str, *, name: str = "", fn: str = "",
                     worker: int | None = None) -> None:
        """Claim a job (pending/lost → running); done rows are untouched —
        the caller should have taken the memoised result instead."""
        now = time.time()
        with self._lock, self._conn:
            self._conn.execute(
                "INSERT INTO jobs(key, name, fn, state, worker, updated_at) "
                "VALUES(?,?,?,'running',?,?) "
                "ON CONFLICT(key) DO UPDATE SET "
                "  state=CASE WHEN jobs.state='done' THEN 'done' ELSE 'running' END, "
                "  name=excluded.name, fn=excluded.fn, "
                "  worker=excluded.worker, updated_at=excluded.updated_at",
                (key, name, fn, worker, now))

    def bump_retries(self, key: str) -> int:
        with self._lock, self._conn:
            self._conn.execute(
                "UPDATE jobs SET retries=retries+1, updated_at=? WHERE key=?",
                (time.time(), key))
            row = self._conn.execute(
                "SELECT retries FROM jobs WHERE key=?", (key,)).fetchone()
        return int(row[0]) if row else 0

    def put_result(self, key: str, arrays: Sequence[Any], *,
                   name: str = "", fn: str = "",
                   worker: int | None = None) -> None:
        """Persist a finished job's result (state → done).  Results above
        ``spill_bytes`` go to a spill file under the run dir; the row keeps
        only the relative filename."""
        blob = _pack(arrays)
        spill = None
        payload: bytes | None = blob
        if len(blob) > self.spill_bytes:
            os.makedirs(self.spill_dir, exist_ok=True)
            spill = key + ".npz"
            tmp = os.path.join(self.spill_dir, spill + ".tmp")
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, os.path.join(self.spill_dir, spill))
            payload = None
        now = time.time()
        with self._lock, self._conn:
            self._conn.execute(
                "INSERT INTO jobs(key, name, fn, state, worker, payload, spill, updated_at) "
                "VALUES(?,?,?,'done',?,?,?,?) "
                "ON CONFLICT(key) DO UPDATE SET state='done', "
                "  name=excluded.name, fn=excluded.fn, worker=excluded.worker, "
                "  payload=excluded.payload, spill=excluded.spill, "
                "  updated_at=excluded.updated_at",
                (key, name, fn, worker, payload, spill, now))

    def load_result(self, key: str) -> list[np.ndarray] | None:
        """Memoisation hit: the arrays of a ``done`` row, else None."""
        with self._lock:
            row = self._conn.execute(
                "SELECT state, payload, spill FROM jobs WHERE key=?",
                (key,)).fetchone()
        if row is None or row[0] != "done":
            return None
        state, payload, spill = row
        if payload is not None:
            return _unpack(payload)
        fp = os.path.join(self.spill_dir, spill)
        try:
            with open(fp, "rb") as f:
                return _unpack(f.read())
        except FileNotFoundError:
            return None

    def mark_lost(self, key: str) -> None:
        with self._lock, self._conn:
            self._conn.execute(
                "UPDATE jobs SET state='lost', updated_at=? "
                "WHERE key=? AND state!='done'", (time.time(), key))

    def mark_worker_jobs_lost(self, wid: int) -> list[str]:
        """A worker died: every job it was *running* is lost (its in-flight
        work is gone; its done rows stay — they were persisted first)."""
        with self._lock, self._conn:
            rows = self._conn.execute(
                "SELECT key FROM jobs WHERE worker=? AND state='running'",
                (wid,)).fetchall()
            self._conn.execute(
                "UPDATE jobs SET state='lost', updated_at=? "
                "WHERE worker=? AND state='running'", (time.time(), wid))
        return [r[0] for r in rows]

    def counts(self) -> dict[str, int]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT state, COUNT(*) FROM jobs GROUP BY state").fetchall()
        return {state: n for state, n in rows}

    def n_done(self) -> int:
        return self.counts().get("done", 0)

    def gc(self, *, max_age_s: float | None = None,
           max_rows: int | None = None,
           now: float | None = None,
           exempt_requests: Iterable[str] = ()) -> dict[str, int]:
        """Prune ``done`` rows (and their spill files) so a long-lived store
        does not grow without bound: drop rows older than ``max_age_s``,
        then — of the survivors — keep only the ``max_rows`` most recently
        updated.  Only ``done`` rows are ever candidates: pending/running/
        lost rows carry live scheduling state and dropping one would
        re-execute (or worse, double-claim) in-flight work, so the state
        filter is structural, not a fast path.

        ``requests`` rows (serve suspended-token payloads) are pruned by
        the same ``max_age_s`` cutoff: the serving path deletes them at
        retire, so in steady state none reach the cutoff — rows that DO are
        orphans of a master that died before retiring them and would
        otherwise leak forever.  ``exempt_requests`` protects keys a live
        run still counts on (its running/suspended rids); ``max_rows``
        deliberately does not apply — age is the only evidence a request
        row is orphaned, whereas result rows are re-computable memoisation.

        Returns ``{"rows": pruned_rows, "spill_files": unlinked_files,
        "request_rows": pruned_request_rows}``."""
        if max_age_s is None and max_rows is None:
            return {"rows": 0, "spill_files": 0, "request_rows": 0}
        if max_age_s is not None and max_age_s < 0:
            raise ValueError(f"max_age_s {max_age_s} must be >= 0")
        if max_rows is not None and max_rows < 0:
            raise ValueError(f"max_rows {max_rows} must be >= 0")
        now = time.time() if now is None else now
        with self._lock, self._conn:
            doomed = []
            if max_age_s is not None:
                doomed += self._conn.execute(
                    "SELECT key, spill FROM jobs "
                    "WHERE state='done' AND updated_at < ?",
                    (now - max_age_s,)).fetchall()
            if max_rows is not None:
                survivors = self._conn.execute(
                    "SELECT key, spill FROM jobs WHERE state='done' "
                    + ("AND updated_at >= ? " if max_age_s is not None else "")
                    + "ORDER BY updated_at DESC",
                    ((now - max_age_s,) if max_age_s is not None else ()),
                ).fetchall()
                doomed += survivors[max_rows:]
            self._conn.executemany(
                "DELETE FROM jobs WHERE key=? AND state='done'",
                [(key,) for key, _ in doomed])
            req_doomed: list[str] = []
            if max_age_s is not None:
                exempt = set(exempt_requests)
                req_doomed = [rid for (rid,) in self._conn.execute(
                    "SELECT rid FROM requests WHERE updated_at < ?",
                    (now - max_age_s,)).fetchall() if rid not in exempt]
                self._conn.executemany(
                    "DELETE FROM requests WHERE rid=?",
                    [(r,) for r in req_doomed])
        spilled = 0
        for _, spill in doomed:
            if spill is None:
                continue
            try:
                os.remove(os.path.join(self.spill_dir, spill))
                spilled += 1
            except FileNotFoundError:
                pass
        return {"rows": len(doomed), "spill_files": spilled,
                "request_rows": len(req_doomed)}

    # -- worker registration / heartbeats ---------------------------------
    def register_worker(self, wid: int, pid: int | None = None) -> None:
        """Registration counts as the first beat — a worker spawned just
        before a monitor tick must not be declared dead before it runs a
        single job (the Heartbeat round-0 bug, fixed the same way)."""
        now = time.time()
        with self._lock, self._conn:
            self._conn.execute(
                "INSERT INTO workers(wid, pid, started_at, last_heartbeat, alive) "
                "VALUES(?,?,?,?,1) "
                "ON CONFLICT(wid) DO UPDATE SET pid=excluded.pid, "
                "  started_at=excluded.started_at, "
                "  last_heartbeat=excluded.last_heartbeat, alive=1",
                (wid, pid, now, now))

    def beat(self, wid: int) -> None:
        with self._lock, self._conn:
            self._conn.execute(
                "UPDATE workers SET last_heartbeat=? WHERE wid=?",
                (time.time(), wid))

    def heartbeats(self) -> dict[int, float]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT wid, last_heartbeat FROM workers WHERE alive=1").fetchall()
        return {wid: hb for wid, hb in rows}

    def expired(self, timeout_s: float, *, boot_grace_s: float | None = None,
                now: float | None = None) -> list[int]:
        """Wids whose heartbeat is older than ``timeout_s`` — discovery, not
        notification: nobody calls fail(), the silence itself is the signal.

        A row whose ``pid`` is still NULL was registered by the master but
        its process has not checked in yet (interpreter boot + imports can
        far exceed the beat interval); such workers only expire after
        ``boot_grace_s``."""
        now = time.time() if now is None else now
        with self._lock:
            rows = self._conn.execute(
                "SELECT wid, last_heartbeat, pid FROM workers "
                "WHERE alive=1").fetchall()
        out = []
        for wid, hb, pid in rows:
            limit = timeout_s if pid is not None else max(
                timeout_s, boot_grace_s if boot_grace_s is not None else timeout_s)
            if now - hb > limit:
                out.append(wid)
        return out

    def booted_wids(self) -> list[int]:
        """Alive workers whose process has checked in (stamped its pid)."""
        with self._lock:
            rows = self._conn.execute(
                "SELECT wid FROM workers WHERE alive=1 AND pid IS NOT NULL"
            ).fetchall()
        return [r[0] for r in rows]

    def mark_worker_dead(self, wid: int) -> None:
        with self._lock, self._conn:
            self._conn.execute(
                "UPDATE workers SET alive=0 WHERE wid=?", (wid,))

    # -- serve-path request persistence -----------------------------------
    def put_request(self, rid: str, fields: Mapping[str, Any]) -> None:
        """Persist a request's host-retained recovery state (tokens etc.)
        as an npz of named arrays."""
        buf = io.BytesIO()
        np.savez(buf, **{k: _canon(v) for k, v in fields.items()})
        with self._lock, self._conn:
            self._conn.execute(
                "INSERT INTO requests(rid, payload, updated_at) VALUES(?,?,?) "
                "ON CONFLICT(rid) DO UPDATE SET payload=excluded.payload, "
                "  updated_at=excluded.updated_at",
                (rid, buf.getvalue(), time.time()))

    def get_request(self, rid: str) -> dict[str, np.ndarray] | None:
        with self._lock:
            row = self._conn.execute(
                "SELECT payload FROM requests WHERE rid=?", (rid,)).fetchone()
        if row is None:
            return None
        with np.load(io.BytesIO(row[0])) as z:
            return {k: z[k] for k in z.files}

    def get_requests(self) -> dict[str, dict[str, np.ndarray]]:
        with self._lock:
            rids = [r[0] for r in self._conn.execute(
                "SELECT rid FROM requests").fetchall()]
        return {rid: req for rid in rids
                if (req := self.get_request(rid)) is not None}

    def delete_request(self, rid: str) -> None:
        with self._lock, self._conn:
            self._conn.execute("DELETE FROM requests WHERE rid=?", (rid,))

    # -- meta / hygiene ----------------------------------------------------
    def set_meta(self, k: str, v: str) -> None:
        with self._lock, self._conn:
            self._conn.execute(
                "INSERT INTO meta(k, v) VALUES(?,?) "
                "ON CONFLICT(k) DO UPDATE SET v=excluded.v", (k, v))

    def get_meta(self, k: str) -> str | None:
        with self._lock:
            row = self._conn.execute(
                "SELECT v FROM meta WHERE k=?", (k,)).fetchone()
        return row[0] if row else None

    def check_leaks(self) -> list[str]:
        """Store hygiene after a run fully drains: no rows stuck ``running``
        on a dead worker, no orphaned spill files.  Returns human-readable
        problems (empty list == clean) — the crash-soak asserts on this."""
        problems: list[str] = []
        with self._lock:
            stuck = self._conn.execute(
                "SELECT j.key, j.worker FROM jobs j "
                "LEFT JOIN workers w ON j.worker = w.wid "
                "WHERE j.state='running' AND (w.alive IS NULL OR w.alive=0)"
            ).fetchall()
            spills = {r[0] for r in self._conn.execute(
                "SELECT spill FROM jobs WHERE spill IS NOT NULL").fetchall()}
        for key, wid in stuck:
            problems.append(f"job {key[:12]} stuck running on dead worker {wid}")
        if os.path.isdir(self.spill_dir):
            for fname in os.listdir(self.spill_dir):
                if fname.endswith(".tmp") or fname not in spills:
                    problems.append(f"orphan spill file {fname}")
        return problems
