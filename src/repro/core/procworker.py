"""ProcessExecutor — the durable runtime over real worker processes.

The LocalExecutor's workers are threads sharing one GIL and one address
space: failures must be *announced* (explicit ``fail()``) and every result
dies with the process.  This executor keeps the exact same dispatch
machinery (per-worker queues, pipelined/dataflow modes, master placement,
lineage recovery) but backs each :class:`Worker` slot with a real
``multiprocessing`` *spawn* process and a sqlite :class:`JobStore`:

* results are persisted under **content identity** (`job_key`) before the
  worker replies, so a re-run of the same graph — same process or a fresh
  master after a SIGKILL — serves ``done`` jobs from the store instead of
  recomputing them (memoisation);
* workers stamp wall-clock heartbeats into the store; the master's monitor
  thread *discovers* dead workers by heartbeat expiry (store-backed
  :class:`Heartbeat`) — nothing ever calls ``fail()`` on their behalf;
* dispatch gets a per-job timeout and bounded retry with exponential
  backoff: a lost/silent worker's in-flight jobs are re-placed on live
  workers, and the monitor spawns a replacement process for the dead slot.

Worker processes never import jax (see ``_procworker_child``): they resolve
a numpy-level function table from a ``"module:attr"`` spec.  The master
keeps its normal registry for job *kinds* and for control functions, which
still run on the host.

Because every process result is sent back **and** persisted, a worker death
loses only its in-flight jobs — the paper's ``no_send_back`` recompute cost
(§5) disappears: lineage recovery becomes a store lookup.
"""
from __future__ import annotations

import itertools
import multiprocessing
import queue
import tempfile
import threading
import time

import numpy as np

from . import _procworker_child
from .executor import LocalExecutor, SegmentReport
from .fault import Heartbeat
from .job import ChunkedData, DataChunk, Job, JobGraph
from .registry import ControlContext, FunctionKind, FunctionRegistry
from .scheduler import CostModelParams, VirtualCluster, Worker
from .store import JobStore, job_key

__all__ = ["ProcessExecutor", "WorkerFunctionError"]


class WorkerFunctionError(RuntimeError):
    """A worker function raised — deterministic, so not retried."""


class _ProcHandle:
    """Master-side channel to one worker process.  ``ch_lock`` serialises
    request/response pairs (never held while taking the executor lock, so
    lineage recovery under the dispatch lock cannot deadlock a finishing
    job that needs it)."""

    def __init__(self, wid: int, process, req_q, resp_q):
        self.wid = wid
        self.process = process
        self.req_q = req_q
        self.resp_q = resp_q
        self.lost = False
        self.ch_lock = threading.Lock()
        self.seq = itertools.count()


class ProcessExecutor(LocalExecutor):
    """LocalExecutor whose worker slots are real spawn processes.

    ``worker_fns`` — ``"module:attr"`` spec of the child-side function
    table: a dict mapping ``str(fid)`` of every non-control registry entry
    to a plain numpy function (the paper's fat-worker registration).
    ``store`` — path to the sqlite store (or a JobStore; its path is
    reused — each process opens its own connection).  None ⇒ a fresh
    temporary store (no cross-run memoisation).
    """

    def __init__(self, cluster: VirtualCluster, registry: FunctionRegistry,
                 worker_fns: str, *,
                 store: JobStore | str | None = None,
                 mode: str = "pipelined",
                 strategy: str = "greedy",
                 cost_params: CostModelParams | None = None,
                 job_timeout_s: float = 30.0,
                 max_retries: int = 3,
                 backoff_s: float = 0.05,
                 heartbeat_interval_s: float = 0.25,
                 heartbeat_max_missed: int = 5,
                 boot_grace_s: float = 10.0,
                 **kw):
        super().__init__(cluster, registry, mode=mode, strategy=strategy,
                         cost_params=cost_params, **kw)
        self.worker_fns = worker_fns
        if store is None:
            self._tmpdir = tempfile.TemporaryDirectory(prefix="repro-jobstore-")
            store = self._tmpdir.name + "/jobs.sqlite"
        else:
            self._tmpdir = None
        self.jobstore = store if isinstance(store, JobStore) else JobStore(store)
        self.job_timeout_s = job_timeout_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.heartbeat_interval_s = heartbeat_interval_s
        self.heartbeat_max_missed = heartbeat_max_missed
        self.n_executed = 0
        self.n_memoised = 0
        self.procs: dict[int, _ProcHandle] = {}
        self._mp = multiprocessing.get_context("spawn")
        self._hb = Heartbeat(cluster, heartbeat_max_missed,
                             store=self.jobstore,
                             interval_s=heartbeat_interval_s,
                             boot_grace_s=boot_grace_s)
        self._monitor: threading.Thread | None = None
        self._stop = threading.Event()
        self._closed = False

    # -- process lifecycle -------------------------------------------------
    def _spawn_proc(self, wid: int) -> _ProcHandle:
        req_q = self._mp.Queue()
        resp_q = self._mp.Queue()
        # register before start: the row's registration beat covers the
        # child's import window so the monitor never reaps a booting worker
        self.jobstore.register_worker(wid)
        p = self._mp.Process(
            target=_procworker_child.worker_main,
            args=(wid, self.jobstore.path, self.worker_fns,
                  self.heartbeat_interval_s, req_q, resp_q),
            daemon=True, name=f"hypar-proc-w{wid}")
        p.start()
        ph = _ProcHandle(wid, p, req_q, resp_q)
        self.procs[wid] = ph
        self._hb.register(wid)
        return ph

    def _ensure_started(self) -> None:
        if self._closed:
            raise RuntimeError("ProcessExecutor is closed")
        if not self.cluster.workers:
            for _ in range(self.cluster.max_workers):
                self.cluster.spawn_worker()
        for w in self.cluster.alive_workers():
            ph = self.procs.get(w.wid)
            if ph is None or ph.lost:
                self._spawn_proc(w.wid)
        if self._monitor is None:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True, name="hypar-monitor")
            self._monitor.start()

    def _monitor_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval_s):
            try:
                for wid in self._hb.expired_wids():
                    self._declare_lost(wid)
            except Exception:  # monitor must survive transient store errors
                pass

    def _declare_lost(self, wid: int) -> None:
        """Heartbeat-expiry discovery: reap the process, fail the slot,
        mark its in-flight jobs lost, spawn a replacement."""
        ph = self.procs.get(wid)
        if ph is None or ph.lost:
            return
        ph.lost = True
        try:
            ph.process.terminate()
            ph.process.join(timeout=1.0)
        except Exception:
            pass
        self.jobstore.mark_worker_dead(wid)
        self.jobstore.mark_worker_jobs_lost(wid)
        with self._lock:
            dead = next((w for w in self.cluster.workers if w.wid == wid), None)
            if dead is not None and dead.alive:
                dead.fail()
            self.store.invalidate_worker(wid)
            try:
                repl = self.cluster.spawn_worker()
            except RuntimeError:
                repl = None
        if repl is not None:
            self._spawn_proc(repl.wid)

    def close(self) -> None:
        """Stop the monitor and shut every worker process down."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=2.0)
            self._monitor = None
        for ph in self.procs.values():
            if ph.lost:
                continue
            try:
                ph.req_q.put(("stop",))
            except Exception:
                pass
        for ph in self.procs.values():
            ph.process.join(timeout=2.0)
            if ph.process.is_alive():
                ph.process.terminate()
                ph.process.join(timeout=1.0)
        self.procs.clear()
        self.jobstore.close()
        if self._tmpdir is not None:
            self._tmpdir.cleanup()

    def __enter__(self) -> "ProcessExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- dispatch ----------------------------------------------------------
    def run(self, graph: JobGraph, **kw):
        self._ensure_started()
        return super().run(graph, **kw)

    def _resolve_inputs(self, job: Job, graph: JobGraph,
                        report: SegmentReport, worker: Worker) -> list[ChunkedData]:
        """Host-side input resolution: chunks stay as numpy host arrays
        (the process boundary is the transfer; no device moves here)."""
        inputs: list[ChunkedData] = []
        for ref in job.inputs:
            rec = self.store.records.get(ref.job)
            if rec is None or rec.data is None:
                self._recover(ref.job, graph, report)
                rec = self.store.get(ref.job)
            sel = ref.select(rec.data)
            report.local_bytes += sum(c.nbytes for c in sel)
            inputs.append(ChunkedData([DataChunk(np.asarray(c.data))
                                       for c in sel]))
        if job.name in graph.bound_inputs:
            data = graph.bound_inputs[job.name]
            inputs.insert(0, ChunkedData([DataChunk(np.asarray(c.data))
                                          for c in data]))
        return inputs

    def _execute_on(self, job: Job, worker: Worker, graph: JobGraph,
                    report: SegmentReport,
                    ctx: ControlContext | None = None) -> tuple[ChunkedData, float]:
        rf = self.registry[job.fn]
        if rf.kind == FunctionKind.CONTROL:
            # control jobs stay on the master host (paper §3.3)
            return super()._execute_on(job, worker, graph, report, ctx)
        with self._lock:
            inputs = self._resolve_inputs(job, graph, report, worker)
        chunk_lists = [[np.asarray(c.data) for c in cd] for cd in inputs]
        key = job_key(str(job.fn), [a for lst in chunk_lists for a in lst])
        t0 = time.perf_counter()
        memo = self.jobstore.load_result(key)
        if memo is not None:
            out = ChunkedData([DataChunk(a) for a in memo])
            with self._lock:
                self.n_memoised += 1
                report.memoised_jobs.append(job.name)
                worker.jobs_done += 1
                self.store.put(job, out, worker)
            return out, time.perf_counter() - t0
        arrays = self._dispatch_with_retry(job, worker, key, rf.kind,
                                           chunk_lists, report)
        out = ChunkedData([DataChunk(a) for a in arrays])
        elapsed = time.perf_counter() - t0
        with self._lock:
            self.n_executed += 1
            worker.jobs_done += 1
            self.store.put(job, out, worker)
            if self._master is not None:
                self._master.observe(job.fn, elapsed)
        return out, elapsed

    def _live_worker(self, preferred: Worker, deadline: float) -> Worker | None:
        """The placed worker if its process is live, else the least-loaded
        live one; blocks (until ``deadline``) for the monitor's replacement
        when no process is currently live."""
        while True:
            with self._lock:
                ph = self.procs.get(preferred.wid)
                if preferred.alive and ph is not None and not ph.lost:
                    return preferred
                cands = [w for w in self.cluster.alive_workers()
                         if (p := self.procs.get(w.wid)) is not None
                         and not p.lost]
                if cands:
                    return min(cands, key=lambda w: w.jobs_done)
            if time.monotonic() >= deadline:
                return None
            time.sleep(0.02)

    def _dispatch_with_retry(self, job: Job, worker: Worker, key: str,
                             kind: str, chunk_lists: list[list[np.ndarray]],
                             report: SegmentReport) -> list[np.ndarray]:
        delay = self.backoff_s
        outcome = "no live worker"
        respawn_wait = max(2 * self.heartbeat_interval_s
                           * self.heartbeat_max_missed, 5.0)
        for attempt in range(self.max_retries + 1):
            if attempt:
                time.sleep(delay)
                delay *= 2
                with self._lock:
                    report.recovered_jobs.append(job.name)
            target = self._live_worker(worker,
                                       time.monotonic() + respawn_wait)
            if target is None:
                continue
            worker = target
            ph = self.procs[worker.wid]
            self.jobstore.mark_running(key, name=job.name, fn=str(job.fn),
                                       worker=worker.wid)
            outcome, payload = self._dispatch_once(ph, key, job, kind,
                                                   chunk_lists)
            if outcome == "ok":
                return payload
            self.jobstore.mark_lost(key)
        raise RuntimeError(
            f"{job.name}: dispatch failed after {self.max_retries + 1} "
            f"attempts (last: {outcome})")

    def _dispatch_once(self, ph: _ProcHandle, key: str, job: Job, kind: str,
                       chunk_lists: list[list[np.ndarray]]):
        """One request/response round trip with a per-job deadline.  Loss is
        only ever observed through the monitor's heartbeat-expiry flag
        (``ph.lost``) or the deadline — never ``Process.is_alive()``."""
        deadline = time.monotonic() + self.job_timeout_s
        if not ph.ch_lock.acquire(timeout=self.job_timeout_s):
            return "timeout", None
        try:
            seq = next(ph.seq)
            ph.req_q.put(("job", seq, key, str(job.fn), kind, chunk_lists))
            while True:
                if ph.lost:
                    return "lost", None
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return "timeout", None
                try:
                    msg = ph.resp_q.get(timeout=min(0.05, remaining))
                except queue.Empty:
                    continue
                status, rseq, _rkey, payload = msg
                if rseq != seq:
                    continue  # stale reply from a timed-out earlier attempt
                if status == "ok":
                    return "ok", payload
                raise WorkerFunctionError(
                    f"{job.name} (fn={job.fn}) failed on worker "
                    f"{ph.wid}:\n{payload}")
        finally:
            ph.ch_lock.release()
