"""Job model of Mundani et al. — segments, jobs, chunks, dependencies.

The paper (§2.1) defines:

* an *algorithm* = ordered list of parallel segments ``S_1 .. S_n``,
* a *parallel segment* = set of jobs that may all execute concurrently; the
  segment completes when all its jobs have terminated (a barrier),
* a *job* = set of instruction sequences; sequences may run concurrently
  inside the job; the job completes when all sequences have terminated,
* dependencies are expressed as "job J_i consumes (chunks of) the results of
  job J_j" (``R1[0..5]`` in the paper's job-file syntax, §3.3).

Adaptation to JAX (see DESIGN.md §2): a *sequence of instructions* maps to a
shard of the job's chunk axis; the framework derives data distribution from
the declared chunking, exactly as the paper's framework distributes chunks
over a job's sequences.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DataChunk",
    "ChunkedData",
    "ChunkRef",
    "Job",
    "ParallelSegment",
    "JobGraph",
    "GraphValidationError",
]


class GraphValidationError(ValueError):
    """A job graph violates the paper's structural rules."""


# ---------------------------------------------------------------------------
# Data chunks (paper §2.2, §3.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DataChunk:
    """One consecutive memory location holding ``n_elem`` elements.

    Paper: ``DataChunk(MPI type datatype, int n_elem, void *data)``; the
    constructor *copies the pointer, not the data* — ownership moves to the
    framework.  JAX arrays are immutable so the aliasing hazard disappears;
    we keep the constructor shape for fidelity.
    """

    data: Any  # jax.Array | np.ndarray
    dtype: Any = None
    n_elem: int = -1

    def __post_init__(self):
        arr = jnp.asarray(self.data) if not isinstance(self.data, (jax.Array, np.ndarray)) else self.data
        self.data = arr
        if self.dtype is None:
            self.dtype = arr.dtype
        if self.n_elem < 0:
            self.n_elem = int(arr.size)
        # cached: queried per placement candidate on the dispatch hot path
        self._nbytes = int(np.dtype(self.dtype).itemsize) * self.n_elem

    @property
    def nbytes(self) -> int:
        return self._nbytes


class ChunkedData:
    """Paper's ``FunctionData``: an ordered collection of data chunks.

    Every job input/output is a ``ChunkedData``.  The chunk axis is the unit
    of automatic distribution: the framework splits chunks over the job's
    instruction sequences (⇒ over mesh shards).
    """

    def __init__(self, chunks: Iterable[DataChunk] | None = None):
        self._chunks: list[DataChunk] = list(chunks or [])

    # -- paper-faithful accessors ------------------------------------------------
    def push_back(self, chunk: DataChunk) -> None:
        self._chunks.append(chunk)

    def get_data_chunk(self, i: int) -> DataChunk:
        return self._chunks[i]

    def n_chunks(self) -> int:
        return len(self._chunks)

    # -- pythonic accessors --------------------------------------------------
    def __len__(self) -> int:
        return len(self._chunks)

    def __iter__(self):
        return iter(self._chunks)

    def __getitem__(self, sel):
        if isinstance(sel, slice):
            return ChunkedData(self._chunks[sel])
        return self._chunks[sel]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self._chunks)

    # -- conversion helpers ----------------------------------------------------
    @classmethod
    def from_array(cls, arr, n_chunks: int) -> "ChunkedData":
        """Split ``arr`` along its leading axis into ``n_chunks`` chunks.

        This is the paper's "input data … has to be given in amount of
        chunks" requirement (§2.2).  Uneven splits follow ``np.array_split``
        semantics (first chunks one element larger).
        """
        arr = jnp.asarray(arr)
        if n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
        if arr.ndim == 0:
            raise ValueError("cannot chunk a scalar")
        bounds = np.array_split(np.arange(arr.shape[0]), n_chunks)
        return cls([DataChunk(arr[b[0]:b[-1] + 1]) for b in bounds if b.size])

    @classmethod
    def from_arrays(cls, arrs: Iterable[Any]) -> "ChunkedData":
        # skip the jnp.asarray dispatch for arrays already on device — this
        # sits on the executor's per-job hot path
        return cls([DataChunk(a if isinstance(a, jax.Array)
                              else jnp.asarray(a)) for a in arrs])

    def to_array(self):
        """Concatenate all chunks along the leading axis."""
        if not self._chunks:
            raise ValueError("empty ChunkedData")
        if len(self._chunks) == 1:
            return self._chunks[0].data
        return jnp.concatenate([jnp.atleast_1d(c.data) for c in self._chunks], axis=0)

    def arrays(self) -> list[Any]:
        return [c.data for c in self._chunks]


# ---------------------------------------------------------------------------
# Dependencies (paper §3.3 — "R1[0..5]" etc.)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChunkRef:
    """Reference to (a slice of) another job's result chunks.

    ``ChunkRef("J1")``          — all chunks of J1's result (paper: ``R1``)
    ``ChunkRef("J1", 0, 5)``    — chunks [0, 5) of J1's result (paper: ``R1[0..5]``)
    """

    job: str
    lo: int | None = None
    hi: int | None = None

    @property
    def whole(self) -> bool:
        return self.lo is None

    def select(self, data: ChunkedData) -> ChunkedData:
        if self.whole:
            return data
        if self.hi > data.n_chunks() or self.lo < 0 or self.lo >= self.hi:
            raise GraphValidationError(
                f"{self}: selection out of range for {data.n_chunks()} chunks")
        return data[self.lo:self.hi]

    def __repr__(self):
        base = f"R{self.job[1:]}" if self.job.startswith("J") else f"R({self.job})"
        return base if self.whole else f"{base}[{self.lo}..{self.hi}]"


# ---------------------------------------------------------------------------
# Jobs & segments (paper §2.2, §3.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Job:
    """A schedulable unit (paper §3.3 job definition, four arguments).

    ``fn``            — function identifier registered with the framework
    ``n_threads``     — 0 ⇒ as many as the worker has cores (paper);
                        adapted: 0 ⇒ the full intra-worker ("model") axis,
                        k>0 ⇒ exactly k lanes of intra-job parallelism.
    ``inputs``        — ChunkRefs to other jobs' results and/or bound data
    ``no_send_back``  — paper's optional 4th argument: results stay on the
                        worker (device-local), only a completion message is
                        sent to the scheduler.
    ``cost_hint``     — estimated useful FLOPs of one execution; consumed by
                        the cost-model placement strategy (DESIGN.md §5).
                        0.0 ⇒ unknown (the scheduler falls back to a
                        bytes-based roofline bound).
    """

    name: str
    fn: int | str
    n_threads: int = 0
    inputs: tuple[ChunkRef, ...] = ()
    no_send_back: bool = False
    cost_hint: float = 0.0
    # runtime metadata (not part of the paper's definition)
    segment: int = -1

    def __post_init__(self):
        if self.n_threads < 0:
            raise GraphValidationError(f"{self.name}: n_threads must be >= 0")
        self.inputs = tuple(self.inputs)

    def deps(self) -> tuple[str, ...]:
        return tuple(ref.job for ref in self.inputs)


@dataclasses.dataclass
class ParallelSegment:
    """Set of jobs that may all execute concurrently (paper §2.1)."""

    jobs: list[Job] = dataclasses.field(default_factory=list)

    def __iter__(self):
        return iter(self.jobs)

    def __len__(self):
        return len(self.jobs)

    def names(self) -> list[str]:
        return [j.name for j in self.jobs]


class JobGraph:
    """The algorithm: an ordered list of parallel segments.

    Structural rules enforced (paper §2.1/§3.3):
      * job names unique,
      * a job may only consume results of jobs in *earlier* segments
        (within-segment jobs are concurrent, so same-segment reads race),
      * dynamic additions (``add_dynamic``) may target the current or any
        following segment, never a completed one.
    """

    def __init__(self, segments: Iterable[ParallelSegment] | None = None):
        self.segments: list[ParallelSegment] = list(segments or [])
        self.bound_inputs: dict[str, ChunkedData] = {}
        self._reindex()
        self.validate()

    # -- construction -----------------------------------------------------------
    def add_segment(self, jobs: Sequence[Job] | ParallelSegment) -> int:
        seg = jobs if isinstance(jobs, ParallelSegment) else ParallelSegment(list(jobs))
        idx = len(self.segments)
        self.segments.append(seg)
        # incremental index + validation (graphs grow to thousands of jobs
        # in iterative workloads; full revalidation would be O(n^2))
        for job in seg.jobs:
            if job.name in self._by_name:
                self.segments.pop()
                raise GraphValidationError(f"duplicate job name {job.name}")
            job.segment = idx
            self._by_name[job.name] = job
        try:
            for job in seg.jobs:
                self._validate_job(job)
        except GraphValidationError:
            for job in seg.jobs:
                del self._by_name[job.name]
            self.segments.pop()
            raise
        return idx

    def bind_input(self, job_name: str, data: ChunkedData | Any, n_chunks: int | None = None) -> None:
        """Attach initial input data to a job (the paper's example feeds the
        array ``A`` as k chunks into J1/J2)."""
        if not isinstance(data, ChunkedData):
            if n_chunks is None:
                raise ValueError("n_chunks required when binding a raw array")
            data = ChunkedData.from_array(data, n_chunks)
        self.bound_inputs[job_name] = data

    def add_dynamic(self, job: Job, segment_index: int, *, current: int) -> None:
        """Paper §3.3: during runtime each job can add a finite number of new
        jobs to the current or following parallel segments."""
        if segment_index < current:
            raise GraphValidationError(
                f"dynamic job {job.name} targets completed segment {segment_index} (current={current})")
        if job.name in self._by_name:
            raise GraphValidationError(f"duplicate job name {job.name}")
        while len(self.segments) <= segment_index:
            self.segments.append(ParallelSegment())
        job.segment = segment_index
        self.segments[segment_index].jobs.append(job)
        self._by_name[job.name] = job
        self._validate_job(job)

    def remove_job(self, name: str) -> Job:
        """Retire a dynamic job from the graph (serving-time GC, or a
        preempted request's job returning to the master queue).

        Long-lived request streams (repro.serve.scheduler) add one dynamic
        job per admitted request; without retirement the graph grows without
        bound.  Removal is only legal when no remaining job consumes the
        retired job's results.  The name becomes reusable: a preempted
        request re-spawns its job under the same name when it resumes
        (``HyParRequestTracker.preempt`` / ``place_batch``)."""
        job = self._by_name.get(name)
        if job is None:
            raise GraphValidationError(f"cannot remove unknown job {name}")
        consumers = [j.name for j in self.jobs()
                     if name in j.deps() and j.name != name]
        if consumers:
            raise GraphValidationError(
                f"cannot remove {name}: still consumed by {consumers}")
        self.segments[job.segment].jobs.remove(job)
        del self._by_name[name]
        self.bound_inputs.pop(name, None)
        return job

    # -- introspection ----------------------------------------------------------
    def job(self, name: str) -> Job:
        return self._by_name[name]

    def jobs(self) -> Iterable[Job]:
        for seg in self.segments:
            yield from seg.jobs

    def names(self) -> list[str]:
        return [j.name for j in self.jobs()]

    def n_jobs(self) -> int:
        """O(1) total job count (the executor polls this per segment;
        scanning every segment would be O(segments²) over a run)."""
        return len(self._by_name)

    def segment_of(self, name: str) -> int:
        return self._by_name[name].segment

    def consumers(self, name: str) -> list[Job]:
        return [j for j in self.jobs() if name in j.deps()]

    def is_hybrid(self) -> tuple[bool, str]:
        """Classify per paper §2.1: strict / loose / not hybrid.

        Strict: some segment has >1 job AND one of *its* jobs has >1 sequence
        (n_threads != 1).  Loose: both conditions hold but in different
        segments.
        """
        multi_job = [i for i, s in enumerate(self.segments) if len(s) > 1]
        multi_seq = [i for i, s in enumerate(self.segments)
                     if any(j.n_threads != 1 for j in s)]
        strict = [i for i in multi_job
                  if any(j.n_threads != 1 for j in self.segments[i])]
        if strict:
            return True, "strict"
        if multi_job and multi_seq:
            return True, "loose"
        return False, "sequential"

    # -- validation --------------------------------------------------------------
    def _reindex(self) -> None:
        self._by_name: dict[str, Job] = {}
        for i, seg in enumerate(self.segments):
            for job in seg.jobs:
                job.segment = i
                if job.name in self._by_name:
                    raise GraphValidationError(f"duplicate job name {job.name}")
                self._by_name[job.name] = job

    def _validate_job(self, job: Job) -> None:
        for ref in job.inputs:
            if ref.job not in self._by_name:
                raise GraphValidationError(
                    f"{job.name} depends on unknown job {ref.job}")
            dep = self._by_name[ref.job]
            if dep.segment >= job.segment:
                raise GraphValidationError(
                    f"{job.name} (segment {job.segment}) depends on {ref.job} "
                    f"(segment {dep.segment}); dependencies must come from "
                    f"earlier segments")

    def validate(self) -> None:
        for job in self.jobs():
            self._validate_job(job)

    def __repr__(self):
        lines = []
        for i, seg in enumerate(self.segments):
            lines.append(f"S{i}: " + ", ".join(
                f"{j.name}(fn={j.fn},t={j.n_threads},in={list(j.inputs)},"
                f"nsb={j.no_send_back})" for j in seg.jobs))
        return "JobGraph[\n  " + "\n  ".join(lines) + "\n]"
