"""Executors — how job graphs actually run.

Two backends (DESIGN.md §2):

* :class:`LocalExecutor` — the *paper-faithful* runtime.  Workers are pinned
  to individual JAX devices; jobs are dispatched one by one following the
  master scheduler's placement plan; chunk transfers between devices are
  explicit (and accounted), ``no_send_back`` results stay on their worker's
  device.  Worker failures lose retained results, which are recovered by
  re-executing the producing jobs from the graph (lineage recovery).
  Dynamic jobs (control functions) are handled on the host, exactly like the
  paper's master re-enqueueing mechanism.

* :class:`SpmdExecutor` — the *beyond-paper* runtime for TPU pods.  A whole
  parallel segment is fused into one SPMD computation: same-function
  chunkwise jobs are batched over a stacked chunk axis and sharded across
  the mesh (the generalisation of the paper's worker co-scheduling), and
  GSPMD inserts the collectives the paper's schedulers would have sent as
  messages.  Self-re-enqueueing iterative patterns (the Jacobi J3) are fused
  into a single on-device ``lax.while_loop``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .job import ChunkedData, ChunkRef, DataChunk, GraphValidationError, Job, JobGraph
from .registry import ControlContext, FunctionKind, FunctionRegistry
from .scheduler import (MasterScheduler, Placement, ResultStore, VirtualCluster,
                        Worker)

__all__ = [
    "ExecutionReport",
    "LocalExecutor",
    "SpmdExecutor",
    "IterativeSpec",
]


# ---------------------------------------------------------------------------
# Reporting / monitoring (paper future work §5: "basic monitoring")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SegmentReport:
    index: int
    jobs: list[str] = dataclasses.field(default_factory=list)
    moved_bytes: int = 0
    local_bytes: int = 0
    co_scheduled: list[tuple[str, ...]] = dataclasses.field(default_factory=list)
    recovered_jobs: list[str] = dataclasses.field(default_factory=list)
    speculated_jobs: list[str] = dataclasses.field(default_factory=list)
    sim_makespan: float = 0.0
    wall_time: float = 0.0


@dataclasses.dataclass
class ExecutionReport:
    segments: list[SegmentReport] = dataclasses.field(default_factory=list)
    dynamic_jobs_added: int = 0

    @property
    def moved_bytes(self) -> int:
        return sum(s.moved_bytes for s in self.segments)

    @property
    def local_bytes(self) -> int:
        return sum(s.local_bytes for s in self.segments)

    @property
    def recovered_jobs(self) -> list[str]:
        return [j for s in self.segments for j in s.recovered_jobs]

    def summary(self) -> str:
        return (f"segments={len(self.segments)} moved={self.moved_bytes}B "
                f"local={self.local_bytes}B dynamic={self.dynamic_jobs_added} "
                f"recovered={len(self.recovered_jobs)}")


# ---------------------------------------------------------------------------
# Local (paper-faithful) executor
# ---------------------------------------------------------------------------


class LocalExecutor:
    """Dispatch jobs to per-device workers following the placement plan."""

    def __init__(self, cluster: VirtualCluster, registry: FunctionRegistry, *,
                 speculative_slowdown_threshold: float = 2.0,
                 block_per_job: bool = False):
        self.cluster = cluster
        self.registry = registry
        self.store = ResultStore(cluster)
        self.speculative_slowdown_threshold = speculative_slowdown_threshold
        # paper semantics: the barrier is at SEGMENT granularity — jobs are
        # dispatched asynchronously and the scheduler waits once per segment
        # (block_per_job=True restores per-job waits for precise worker
        # timing, e.g. in straggler experiments)
        self.block_per_job = block_per_job
        self._jit_cache: dict[Any, Callable] = {}

    # -- plumbing ----------------------------------------------------------------
    def _jitted(self, fid) -> Callable:
        if fid not in self._jit_cache:
            self._jit_cache[fid] = jax.jit(self.registry[fid].fn)
        return self._jit_cache[fid]

    def _resolve_inputs(self, job: Job, graph: JobGraph, report: SegmentReport,
                        worker: Worker) -> list[ChunkedData]:
        """Fetch each input ref, moving chunks to the worker's device.

        Lost results (dead worker + no_send_back) trigger lineage recovery:
        the producing job is re-executed (paper §3.1 names exactly this
        recompute cost as the drawback of result retention).
        """
        inputs: list[ChunkedData] = []
        for ref in job.inputs:
            rec = self.store.records.get(ref.job)
            if rec is None or rec.data is None:
                self._recover(ref.job, graph, report)
                rec = self.store.get(ref.job)
            sel = ref.select(rec.data)
            moved = []
            for c in sel:
                src_dev = (c.data.devices().pop()
                           if isinstance(c.data, jax.Array) and c.data.devices() else None)
                if src_dev is not None and src_dev != worker.device:
                    report.moved_bytes += c.nbytes
                    moved.append(DataChunk(jax.device_put(c.data, worker.device)))
                else:
                    report.local_bytes += c.nbytes
                    moved.append(c)
            inputs.append(ChunkedData(moved))
        if job.name in graph.bound_inputs:
            data = graph.bound_inputs[job.name]
            moved = []
            for c in data:
                on_dev = (isinstance(c.data, jax.Array) and c.data.devices()
                          and c.data.devices().pop() == worker.device)
                moved.append(c if on_dev
                             else DataChunk(jax.device_put(c.data, worker.device)))
            inputs.insert(0, ChunkedData(moved))
        return inputs

    def _recover(self, name: str, graph: JobGraph, report: SegmentReport) -> None:
        """Re-execute a job whose result was lost (recursively)."""
        job = graph.job(name)
        # choose any alive worker (fresh placement — the original is dead)
        alive = self.cluster.alive_workers()
        if not alive:
            worker = self.cluster.spawn_worker()
        else:
            worker = min(alive, key=lambda w: w.jobs_done)
        report.recovered_jobs.append(name)
        self._execute_on(job, worker, graph, report)

    # -- execution ----------------------------------------------------------------
    def _execute_on(self, job: Job, worker: Worker, graph: JobGraph,
                    report: SegmentReport,
                    ctx: ControlContext | None = None) -> ChunkedData:
        rf = self.registry[job.fn]
        inputs = self._resolve_inputs(job, graph, report, worker)
        t0 = time.perf_counter()
        if rf.kind == FunctionKind.CHUNKWISE:
            if not inputs:
                raise GraphValidationError(
                    f"{job.name}: chunkwise function {job.fn!r} needs input chunks")
            fn = self._jitted(job.fn)
            zipped = list(zip(*[cd.arrays() for cd in inputs]))
            out_chunks = [DataChunk(fn(*args)) for args in zipped]
            out = ChunkedData(out_chunks)
        elif rf.kind == FunctionKind.WHOLE:
            out = rf.fn(*inputs)
            if not isinstance(out, ChunkedData):
                out = ChunkedData.from_arrays(
                    out if isinstance(out, (list, tuple)) else [out])
        elif rf.kind == FunctionKind.CONTROL:
            if ctx is None:
                ctx = ControlContext(graph, job.segment)
            host_inputs = [ChunkedData([DataChunk(np.asarray(c.data)) for c in cd])
                           for cd in inputs]
            out = rf.fn(*host_inputs, ctx)
            if out is None:
                out = ChunkedData([])
            elif not isinstance(out, ChunkedData):
                out = ChunkedData.from_arrays(
                    out if isinstance(out, (list, tuple)) else [out])
            for new_job, seg_idx in ctx.added:
                graph.add_dynamic(new_job, seg_idx, current=job.segment)
        else:  # pragma: no cover
            raise GraphValidationError(f"unknown kind {rf.kind}")
        if self.block_per_job:
            for c in out:
                if isinstance(c.data, jax.Array):
                    c.data.block_until_ready()
        elapsed = time.perf_counter() - t0
        worker.jobs_done += 1
        self.store.put(job, out, worker)
        return out, elapsed

    def run(self, graph: JobGraph, *, release_consumed: bool = False) -> tuple[dict, ExecutionReport]:
        """Execute the whole graph; returns (results by job name, report).

        ``release_consumed`` — after a segment completes, release results
        whose every consumer has already run (the paper's scheduler "signals
        them the data is no longer required").
        """
        report = ExecutionReport()
        master = MasterScheduler(graph, self.cluster)
        seg_idx = 0
        while seg_idx < len(graph.segments):
            segment = graph.segments[seg_idx]
            sreport = SegmentReport(index=seg_idx, jobs=list(segment.names()))
            t0 = time.perf_counter()
            placements = master.plan_segment(segment.jobs, self.store)
            worker_time: dict[int, float] = {}
            n_dynamic_before = sum(len(s) for s in graph.segments)
            for p in placements:
                if p.co_scheduled_with:
                    sreport.co_scheduled.append((p.job.name,) + p.co_scheduled_with)
                worker = p.worker
                ctx = ControlContext(graph, seg_idx)
                # straggler mitigation: speculatively duplicate on a faster
                # worker when the chosen one is degraded
                if (worker.slowdown >= self.speculative_slowdown_threshold
                        and len(self.cluster.alive_workers()) > 1):
                    fast = min((w for w in self.cluster.alive_workers()
                                if w.wid != worker.wid),
                               key=lambda w: w.slowdown)
                    if fast.slowdown < worker.slowdown:
                        sreport.speculated_jobs.append(p.job.name)
                        worker = fast
                _, elapsed = self._execute_on(p.job, worker, graph, sreport, ctx)
                worker_time[worker.wid] = worker_time.get(worker.wid, 0.0) \
                    + elapsed * worker.slowdown
            n_dynamic_after = sum(len(s) for s in graph.segments)
            report.dynamic_jobs_added += max(0, n_dynamic_after - n_dynamic_before
                                             - 0)
            if not self.block_per_job:
                # paper's segment barrier: wait for every job of the segment
                for p in placements:
                    rec = self.store.records.get(p.job.name)
                    if rec is not None and rec.data is not None:
                        for c in rec.data:
                            if isinstance(c.data, jax.Array):
                                c.data.block_until_ready()
            sreport.sim_makespan = max(worker_time.values(), default=0.0)
            sreport.wall_time = time.perf_counter() - t0
            report.segments.append(sreport)
            if release_consumed:
                self._release_dead_results(graph, seg_idx)
            seg_idx += 1
        results = {name: rec.data for name, rec in self.store.records.items()
                   if rec.data is not None}
        return results, report

    def _release_dead_results(self, graph: JobGraph, done_segment: int) -> None:
        for name, rec in self.store.records.items():
            if rec.data is None:
                continue
            consumers = graph.consumers(name)
            if consumers and all(c.segment <= done_segment and
                                 c.name in self.store.records for c in consumers):
                self.store.release(name)


# ---------------------------------------------------------------------------
# SPMD (fused) executor — beyond-paper optimisation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IterativeSpec:
    """A self-re-enqueueing segment group (the paper's dynamic-job loop),
    declared explicitly so it can be fused to ``lax.while_loop``.

    ``body``  — f(carry) -> carry, the fused body of the repeated segments
    ``cond``  — f(carry) -> bool scalar
    ``max_iters`` — safety bound (the paper requires a *finite* number of
                    dynamic additions)
    """

    body: Callable
    cond: Callable
    max_iters: int = 10_000


class SpmdExecutor:
    """Fuse segments into SPMD computations over a device mesh.

    Same-function chunkwise job groups in a segment are stacked over the
    chunk axis and executed as ONE sharded computation (`vmap` over chunks,
    chunk axis sharded over the mesh's data axes).  ``no_send_back`` keeps
    outputs sharded in place; sent-back results are gathered (replicated) —
    exactly the communication the paper's workers would perform, but
    expressed as collectives that XLA can schedule/overlap.
    """

    def __init__(self, mesh: jax.sharding.Mesh, registry: FunctionRegistry, *,
                 chunk_axes: tuple[str, ...] | None = None):
        self.mesh = mesh
        self.registry = registry
        # chunk axis = all mesh axes by default (fully sharded chunk axis)
        self.chunk_axes = chunk_axes if chunk_axes is not None else tuple(mesh.axis_names)
        self.results: dict[str, Any] = {}     # job name -> stacked array(s)
        self._compiled: dict[Any, Callable] = {}

    # -- sharding helpers --------------------------------------------------------
    def _chunk_sharding(self, n_chunks: int):
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = []
        size = 1
        for a in self.chunk_axes:
            s = self.mesh.shape[a]
            if n_chunks % (size * s) == 0:
                axes.append(a)
                size *= s
            else:
                break
        spec = P(tuple(axes)) if axes else P()
        return NamedSharding(self.mesh, spec)

    def _replicated(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self.mesh, P())

    # -- execution ----------------------------------------------------------------
    def _stacked_input(self, job: Job, graph: JobGraph) -> list[Any]:
        arrs = []
        if job.name in graph.bound_inputs:
            cd = graph.bound_inputs[job.name]
            arrs.append(jnp.stack(cd.arrays()))
        for ref in job.inputs:
            if ref.job not in self.results:
                raise GraphValidationError(f"{job.name}: missing result {ref.job}")
            val = self.results[ref.job]
            if not ref.whole:
                val = val[ref.lo:ref.hi]
            arrs.append(val)
        return arrs

    def _fused_chunkwise(self, fid, n_chunks: int, send_back: bool):
        key = (fid, n_chunks, send_back)
        if key not in self._compiled:
            fn = self.registry[fid].fn
            out_sh = self._replicated() if send_back else self._chunk_sharding(n_chunks)
            self._compiled[key] = jax.jit(
                jax.vmap(fn),
                in_shardings=None,   # let GSPMD propagate from operands
                out_shardings=out_sh)
        return self._compiled[key]

    def run(self, graph: JobGraph) -> dict[str, Any]:
        for seg_idx, segment in enumerate(graph.segments):
            # group same-function chunkwise jobs (worker co-scheduling,
            # generalised: ONE sharded call executes the whole group)
            groups: dict[Any, list[Job]] = {}
            singles: list[Job] = []
            for job in segment.jobs:
                rf = self.registry[job.fn]
                if rf.kind == FunctionKind.CHUNKWISE:
                    groups.setdefault(job.fn, []).append(job)
                else:
                    singles.append(job)
            for fid, jobs in groups.items():
                ins = [self._stacked_input(j, graph) for j in jobs]
                counts = [i[0].shape[0] for i in ins]
                stacked = [jnp.concatenate([i[k] for i in ins], axis=0)
                           for k in range(len(ins[0]))]
                send_back = not all(j.no_send_back for j in jobs)
                fused = self._fused_chunkwise(fid, int(sum(counts)), send_back)
                out = fused(*stacked)
                # split the fused result back to per-job results
                off = 0
                for j, c in zip(jobs, counts):
                    self.results[j.name] = out[off:off + c]
                    off += c
            for job in singles:
                rf = self.registry[job.fn]
                ins = self._stacked_input(job, graph)
                if rf.kind == FunctionKind.WHOLE:
                    out = rf.fn(*[ChunkedData.from_arrays(list(a)) for a in ins])
                    self.results[job.name] = jnp.stack(out.arrays())
                elif rf.kind == FunctionKind.CONTROL:
                    ctx = ControlContext(graph, seg_idx)
                    host_ins = [ChunkedData.from_arrays([np.asarray(x) for x in a])
                                for a in ins]
                    out = rf.fn(*host_ins, ctx)
                    self.results[job.name] = (jnp.stack(out.arrays())
                                              if out is not None and len(out) else jnp.zeros((0,)))
                    for new_job, tgt in ctx.added:
                        graph.add_dynamic(new_job, tgt, current=seg_idx)
                else:  # pragma: no cover
                    raise GraphValidationError(f"unsupported kind {rf.kind}")
        return dict(self.results)

    # -- iterative fusion (beyond-paper: dynamic-job loop -> while_loop) --------
    def run_iterative(self, spec: IterativeSpec, carry):
        """Fuse a convergence loop on device.

        The paper expresses iteration by letting a control job re-enqueue the
        body segments; host round-trips per iteration are the price.  On TPU
        we fuse body+condition into one ``lax.while_loop`` so the loop never
        leaves the device.  Both paths are benchmarked in
        ``benchmarks/jacobi_paper.py``.
        """
        key = ("iterative", id(spec))
        if key not in self._compiled:
            it = jnp.zeros((), jnp.int32)

            def cond(state):
                i, c = state
                return jnp.logical_and(i < spec.max_iters, spec.cond(c))

            def body(state):
                i, c = state
                return i + 1, spec.body(c)

            self._compiled[key] = jax.jit(
                lambda c: jax.lax.while_loop(cond, body, (it, c)))
        n_iters, final = self._compiled[key](carry)
        return final, int(n_iters)
