"""Executors — how job graphs actually run.

All executors implement the :class:`BaseExecutor` contract
(``run(graph) -> (results, ExecutionReport)``) so launchers, benchmarks and
apps never special-case the runtime (DESIGN.md §2).

* :class:`LocalExecutor` — the *paper-faithful* runtime.  Workers are pinned
  to individual JAX devices; chunk transfers between devices are explicit
  (and accounted), ``no_send_back`` results stay on their worker's device.
  Worker failures lose retained results, which are recovered by re-executing
  the producing jobs from the graph (lineage recovery).  Dynamic jobs
  (control functions) are handled on the host, exactly like the paper's
  master re-enqueueing mechanism.

  Three dispatch modes (DESIGN.md §2.3):

  - ``mode="sync"`` — the paper's loop: placements execute one by one on the
    host thread; ``block_per_job=True`` additionally waits for each job's
    device work (precise per-worker timing, e.g. straggler experiments).
  - ``mode="pipelined"`` — per-worker dispatch queues: every placement of a
    segment is issued without host-side blocking (JAX async dispatch
    overlaps ``device_put`` input transfers with compute); the host waits
    once at the paper's segment barrier.  Control jobs drain on the host as
    their inputs complete.
  - ``mode="dataflow"`` — the barrier relaxed to true dataflow: a job in
    segment *k+1* whose inputs are all available is dispatched before
    segment *k* fully drains (the paper's strict barrier becomes an opt-in
    strictness level).

* :class:`SpmdExecutor` — the *beyond-paper* runtime for TPU pods.  A whole
  parallel segment is fused into one SPMD computation: same-function
  chunkwise jobs are batched over a stacked chunk axis and sharded across
  the mesh (the generalisation of the paper's worker co-scheduling), and
  GSPMD inserts the collectives the paper's schedulers would have sent as
  messages.  Self-re-enqueueing iterative patterns (the Jacobi J3) are fused
  into a single on-device ``lax.while_loop``.
"""
from __future__ import annotations

import abc
import concurrent.futures
import dataclasses
import threading
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from .job import ChunkedData, DataChunk, GraphValidationError, Job, JobGraph
from .registry import ControlContext, FunctionKind, FunctionRegistry
from .scheduler import (CostModelParams, MasterScheduler, Placement,
                        ResultStore, VirtualCluster, Worker)

__all__ = [
    "ExecutionReport",
    "BaseExecutor",
    "LocalExecutor",
    "SpmdExecutor",
    "IterativeSpec",
]


# ---------------------------------------------------------------------------
# Reporting / monitoring (paper future work §5: "basic monitoring")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SegmentReport:
    index: int
    jobs: list[str] = dataclasses.field(default_factory=list)
    moved_bytes: int = 0
    local_bytes: int = 0
    co_scheduled: list[tuple[str, ...]] = dataclasses.field(default_factory=list)
    recovered_jobs: list[str] = dataclasses.field(default_factory=list)
    speculated_jobs: list[str] = dataclasses.field(default_factory=list)
    # jobs served from a durable JobStore instead of executing (ProcessExecutor)
    memoised_jobs: list[str] = dataclasses.field(default_factory=list)
    sim_makespan: float = 0.0
    wall_time: float = 0.0


@dataclasses.dataclass
class ExecutionReport:
    segments: list[SegmentReport] = dataclasses.field(default_factory=list)
    dynamic_jobs_added: int = 0
    mode: str = "sync"

    @property
    def moved_bytes(self) -> int:
        return sum(s.moved_bytes for s in self.segments)

    @property
    def local_bytes(self) -> int:
        return sum(s.local_bytes for s in self.segments)

    @property
    def recovered_jobs(self) -> list[str]:
        return [j for s in self.segments for j in s.recovered_jobs]

    @property
    def memoised_jobs(self) -> list[str]:
        return [j for s in self.segments for j in s.memoised_jobs]

    def summary(self) -> str:
        return (f"mode={self.mode} segments={len(self.segments)} "
                f"moved={self.moved_bytes}B "
                f"local={self.local_bytes}B dynamic={self.dynamic_jobs_added} "
                f"recovered={len(self.recovered_jobs)}")


# ---------------------------------------------------------------------------
# Unified executor contract
# ---------------------------------------------------------------------------


class BaseExecutor(abc.ABC):
    """What every runtime must provide: execute a JobGraph, return the
    results directory plus an :class:`ExecutionReport`.

    Implementations differ in *where* jobs run (per-device workers, SPMD
    mesh, …) but not in the contract, so ``launch/``, ``benchmarks/`` and
    ``apps/`` code is runtime-agnostic.
    """

    registry: FunctionRegistry

    @abc.abstractmethod
    def run(self, graph: JobGraph, **kwargs
            ) -> tuple[dict[str, Any], ExecutionReport]:
        """Execute the whole graph; returns (results by job name, report)."""


# ---------------------------------------------------------------------------
# Local (paper-faithful) executor
# ---------------------------------------------------------------------------

MODES = ("sync", "pipelined", "dataflow")


class LocalExecutor(BaseExecutor):
    """Dispatch jobs to per-device workers following the placement plan."""

    def __init__(self, cluster: VirtualCluster, registry: FunctionRegistry, *,
                 speculative_slowdown_threshold: float = 2.0,
                 block_per_job: bool = False,
                 mode: str = "sync",
                 strategy: str = "greedy",
                 cost_params: CostModelParams | None = None,
                 observed_fn_times: dict[Any, float] | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown dispatch mode {mode!r}; pick from {MODES}")
        self.cluster = cluster
        self.registry = registry
        self.store = ResultStore(cluster)
        self.speculative_slowdown_threshold = speculative_slowdown_threshold
        # paper semantics: the barrier is at SEGMENT granularity — jobs are
        # dispatched asynchronously and the scheduler waits once per segment
        # (block_per_job=True restores per-job waits for precise worker
        # timing, e.g. in straggler experiments)
        self.block_per_job = block_per_job
        self.mode = mode
        self.strategy = strategy
        self.cost_params = cost_params
        # per-function wall-time seed for the master's queue-term EWMA
        # (e.g. tuned kernel timings, repro.kernels.tuning)
        self.observed_fn_times = observed_fn_times
        self._jit_cache: dict[Any, Callable] = {}
        # serialises store/report/graph mutation when worker queues dispatch
        # from threads; reentrant because lineage recovery recurses into
        # _execute_on
        self._lock = threading.RLock()
        self._queues: dict[int, concurrent.futures.ThreadPoolExecutor] = {}
        self._inflight: dict[int, int] = {}
        self._master: MasterScheduler | None = None

    # -- plumbing ----------------------------------------------------------------
    def _jitted(self, fid) -> Callable:
        with self._lock:
            if fid not in self._jit_cache:
                fn = self.registry[fid].fn
                # already-jitted user functions are reused as-is so their
                # compile cache survives across executors (the paper's users
                # register *compiled* functions)
                self._jit_cache[fid] = fn if hasattr(fn, "lower") else jax.jit(fn)
            return self._jit_cache[fid]

    def _queue(self, wid: int) -> concurrent.futures.ThreadPoolExecutor:
        """One single-threaded dispatch queue per worker: jobs placed on a
        worker issue in placement order, workers issue concurrently."""
        q = self._queues.get(wid)
        if q is None:
            q = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix=f"hypar-w{wid}")
            self._queues[wid] = q
        return q

    def _shutdown_queues(self) -> None:
        for q in self._queues.values():
            q.shutdown(wait=True)
        self._queues.clear()
        self._inflight.clear()

    def _resolve_inputs(self, job: Job, graph: JobGraph, report: SegmentReport,
                        worker: Worker) -> list[ChunkedData]:
        """Fetch each input ref, moving chunks to the worker's device.

        Lost results (dead worker + no_send_back) trigger lineage recovery:
        the producing job is re-executed (paper §3.1 names exactly this
        recompute cost as the drawback of result retention).
        """
        inputs: list[ChunkedData] = []
        for ref in job.inputs:
            rec = self.store.records.get(ref.job)
            if rec is None or rec.data is None:
                self._recover(ref.job, graph, report)
                rec = self.store.get(ref.job)
            sel = ref.select(rec.data)
            moved = []
            for c in sel:
                src_dev = (c.data.devices().pop()
                           if isinstance(c.data, jax.Array) and c.data.devices() else None)
                if src_dev is not None and src_dev != worker.device:
                    report.moved_bytes += c.nbytes
                    moved.append(DataChunk(jax.device_put(c.data, worker.device)))
                else:
                    report.local_bytes += c.nbytes
                    moved.append(c)
            inputs.append(ChunkedData(moved))
        if job.name in graph.bound_inputs:
            data = graph.bound_inputs[job.name]
            moved = []
            for c in data:
                on_dev = (isinstance(c.data, jax.Array) and c.data.devices()
                          and c.data.devices().pop() == worker.device)
                moved.append(c if on_dev
                             else DataChunk(jax.device_put(c.data, worker.device)))
            inputs.insert(0, ChunkedData(moved))
        return inputs

    def _recover(self, name: str, graph: JobGraph, report: SegmentReport) -> None:
        """Re-execute a job whose result was lost (recursively)."""
        job = graph.job(name)
        # choose any alive worker (fresh placement — the original is dead)
        alive = self.cluster.alive_workers()
        if not alive:
            worker = self.cluster.spawn_worker()
        else:
            worker = min(alive, key=lambda w: w.jobs_done)
        report.recovered_jobs.append(name)
        self._execute_on(job, worker, graph, report)

    # -- execution ----------------------------------------------------------------
    def _execute_on(self, job: Job, worker: Worker, graph: JobGraph,
                    report: SegmentReport,
                    ctx: ControlContext | None = None) -> tuple[ChunkedData, float]:
        """Resolve inputs, run the registered function, record the result.

        The dispatch lock is held only around shared-state access (store
        reads + recovery + report counters, then store.put + feedback) and
        for the whole control branch (graph mutation); chunkwise/whole
        dispatch itself runs unlocked so worker queues overlap transfers
        and compiled-function dispatch.
        """
        rf = self.registry[job.fn]
        with self._lock:
            inputs = self._resolve_inputs(job, graph, report, worker)
        t0 = time.perf_counter()
        if rf.kind == FunctionKind.CHUNKWISE:
            if not inputs:
                raise GraphValidationError(
                    f"{job.name}: chunkwise function {job.fn!r} needs input chunks")
            fn = self._jitted(job.fn)
            zipped = list(zip(*[cd.arrays() for cd in inputs]))
            out_chunks = [DataChunk(fn(*args)) for args in zipped]
            out = ChunkedData(out_chunks)
        elif rf.kind == FunctionKind.WHOLE:
            out = rf.fn(*inputs)
            if not isinstance(out, ChunkedData):
                out = ChunkedData.from_arrays(
                    out if isinstance(out, (list, tuple)) else [out])
        elif rf.kind == FunctionKind.CONTROL:
            with self._lock:
                if ctx is None:
                    ctx = ControlContext(graph, job.segment)
                host_inputs = [ChunkedData([DataChunk(np.asarray(c.data))
                                            for c in cd]) for cd in inputs]
                out = rf.fn(*host_inputs, ctx)
                if out is None:
                    out = ChunkedData([])
                elif not isinstance(out, ChunkedData):
                    out = ChunkedData.from_arrays(
                        out if isinstance(out, (list, tuple)) else [out])
                for new_job, seg_idx in ctx.added:
                    graph.add_dynamic(new_job, seg_idx, current=job.segment)
        else:  # pragma: no cover
            raise GraphValidationError(f"unknown kind {rf.kind}")
        if self.block_per_job:
            for c in out:
                if isinstance(c.data, jax.Array):
                    c.data.block_until_ready()
        elapsed = time.perf_counter() - t0
        with self._lock:
            worker.jobs_done += 1
            self.store.put(job, out, worker)
            if self._master is not None:
                self._master.observe(job.fn, elapsed)
        return out, elapsed

    def _maybe_speculate(self, p: Placement, sreport: SegmentReport) -> Worker:
        """Straggler mitigation: speculatively duplicate on a faster worker
        when the chosen one is degraded."""
        worker = p.worker
        if (worker.slowdown >= self.speculative_slowdown_threshold
                and len(self.cluster.alive_workers()) > 1):
            fast = min((w for w in self.cluster.alive_workers()
                        if w.wid != worker.wid),
                       key=lambda w: w.slowdown)
            if fast.slowdown < worker.slowdown:
                sreport.speculated_jobs.append(p.job.name)
                worker = fast
        return worker

    def _segment_barrier(self, names: Iterable[str]) -> None:
        """The paper's segment barrier: wait for every job of the segment."""
        for name in names:
            rec = self.store.records.get(name)
            if rec is not None and rec.data is not None:
                for c in rec.data:
                    if isinstance(c.data, jax.Array):
                        c.data.block_until_ready()

    def run(self, graph: JobGraph, *, release_consumed: bool = False
            ) -> tuple[dict, ExecutionReport]:
        """Execute the whole graph; returns (results by job name, report).

        ``release_consumed`` — after a segment completes, release results
        whose every consumer has already run (the paper's scheduler "signals
        them the data is no longer required").
        """
        report = ExecutionReport(mode=self.mode)
        self._master = MasterScheduler(graph, self.cluster,
                                       strategy=self.strategy,
                                       cost_params=self.cost_params,
                                       observed_fn_times=self.observed_fn_times)
        try:
            if self.mode == "sync":
                self._run_sync(graph, report, release_consumed)
            elif self.mode == "pipelined":
                self._run_pipelined(graph, report, release_consumed)
            else:
                self._run_dataflow(graph, report, release_consumed)
        finally:
            self._shutdown_queues()
            self._master = None
        results = {name: rec.data for name, rec in self.store.records.items()
                   if rec.data is not None}
        return results, report

    # -- mode: sync (the paper's dispatch loop) --------------------------------
    def _run_sync(self, graph: JobGraph, report: ExecutionReport,
                  release_consumed: bool) -> None:
        master = self._master
        seg_idx = 0
        while seg_idx < len(graph.segments):
            segment = graph.segments[seg_idx]
            sreport = SegmentReport(index=seg_idx, jobs=list(segment.names()))
            t0 = time.perf_counter()
            worker_time: dict[int, float] = {}
            n_dynamic_before = graph.n_jobs()
            executed: set[str] = set()
            # fixpoint over same-segment dynamic additions: control jobs may
            # add to the *current* segment, which needs a re-plan pass
            pending = list(segment.jobs)
            while pending:
                placements = master.plan_segment(pending, self.store)
                for p in placements:
                    if p.co_scheduled_with:
                        sreport.co_scheduled.append((p.job.name,) + p.co_scheduled_with)
                    worker = self._maybe_speculate(p, sreport)
                    ctx = ControlContext(graph, seg_idx)
                    _, elapsed = self._execute_on(p.job, worker, graph, sreport, ctx)
                    worker_time[worker.wid] = worker_time.get(worker.wid, 0.0) \
                        + elapsed * worker.slowdown
                    executed.add(p.job.name)
                pending = [j for j in segment.jobs if j.name not in executed]
            n_dynamic_after = graph.n_jobs()
            report.dynamic_jobs_added += max(0, n_dynamic_after - n_dynamic_before)
            if not self.block_per_job:
                self._segment_barrier(executed)
            sreport.jobs = list(segment.names())
            sreport.sim_makespan = max(worker_time.values(), default=0.0)
            sreport.wall_time = time.perf_counter() - t0
            report.segments.append(sreport)
            if release_consumed:
                self._release_dead_results(graph, seg_idx)
            seg_idx += 1

    # -- mode: pipelined (per-worker queues, strict segment barrier) -----------
    def _run_pipelined(self, graph: JobGraph, report: ExecutionReport,
                       release_consumed: bool) -> None:
        master = self._master
        seg_idx = 0
        while seg_idx < len(graph.segments):
            segment = graph.segments[seg_idx]
            sreport = SegmentReport(index=seg_idx, jobs=list(segment.names()))
            t0 = time.perf_counter()
            worker_time: dict[int, float] = {}
            n_dynamic_before = graph.n_jobs()
            executed: set[str] = set()
            pending = list(segment.jobs)
            while pending:
                placements = master.plan_segment(pending, self.store)
                futures: dict[str, tuple[concurrent.futures.Future, Worker]] = {}
                for p in placements:
                    if p.co_scheduled_with:
                        sreport.co_scheduled.append((p.job.name,) + p.co_scheduled_with)
                    worker = self._maybe_speculate(p, sreport)
                    executed.add(p.job.name)
                    if self.registry[p.job.fn].kind == FunctionKind.CONTROL:
                        # host job: all deps live in earlier (drained)
                        # segments, so it runs immediately on the host thread
                        # while device queues fill
                        ctx = ControlContext(graph, seg_idx)
                        _, elapsed = self._execute_on(p.job, worker, graph,
                                                      sreport, ctx)
                        worker_time[worker.wid] = worker_time.get(worker.wid, 0.0) \
                            + elapsed * worker.slowdown
                    else:
                        fut = self._queue(worker.wid).submit(
                            self._execute_on, p.job, worker, graph, sreport)
                        futures[p.job.name] = (fut, worker)
                for name, (fut, worker) in futures.items():
                    _, elapsed = fut.result()  # re-raises worker exceptions
                    worker_time[worker.wid] = worker_time.get(worker.wid, 0.0) \
                        + elapsed * worker.slowdown
                pending = [j for j in segment.jobs if j.name not in executed]
            n_dynamic_after = graph.n_jobs()
            report.dynamic_jobs_added += max(0, n_dynamic_after - n_dynamic_before)
            self._segment_barrier(executed)
            sreport.jobs = list(segment.names())
            sreport.sim_makespan = max(worker_time.values(), default=0.0)
            sreport.wall_time = time.perf_counter() - t0
            report.segments.append(sreport)
            if release_consumed:
                self._release_dead_results(graph, seg_idx)
            seg_idx += 1

    # -- mode: dataflow (relaxed barrier, FIRST_COMPLETED draining) ------------
    def _run_dataflow(self, graph: JobGraph, report: ExecutionReport,
                      release_consumed: bool) -> None:
        """Dependency-driven dispatch across segment boundaries.

        A job is dispatchable once every producer it references has finished
        *dispatching* (its result handle exists; device compute may still be
        in flight — JAX chains the data dependency).  Control jobs run on
        the host as their inputs complete, orco-style: the driver drains
        whichever future finishes first rather than a whole segment.
        """
        master = self._master
        t_run0 = time.perf_counter()
        futures: dict[str, tuple[concurrent.futures.Future, Worker, int]] = {}
        done: set[str] = set()          # device jobs with completed dispatch
        host_done: set[str] = set()     # executed control jobs
        seg_reports: dict[int, SegmentReport] = {}
        seg_t0: dict[int, float] = {}
        worker_time: dict[int, dict[int, float]] = {}

        def sreport_for(seg: int) -> SegmentReport:
            if seg not in seg_reports:
                seg_reports[seg] = SegmentReport(index=seg)
                seg_t0[seg] = time.perf_counter()
            return seg_reports[seg]

        def harvest() -> None:
            for name, (fut, worker, seg) in list(futures.items()):
                if name in done or not fut.done():
                    continue
                _, elapsed = fut.result()
                wt = worker_time.setdefault(seg, {})
                wt[worker.wid] = wt.get(worker.wid, 0.0) + elapsed * worker.slowdown
                with self._lock:
                    self._inflight[worker.wid] = max(
                        0, self._inflight.get(worker.wid, 0) - 1)
                done.add(name)
                sreport_for(seg).wall_time = time.perf_counter() - seg_t0[seg]

        while True:
            harvest()
            finished = done | host_done
            pending = [j for j in graph.jobs()
                       if j.name not in futures and j.name not in host_done]
            waiting = [f for n, (f, _, _) in futures.items() if n not in done]
            if not pending:
                # drain before declaring done: only harvest() observes
                # results, so a future completing between harvest() and
                # here must not be skipped (it may hold an exception)
                if not waiting:
                    break
                concurrent.futures.wait(
                    waiting, return_when=concurrent.futures.FIRST_COMPLETED)
                continue
            ready = [j for j in pending
                     if all(d in finished for d in j.deps())]
            if not ready:
                if not waiting:  # pragma: no cover - valid graphs always progress
                    raise GraphValidationError(
                        f"dataflow deadlock: {[j.name for j in pending]} not ready")
                concurrent.futures.wait(
                    waiting, return_when=concurrent.futures.FIRST_COMPLETED)
                continue
            controls = [j for j in ready
                        if self.registry[j.fn].kind == FunctionKind.CONTROL]
            device_jobs = [j for j in ready if j not in controls]
            if device_jobs:
                with self._lock:
                    loads = dict(self._inflight)
                    placements = master.plan_segment(device_jobs, self.store,
                                                     loads=loads)
                for p in placements:
                    sr = sreport_for(p.job.segment)
                    if p.co_scheduled_with:
                        sr.co_scheduled.append((p.job.name,) + p.co_scheduled_with)
                    worker = self._maybe_speculate(p, sr)
                    with self._lock:
                        self._inflight[worker.wid] = \
                            self._inflight.get(worker.wid, 0) + 1
                    fut = self._queue(worker.wid).submit(
                        self._execute_on, p.job, worker, graph, sr)
                    futures[p.job.name] = (fut, worker, p.job.segment)
            for job in sorted(controls, key=lambda j: (j.segment, j.name)):
                sr = sreport_for(job.segment)
                worker = (self.cluster.alive_workers()
                          or [self.cluster.spawn_worker()])[0]
                ctx = ControlContext(graph, job.segment)
                _, elapsed = self._execute_on(job, worker, graph, sr, ctx)
                report.dynamic_jobs_added += len(ctx.added)
                wt = worker_time.setdefault(job.segment, {})
                wt[worker.wid] = wt.get(worker.wid, 0.0) + elapsed * worker.slowdown
                host_done.add(job.name)
                sr.wall_time = time.perf_counter() - seg_t0[job.segment]

        # final barrier: everything must be device-complete before results
        # are handed back
        self._segment_barrier(done | host_done)
        for seg, sr in sorted(seg_reports.items()):
            sr.jobs = (graph.segments[seg].names()
                       if seg < len(graph.segments) else [])
            sr.sim_makespan = max(worker_time.get(seg, {}).values(), default=0.0)
            report.segments.append(sr)
        if release_consumed:
            for seg in range(len(graph.segments)):
                self._release_dead_results(graph, seg)

    def _release_dead_results(self, graph: JobGraph, done_segment: int) -> None:
        for name, rec in self.store.records.items():
            if rec.data is None:
                continue
            consumers = graph.consumers(name)
            if consumers and all(c.segment <= done_segment and
                                 c.name in self.store.records for c in consumers):
                self.store.release(name)


# ---------------------------------------------------------------------------
# SPMD (fused) executor — beyond-paper optimisation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IterativeSpec:
    """A self-re-enqueueing segment group (the paper's dynamic-job loop),
    declared explicitly so it can be fused to ``lax.while_loop``.

    ``body``  — f(carry) -> carry, the fused body of the repeated segments
    ``cond``  — f(carry) -> bool scalar
    ``max_iters`` — safety bound (the paper requires a *finite* number of
                    dynamic additions)
    """

    body: Callable
    cond: Callable
    max_iters: int = 10_000


class SpmdExecutor(BaseExecutor):
    """Fuse segments into SPMD computations over a device mesh.

    Same-function chunkwise job groups in a segment are stacked over the
    chunk axis and executed as ONE sharded computation (`vmap` over chunks,
    chunk axis sharded over the mesh's data axes).  ``no_send_back`` keeps
    outputs sharded in place; sent-back results are gathered (replicated) —
    exactly the communication the paper's workers would perform, but
    expressed as collectives that XLA can schedule/overlap.
    """

    def __init__(self, mesh: jax.sharding.Mesh, registry: FunctionRegistry, *,
                 chunk_axes: tuple[str, ...] | None = None):
        self.mesh = mesh
        self.registry = registry
        # chunk axis = all mesh axes by default (fully sharded chunk axis)
        self.chunk_axes = chunk_axes if chunk_axes is not None else tuple(mesh.axis_names)
        self.results: dict[str, Any] = {}     # job name -> stacked array(s)
        self._compiled: dict[Any, Callable] = {}

    # -- sharding helpers --------------------------------------------------------
    def _chunk_sharding(self, n_chunks: int):
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = []
        size = 1
        for a in self.chunk_axes:
            s = self.mesh.shape[a]
            if n_chunks % (size * s) == 0:
                axes.append(a)
                size *= s
            else:
                break
        spec = P(tuple(axes)) if axes else P()
        return NamedSharding(self.mesh, spec)

    def _replicated(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self.mesh, P())

    # -- execution ----------------------------------------------------------------
    def _stacked_input(self, job: Job, graph: JobGraph) -> list[Any]:
        arrs = []
        if job.name in graph.bound_inputs:
            cd = graph.bound_inputs[job.name]
            arrs.append(jnp.stack(cd.arrays()))
        for ref in job.inputs:
            if ref.job not in self.results:
                raise GraphValidationError(f"{job.name}: missing result {ref.job}")
            val = self.results[ref.job]
            if not ref.whole:
                val = val[ref.lo:ref.hi]
            arrs.append(val)
        return arrs

    def _fused_chunkwise(self, fid, n_chunks: int, send_back: bool):
        key = (fid, n_chunks, send_back)
        if key not in self._compiled:
            fn = self.registry[fid].fn
            out_sh = self._replicated() if send_back else self._chunk_sharding(n_chunks)
            self._compiled[key] = jax.jit(
                jax.vmap(fn),
                in_shardings=None,   # let GSPMD propagate from operands
                out_shardings=out_sh)
        return self._compiled[key]

    def run(self, graph: JobGraph) -> tuple[dict[str, Any], ExecutionReport]:
        report = ExecutionReport(mode="spmd")
        for seg_idx, segment in enumerate(graph.segments):
            sreport = SegmentReport(index=seg_idx, jobs=list(segment.names()))
            t0 = time.perf_counter()
            n_dynamic_before = graph.n_jobs()
            # group same-function chunkwise jobs (worker co-scheduling,
            # generalised: ONE sharded call executes the whole group)
            groups: dict[Any, list[Job]] = {}
            singles: list[Job] = []
            for job in segment.jobs:
                rf = self.registry[job.fn]
                if rf.kind == FunctionKind.CHUNKWISE:
                    groups.setdefault(job.fn, []).append(job)
                else:
                    singles.append(job)
            for fid, jobs in groups.items():
                if len(jobs) > 1:
                    sreport.co_scheduled.append(tuple(j.name for j in jobs))
                ins = [self._stacked_input(j, graph) for j in jobs]
                counts = [i[0].shape[0] for i in ins]
                stacked = [jnp.concatenate([i[k] for i in ins], axis=0)
                           for k in range(len(ins[0]))]
                send_back = not all(j.no_send_back for j in jobs)
                fused = self._fused_chunkwise(fid, int(sum(counts)), send_back)
                out = fused(*stacked)
                # split the fused result back to per-job results
                off = 0
                for j, c in zip(jobs, counts):
                    self.results[j.name] = out[off:off + c]
                    off += c
            for job in singles:
                rf = self.registry[job.fn]
                ins = self._stacked_input(job, graph)
                if rf.kind == FunctionKind.WHOLE:
                    out = rf.fn(*[ChunkedData.from_arrays(list(a)) for a in ins])
                    self.results[job.name] = jnp.stack(out.arrays())
                elif rf.kind == FunctionKind.CONTROL:
                    ctx = ControlContext(graph, seg_idx)
                    host_ins = [ChunkedData.from_arrays([np.asarray(x) for x in a])
                                for a in ins]
                    out = rf.fn(*host_ins, ctx)
                    self.results[job.name] = (jnp.stack(out.arrays())
                                              if out is not None and len(out) else jnp.zeros((0,)))
                    for new_job, tgt in ctx.added:
                        graph.add_dynamic(new_job, tgt, current=seg_idx)
                else:  # pragma: no cover
                    raise GraphValidationError(f"unsupported kind {rf.kind}")
            report.dynamic_jobs_added += max(
                0, graph.n_jobs() - n_dynamic_before)
            sreport.jobs = list(segment.names())
            sreport.wall_time = time.perf_counter() - t0
            report.segments.append(sreport)
        return dict(self.results), report

    # -- iterative fusion (beyond-paper: dynamic-job loop -> while_loop) --------
    def run_iterative(self, spec: IterativeSpec, carry):
        """Fuse a convergence loop on device.

        The paper expresses iteration by letting a control job re-enqueue the
        body segments; host round-trips per iteration are the price.  On TPU
        we fuse body+condition into one ``lax.while_loop`` so the loop never
        leaves the device.  Both paths are benchmarked in
        ``benchmarks/jacobi_paper.py``.
        """
        key = ("iterative", id(spec))
        if key not in self._compiled:
            it = jnp.zeros((), jnp.int32)

            def cond(state):
                i, c = state
                return jnp.logical_and(i < spec.max_iters, spec.cond(c))

            def body(state):
                i, c = state
                return i + 1, spec.body(c)

            self._compiled[key] = jax.jit(
                lambda c: jax.lax.while_loop(cond, body, (it, c)))
        n_iters, final = self._compiled[key](carry)
        return final, int(n_iters)
