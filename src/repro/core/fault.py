"""Fault tolerance & monitoring (the paper's §5 "future work", implemented).

The paper identifies the cost of its ``no_send_back`` optimisation: "in case
a worker has to be shut down, all results computed so far are lost and have
to be re-computed".  This module provides:

* :class:`FaultInjector` — deterministic worker-failure injection for tests
  and chaos runs (kill after N jobs / before segment K / explicit kill).
* :class:`Heartbeat` — liveness tracking; a worker that misses
  ``max_missed`` beats is declared dead and its retained results are
  invalidated (triggering lineage recovery in the LocalExecutor).
* :class:`ChaosLocalExecutor` — a LocalExecutor that consults the injector
  around every job execution, exercising the recovery path end-to-end.

At pod scale the same policy applies one level up: a lost *host* invalidates
its checkpoint shard ownership and the launcher restarts from the latest
complete checkpoint (see repro.checkpoint) on a possibly different mesh
(elastic reshard).
"""
from __future__ import annotations

import dataclasses

from .executor import LocalExecutor
from .job import Job, JobGraph
from .registry import FunctionRegistry
from .scheduler import ResultStore, VirtualCluster, Worker

__all__ = ["FaultInjector", "Heartbeat", "ChaosLocalExecutor",
           "ServeChaosInjector"]


@dataclasses.dataclass
class FaultPlan:
    worker: int
    after_jobs: int | None = None      # kill once the worker finished N jobs
    before_segment: int | None = None  # kill when segment K is about to start


class FaultInjector:
    def __init__(self):
        self.plans: list[FaultPlan] = []
        self.killed: list[int] = []

    def kill_after_jobs(self, worker: int, n: int) -> "FaultInjector":
        self.plans.append(FaultPlan(worker=worker, after_jobs=n))
        return self

    def kill_before_segment(self, worker: int, segment: int) -> "FaultInjector":
        self.plans.append(FaultPlan(worker=worker, before_segment=segment))
        return self

    def maybe_kill(self, cluster: VirtualCluster, store: ResultStore, *,
                   segment: int | None = None) -> list[str]:
        """Apply due plans; returns names of results lost."""
        lost: list[str] = []
        for plan in list(self.plans):
            # match on wid, not list index: once replacement workers exist
            # the two can diverge and an index lookup kills the wrong worker
            w = next((x for x in cluster.workers if x.wid == plan.worker), None)
            if w is None:
                continue
            due = ((plan.after_jobs is not None and w.jobs_done >= plan.after_jobs)
                   or (plan.before_segment is not None and segment is not None
                       and segment >= plan.before_segment))
            if due and w.alive:
                w.fail()
                self.killed.append(w.wid)
                lost.extend(store.invalidate_worker(w.wid))
                self.plans.remove(plan)
        return lost


class Heartbeat:
    """Liveness monitor: a silent worker is declared dead after
    ``max_missed`` beats — *discovery*, not notification.

    Two modes:

    * **round-based** (default) — beats are reported by the executor after
      each job; ``tick`` advances one monitoring round and a worker silent
      for more than ``max_missed`` rounds is failed.
    * **store-backed** — pass a :class:`repro.core.store.JobStore`; real
      worker processes stamp wall-clock heartbeats into the store on a
      timer (``interval_s``) and ``tick``/``expired_wids`` compare against
      ``max_missed * interval_s`` of silence.  This is what replaces the
      explicit ``fail()`` protocol for the :class:`ProcessExecutor`.

    Registration itself counts as a beat: a replacement worker spawned
    mid-run must not be killed on the next tick before it ran a single job
    (previously ``last_beat.get(w.wid, 0)`` treated it as silent since
    round 0).
    """

    def __init__(self, cluster: VirtualCluster, max_missed: int = 3, *,
                 store=None, interval_s: float = 1.0,
                 boot_grace_s: float = 10.0):
        self.cluster = cluster
        self.max_missed = max_missed
        self.store = store
        self.interval_s = interval_s
        # real processes take far longer to boot (interpreter + imports)
        # than one beat interval; a worker that never checked in only
        # expires after this grace
        self.boot_grace_s = boot_grace_s
        self.last_beat: dict[int, int] = {}
        self.round = 0

    def register(self, wid: int) -> None:
        """Record the registration-time beat for a newly spawned worker."""
        self.last_beat.setdefault(wid, self.round)

    def beat(self, wid: int) -> None:
        self.last_beat[wid] = self.round

    def expired_wids(self) -> list[int]:
        """Alive workers whose last beat is too old (does not fail them)."""
        if self.store is not None:
            expired = set(self.store.expired(
                self.max_missed * self.interval_s,
                boot_grace_s=self.boot_grace_s))
            return [w.wid for w in self.cluster.alive_workers()
                    if w.wid in expired]
        out = []
        for w in self.cluster.alive_workers():
            self.register(w.wid)  # first sight == registration beat
            if self.round - self.last_beat[w.wid] > self.max_missed:
                out.append(w.wid)
        return out

    def tick(self, store: ResultStore) -> list[str]:
        """Advance one monitoring round; kill silent workers, return lost results."""
        self.round += 1
        lost: list[str] = []
        expired = set(self.expired_wids())
        for w in self.cluster.alive_workers():
            if w.wid in expired:
                w.fail()
                lost.extend(store.invalidate_worker(w.wid))
        return lost


class ServeChaosInjector:
    """Deterministic fault injection for the SERVING path (DESIGN.md §14) —
    the serve-layer sibling of :class:`FaultInjector`.  A
    ``ServeScheduler`` constructed with ``chaos=`` calls ``on_step`` at the
    top of every ``step()`` and consults the other hooks from its watchdog
    and group-failover machinery; without an injector none of those paths
    change.

    All step counts run on the scheduler's ``step_calls`` clock (every
    ``step()`` CALL, including idle ones — plans cannot stall with a
    drained batch).  Three plans, composable:

    * ``kill_group=(gid, after, down)`` — at call ``after`` device group
      ``gid`` is failed (``sched.fail_group``); ``group_healthy`` stays
      False for ``down`` further calls, then the next health probe rejoins
      the group.
    * ``slow=(after, n, extra_s)`` — calls ``[after, after+n)`` report an
      extra ``extra_s`` seconds of measured duration to the step watchdog.
      The delay is injected into the MEASUREMENT, not slept: soaks stay
      fast and deterministic, and at the watchdog's granularity a wedged
      step is indistinguishable from a slow one anyway.  ``slow_gid``
      narrows it to one device group.
    * ``pressure=(gid, after, n, pages)`` — the injector holds up to
      ``pages`` pages of group ``gid``'s pool for ``n`` calls (an
      allocator-level load spike forcing deferred admission / preemption);
      held pages are released at the window end, or by ``fail_group``'s
      quarantine sweep if the group dies holding them.
    """

    def __init__(self, *, kill_group: tuple[int, int, int] | None = None,
                 slow: tuple[int, int, float] | None = None,
                 slow_gid: int | None = None,
                 pressure: tuple[int, int, int, int] | None = None):
        self.kill_group = kill_group
        self.slow = slow
        self.slow_gid = slow_gid
        self.pressure = pressure
        self._held: dict[int, list[int]] = {}   # gid -> held page ids
        self._pressure_fired = False
        self.n_kills = 0
        self.n_slow_steps = 0
        self.n_pressure_pages = 0

    # -- scheduler hooks -------------------------------------------------------
    def on_step(self, sched) -> None:
        """Apply due plans; called at the top of every scheduler step."""
        step = sched.step_calls
        if self.kill_group is not None:
            gid, after, _down = self.kill_group
            if step >= after and sched.groups[gid].healthy \
                    and not self.group_healthy(sched, gid):
                self.n_kills += 1
                sched.fail_group(gid, reason="chaos kill_group")
        if self.pressure is not None:
            gid, after, n, pages = self.pressure
            g = sched.groups[gid]
            if (step >= after and not self._pressure_fired and g.healthy
                    and g.allocator is not None):
                self._pressure_fired = True
                take = min(pages, g.allocator.n_free)
                if take > 0:
                    held = g.allocator.alloc(take)
                    if held is not None:
                        self._held[gid] = held
                        self.n_pressure_pages += len(held)
            if step >= after + n:
                self.release_pages(sched, gid=gid)

    def step_extra_s(self, sched, gid: int) -> float:
        """Measured-duration inflation the watchdog should add for this
        group on the current step."""
        if self.slow is None:
            return 0.0
        if self.slow_gid is not None and gid != self.slow_gid:
            return 0.0
        after, n, extra = self.slow
        if after <= sched.step_calls < after + n:
            self.n_slow_steps += 1
            return float(extra)
        return 0.0

    def group_healthy(self, sched, gid: int) -> bool:
        """Probe gate: is the injected group fault still active?"""
        if self.kill_group is None or gid != self.kill_group[0]:
            return True
        _gid, after, down = self.kill_group
        return not (after <= sched.step_calls < after + down)

    # -- held-page accounting --------------------------------------------------
    def held_pages(self, gid: int) -> list[int]:
        """Pages the injector currently holds in group ``gid``'s pool —
        soak invariant checks add these to the expected outstanding set."""
        return list(self._held.get(gid, []))

    def release_pages(self, sched, gid: int | None = None) -> int:
        """Release held pressure pages (one group, or all).  Called by the
        window end, by ``fail_group``'s quarantine sweep, and by soaks
        before their final leak assertions."""
        gids = [gid] if gid is not None else list(self._held)
        n = 0
        for g in gids:
            held = self._held.pop(g, None)
            if held:
                sched.groups[g].allocator.free(held)
                n += len(held)
        return n


class ChaosLocalExecutor(LocalExecutor):
    """LocalExecutor wired to a FaultInjector — used by tests/benchmarks to
    prove the recovery path (re-execution from the job graph) works.

    Works in every dispatch mode: with ``mode="pipelined"``/``"dataflow"``
    the kill check runs on the worker-queue threads, so it takes the
    executor's dispatch lock — a kill observed by one in-flight job is
    immediately visible to every other queue (the async-recovery contract of
    DESIGN.md §6)."""

    def __init__(self, cluster: VirtualCluster, registry: FunctionRegistry,
                 injector: FaultInjector, **kw):
        super().__init__(cluster, registry, **kw)
        self.injector = injector

    def run(self, graph: JobGraph, **kw):
        # hook segment boundaries: apply segment-triggered kills by wrapping
        # the placement loop via the parent implementation (we intercept by
        # overriding _execute_on and checking before each job)
        self._graph_ref = graph
        return super().run(graph, **kw)

    def _execute_on(self, job, worker, graph, report, ctx=None):
        with self._lock:
            self.injector.maybe_kill(self.cluster, self.store, segment=job.segment)
            if not worker.alive:
                # the scheduler would notice the dead worker and re-place
                alive = self.cluster.alive_workers()
                worker = (min(alive, key=lambda w: w.jobs_done) if alive
                          else self.cluster.spawn_worker())
            out = super()._execute_on(job, worker, graph, report, ctx)
            self.injector.maybe_kill(self.cluster, self.store, segment=job.segment)
            return out
