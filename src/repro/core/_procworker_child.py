"""Worker-process entry point for :class:`repro.core.procworker.ProcessExecutor`.

Runs inside a ``multiprocessing`` *spawn* child — the paper's 'fat worker'
that "registers functions before recompiling the framework": the child
resolves its function table from a ``"module:attr"`` spec at startup and
never sees the master's registry (whose functions may close over jitted
callables and device handles that don't pickle).

Deliberately **jax-free** (like :mod:`repro.core.store`): spawn children pay
full import cost per process, and the numpy-level worker functions need no
device.  Anything jax-flavoured belongs on the master side.

Protocol (one request/response pair in flight per worker — the master's
per-worker dispatch queues already serialise placements per worker):

    ("job", seq, key, fid, kind, inputs)  →  ("ok", seq, key, arrays)
                                          |  ("err", seq, key, traceback)
    ("stop",)                             →  child exits

``inputs`` is one list of numpy chunk arrays per input ref; ``kind`` is
"chunkwise" (fn applied per zipped chunk tuple) or "whole" (fn over the full
chunk lists).  The result is persisted to the :class:`JobStore` **before**
the reply is sent — a master that dies between child completion and reply
delivery still finds the row ``done`` on resume.
"""
from __future__ import annotations

import importlib
import os
import threading
import time
import traceback

import numpy as np

from .store import JobStore

__all__ = ["resolve_fns", "worker_main"]


def resolve_fns(spec: str) -> dict:
    """``"package.module:ATTR"`` → the module-level function table (a dict
    mapping registry fid strings to plain numpy functions)."""
    modname, _, attr = spec.partition(":")
    if not attr:
        raise ValueError(f"worker fn spec {spec!r} must be 'module:attr'")
    table = getattr(importlib.import_module(modname), attr)
    if not isinstance(table, dict):
        raise TypeError(f"{spec} must resolve to a dict, got {type(table)}")
    return table


def _run_job(fn, kind: str, inputs: list[list[np.ndarray]]) -> list[np.ndarray]:
    if kind == "chunkwise":
        return [np.asarray(fn(*args)) for args in zip(*inputs)]
    out = fn(*inputs)  # whole: fn sees every input's full chunk list
    if isinstance(out, (list, tuple)):
        return [np.asarray(a) for a in out]
    return [np.asarray(out)]


def worker_main(wid: int, store_path: str, fns_spec: str,
                hb_interval: float, req_q, resp_q) -> None:
    fns = resolve_fns(fns_spec)
    store = JobStore(store_path)
    store.register_worker(wid, os.getpid())
    stop = threading.Event()

    def _beat() -> None:
        while not stop.wait(hb_interval):
            store.beat(wid)

    beater = threading.Thread(target=_beat, daemon=True,
                              name=f"proc-w{wid}-beat")
    beater.start()
    try:
        while True:
            msg = req_q.get()
            if msg[0] == "stop":
                break
            _, seq, key, fid, kind, inputs = msg
            try:
                arrays = _run_job(fns[fid], kind, inputs)
                # durable BEFORE the reply: the master may die in between
                store.put_result(key, arrays, fn=str(fid), worker=wid)
                resp_q.put(("ok", seq, key, arrays))
            except Exception:
                resp_q.put(("err", seq, key, traceback.format_exc()))
    finally:
        stop.set()
        store.mark_worker_dead(wid)
        store.close()
