"""User-function registration (paper §3.2).

The paper registers functions with signature::

    void function_name(FunctionData *input, FunctionData *output)

inside 'fat' workers before recompiling the framework.  The JAX adaptation is
purely functional — a registered function maps input chunks to output chunks.
Three kinds exist (DESIGN.md §2):

* ``chunkwise`` — ``fn(chunk) -> chunk``; applied to every input chunk
  independently.  This is the distributable kind: the framework splits the
  chunks over the job's instruction sequences (⇒ shards), exactly the
  automatic data distribution of paper §2.2.  One output chunk per input
  chunk.
* ``whole``     — ``fn(ChunkedData) -> ChunkedData``; sees the assembled
  input, returns arbitrary chunks.  Used when the computation is not
  chunk-separable (e.g. the paper's global-max job J3 could be either).
* ``control``   — ``fn(ChunkedData, ControlContext) -> ChunkedData``; runs on
  the host and may *add dynamic jobs* through the context (paper §3.3's
  "each job can add a finite number of new jobs", used by the Jacobi
  convergence job).

Functions are looked up by integer id (paper) or by name (extension).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from .job import ChunkedData, GraphValidationError, Job

__all__ = ["FunctionKind", "FunctionRegistry", "RegisteredFunction", "ControlContext"]


class FunctionKind:
    CHUNKWISE = "chunkwise"
    WHOLE = "whole"
    CONTROL = "control"


@dataclasses.dataclass
class RegisteredFunction:
    fid: int | str
    fn: Callable
    kind: str
    name: str = ""
    # multi-input chunkwise functions consume one chunk from each input ref
    # position per call (zip semantics); whole functions get a tuple of
    # ChunkedData, one per input ref.
    pass


class ControlContext:
    """Handed to control functions so they can enqueue dynamic jobs."""

    def __init__(self, graph, current_segment: int):
        self._graph = graph
        self.current_segment = current_segment
        self.added: list[tuple[Job, int]] = []

    def add_job(self, job: Job, segment_offset: int = 1) -> None:
        """Add ``job`` to the segment ``current + segment_offset``.

        ``segment_offset=0`` targets the *current* segment (allowed by the
        paper); negative offsets are rejected.
        """
        if segment_offset < 0:
            raise GraphValidationError("dynamic jobs cannot target completed segments")
        target = self.current_segment + segment_offset
        self.added.append((job, target))


class FunctionRegistry:
    def __init__(self):
        self._fns: dict[Any, RegisteredFunction] = {}

    def register(self, fid: int | str, fn: Callable, *,
                 kind: str = FunctionKind.CHUNKWISE, name: str = "") -> RegisteredFunction:
        if fid in self._fns:
            raise GraphValidationError(f"function id {fid!r} already registered")
        if kind not in (FunctionKind.CHUNKWISE, FunctionKind.WHOLE, FunctionKind.CONTROL):
            raise GraphValidationError(f"unknown function kind {kind!r}")
        rf = RegisteredFunction(fid=fid, fn=fn, kind=kind,
                                name=name or getattr(fn, "__name__", str(fid)))
        self._fns[fid] = rf
        return rf

    # decorator sugar ---------------------------------------------------------
    def chunkwise(self, fid):
        def deco(fn):
            self.register(fid, fn, kind=FunctionKind.CHUNKWISE)
            return fn
        return deco

    def whole(self, fid):
        def deco(fn):
            self.register(fid, fn, kind=FunctionKind.WHOLE)
            return fn
        return deco

    def control(self, fid):
        def deco(fn):
            self.register(fid, fn, kind=FunctionKind.CONTROL)
            return fn
        return deco

    def __contains__(self, fid):
        return fid in self._fns

    def __getitem__(self, fid) -> RegisteredFunction:
        try:
            return self._fns[fid]
        except KeyError:
            raise GraphValidationError(f"function id {fid!r} not registered") from None

    def ids(self):
        return list(self._fns)
