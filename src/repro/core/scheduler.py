"""Master/scheduler/worker runtime (paper §3.1) adapted to JAX devices.

Paper roles:

* **master scheduler** (rank 0) — holds the complete algorithm description,
  *no job data*; selects available jobs and assigns them to schedulers.
* **schedulers** (rank > 0) — fixed set, alive for the whole run; own their
  jobs' results and know how to assemble results requested by other jobs;
  each drives a set of workers.
* **workers** — dynamically spawned, isolated, memoryless; execute assigned
  jobs; retain each job's I/O until the scheduler releases it; optionally
  keep results local (``no_send_back``).

JAX adaptation (DESIGN.md §2): schedulers/workers are *placement targets* —
each worker is pinned to a device (LocalExecutor) or a mesh slice
(SpmdExecutor).  "Spawning" a worker is allocating a placement slot;
"sending" data is a cross-device transfer, which the placement planner
minimises (locality-aware scheduling = the paper's result-retention idea).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np

from .job import ChunkedData, ChunkRef, GraphValidationError, Job, JobGraph

__all__ = [
    "Worker",
    "SchedulerProc",
    "VirtualCluster",
    "ResultRecord",
    "ResultStore",
    "Placement",
    "CostModelParams",
    "MasterScheduler",
]


# ---------------------------------------------------------------------------
# Cluster model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Worker:
    """An isolated, memoryless executor pinned to a device (paper §3.1)."""

    wid: int
    device: Any
    cores: int = 1
    scheduler: int = 1          # owning scheduler rank
    alive: bool = True
    slowdown: float = 1.0       # >1.0 simulates a straggler (tests/bench only)
    jobs_done: int = 0
    # retained job I/O (paper: kept until the scheduler signals release)
    retained: dict[str, ChunkedData] = dataclasses.field(default_factory=dict)

    def fail(self) -> None:
        """Simulate a worker loss: all retained results are gone (paper §3.1
        explicitly notes this drawback of no_send_back)."""
        self.alive = False
        self.retained.clear()


@dataclasses.dataclass
class SchedulerProc:
    """A scheduler process (rank > 0) — owns results sent back by its workers."""

    rank: int
    device: Any
    stored: dict[str, ChunkedData] = dataclasses.field(default_factory=dict)


class VirtualCluster:
    """Devices organised as schedulers + dynamically spawned workers."""

    def __init__(self, devices: Sequence[Any] | None = None, *,
                 n_schedulers: int = 1, cores_per_worker: int = 1,
                 max_workers: int | None = None):
        self.devices = list(devices if devices is not None else jax.devices())
        if n_schedulers < 1:
            raise ValueError("need at least one scheduler")
        self.n_schedulers = n_schedulers
        self.cores_per_worker = cores_per_worker
        self.max_workers = max_workers if max_workers is not None else max(len(self.devices), 1)
        # master (rank 0) holds no data; schedulers rank 1..N own results.
        # Schedulers share devices round-robin with workers — on real
        # hardware they are host processes, data they "store" lives on their
        # device.
        self.schedulers = [SchedulerProc(rank=r, device=self.devices[r % len(self.devices)])
                           for r in range(1, n_schedulers + 1)]
        self.workers: list[Worker] = []

    # -- paper: workers are spawned during runtime -----------------------------
    def spawn_worker(self, scheduler_rank: int | None = None) -> Worker:
        # dead workers release their slot — recovery must be able to spawn a
        # replacement even when the cluster was at capacity (DESIGN.md §6)
        if len(self.alive_workers()) >= self.max_workers:
            raise RuntimeError(f"cannot spawn more than {self.max_workers} workers")
        wid = len(self.workers)
        sched = scheduler_rank or (wid % self.n_schedulers) + 1
        w = Worker(wid=wid, device=self.devices[wid % len(self.devices)],
                   cores=self.cores_per_worker, scheduler=sched)
        self.workers.append(w)
        return w

    def alive_workers(self) -> list[Worker]:
        return [w for w in self.workers if w.alive]

    def scheduler(self, rank: int) -> SchedulerProc:
        return self.schedulers[rank - 1]


# ---------------------------------------------------------------------------
# Result ownership (paper §3.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResultRecord:
    job: str
    data: ChunkedData | None      # None ⇒ lost (worker failure) or released
    owner_worker: int | None      # set when no_send_back kept it on the worker
    owner_scheduler: int          # scheduler responsible for the job
    sent_back: bool               # False ⇒ lives only on the worker
    nbytes: int = 0

    @property
    def available(self) -> bool:
        return self.data is not None


class ResultStore:
    """Distributed result directory.

    The master never stores data (paper); this store records *where* each
    result lives (scheduler device or retained on a worker) plus the handle
    to the (device-resident) arrays.
    """

    def __init__(self, cluster: VirtualCluster):
        self.cluster = cluster
        self.records: dict[str, ResultRecord] = {}

    def put(self, job: Job, data: ChunkedData, worker: Worker) -> ResultRecord:
        if job.no_send_back:
            worker.retained[job.name] = data
            rec = ResultRecord(job=job.name, data=data, owner_worker=worker.wid,
                               owner_scheduler=worker.scheduler, sent_back=False,
                               nbytes=data.nbytes)
        else:
            sched = self.cluster.scheduler(worker.scheduler)
            sched.stored[job.name] = data
            rec = ResultRecord(job=job.name, data=data, owner_worker=None,
                               owner_scheduler=worker.scheduler, sent_back=True,
                               nbytes=data.nbytes)
        self.records[job.name] = rec
        return rec

    def get(self, name: str) -> ResultRecord:
        try:
            return self.records[name]
        except KeyError:
            raise GraphValidationError(f"no result recorded for job {name}") from None

    def invalidate_worker(self, wid: int) -> list[str]:
        """Worker loss: every not-sent-back result it retained is gone.
        Returns the names of lost results (to be re-computed, DESIGN.md §6)."""
        lost = []
        for rec in self.records.values():
            if rec.owner_worker == wid and not rec.sent_back and rec.data is not None:
                rec.data = None
                lost.append(rec.job)
        return lost

    def release(self, name: str) -> None:
        """Paper: scheduler signals the worker the data is no longer required."""
        rec = self.records.get(name)
        if rec is None:
            return
        if rec.owner_worker is not None:
            w = self.cluster.workers[rec.owner_worker]
            w.retained.pop(name, None)
        rec.data = None

    def location_device(self, name: str):
        rec = self.get(name)
        if rec.owner_worker is not None:
            return self.cluster.workers[rec.owner_worker].device
        return self.cluster.scheduler(rec.owner_scheduler).device


# ---------------------------------------------------------------------------
# Placement planning (master scheduler)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Placement:
    """Assignment of one job to a worker (+ declared parallel width)."""

    job: Job
    worker: Worker
    n_sequences: int              # how many parallel lanes the job gets
    co_scheduled_with: tuple[str, ...] = ()
    local_bytes: int = 0          # input bytes already resident on the worker
    moved_bytes: int = 0          # input bytes that must be transferred
    est_cost_s: float = 0.0       # cost-model estimate (strategy="cost" only)


@dataclasses.dataclass(frozen=True)
class CostModelParams:
    """Hardware constants for the cost-model placement strategy.

    The three terms mirror the roofline decomposition of
    ``repro.analysis.roofline`` (compute / memory / interconnect); use
    :meth:`from_hw` to derive them from an ``analysis.roofline.HW`` profile
    (e.g. ``V5E``).  Defaults are a conservative host-CPU profile so the
    model produces sane *relative* costs out of the box.
    """

    peak_flops: float = 100e9     # per worker
    mem_bw: float = 20e9          # B/s local (worker-resident) reads
    link_bw: float = 5e9          # B/s cross-worker transfers
    dispatch_s: float = 50e-6     # fixed per-job dispatch overhead

    @classmethod
    def from_hw(cls, hw) -> "CostModelParams":
        """Build from any object with peak_flops / hbm_bw / ici_bw attrs
        (duck-typed so core never imports repro.analysis)."""
        return cls(peak_flops=hw.peak_flops, mem_bw=hw.hbm_bw,
                   link_bw=hw.ici_bw)


class MasterScheduler:
    """Rank-0 process: owns the JobGraph, computes placements, stores no data.

    Two selectable placement strategies:

    ``strategy="greedy"`` (default, paper-faithful):
      1. locality first — place a job where the most input bytes already live
         (generalises the paper's ``no_send_back`` retention),
      2. then least-loaded worker,
      3. co-schedule same-function jobs onto one worker while their combined
         thread demand fits its cores (paper §3.3's 2×2-threads-on-4-cores
         example).

    ``strategy="cost"`` (DESIGN.md §5): per candidate worker estimate

        cost = moved_bytes / link_bw                     (transfer)
             + queue_depth * observed_fn_time            (queueing)
             + max(flops_hint / peak, in_bytes / mem_bw) (roofline compute)
               * worker.slowdown

      and place on the argmin.  ``flops_hint`` comes from ``Job.cost_hint``;
      observed per-function wall times are fed back by the executor through
      :meth:`observe` (EWMA), so the queue term sharpens as the run
      progresses.  Co-scheduling is honoured in both strategies.

    Workers are spawned on demand (paper: "dynamically created during
    runtime"), up to the cluster limit.
    """

    def __init__(self, graph: JobGraph, cluster: VirtualCluster, *,
                 strategy: str = "greedy",
                 cost_params: CostModelParams | None = None,
                 observed_fn_times: Mapping[Any, float] | None = None):
        if strategy not in ("greedy", "cost"):
            raise ValueError(f"unknown placement strategy {strategy!r}")
        self.graph = graph
        self.cluster = cluster
        self.strategy = strategy
        self.cost_params = cost_params or CostModelParams()
        # EWMA of observed wall time per function id (cost-model queue term).
        # Seeded from prior measurements when available (e.g. the kernel
        # autotune cache, repro.kernels.tuning) so the very first placement
        # round already prices queueing with observed rather than guessed
        # times; runtime observations keep refining it.
        self._fn_time: dict[Any, float] = dict(observed_fn_times or {})

    # -- runtime feedback (executor -> master) ---------------------------------
    def observe(self, fid, elapsed_s: float, alpha: float = 0.3) -> None:
        prev = self._fn_time.get(fid)
        self._fn_time[fid] = (elapsed_s if prev is None
                              else (1 - alpha) * prev + alpha * elapsed_s)

    def _est_fn_time(self, fid) -> float:
        if fid in self._fn_time:
            return self._fn_time[fid]
        times = list(self._fn_time.values())
        return float(np.mean(times)) if times else self.cost_params.dispatch_s

    def _est_job_cost(self, job: Job, worker: Worker, *, total_in: int,
                      local: int, queue_depth: int) -> float:
        p = self.cost_params
        moved = total_in - local
        transfer_s = moved / p.link_bw
        queue_s = queue_depth * self._est_fn_time(job.fn)
        compute_s = max(job.cost_hint / p.peak_flops,
                        total_in / p.mem_bw) * worker.slowdown
        return p.dispatch_s + transfer_s + queue_s + compute_s

    # -- helpers ---------------------------------------------------------------
    def _input_bytes_by_location(self, job: Job, store: ResultStore) -> dict[int | None, int]:
        """Map worker-id (or None = scheduler-resident) -> input bytes there."""
        by_loc: dict[int | None, int] = {}
        for ref in job.inputs:
            rec = store.records.get(ref.job)
            if rec is None or rec.data is None:
                continue
            sel = ref.select(rec.data)
            loc = rec.owner_worker if not rec.sent_back else None
            by_loc[loc] = by_loc.get(loc, 0) + sel.nbytes
        return by_loc

    def plan_segment(self, segment_jobs: Sequence[Job], store: ResultStore,
                     *, loads: Mapping[int, int] | None = None) -> list[Placement]:
        """Plan placements for every job of one parallel segment.

        This call is batched by design — callers should hand it ALL the jobs
        that become ready together (a whole segment, or a serving admission
        wave — see ``repro.serve.scheduler.HyParRequestTracker.place_batch``)
        rather than loop over singletons: one call amortises the ordering /
        co-scheduling bookkeeping and lets locality and load terms see the
        whole wave at once.

        Re-placement is legal: a job that was removed from the graph
        (``JobGraph.remove_job`` — serving-time GC or a preempted dynamic
        job returning to the master queue) may be re-spawned under the same
        name and planned again in a later wave; the planner holds no state
        keyed on job identity beyond the per-function EWMA, which is
        exactly what SHOULD carry over to the re-placed incarnation.
        """
        loads = dict(loads or {})
        placements: list[Placement] = []
        # deterministic order: jobs sorted by (fn, name) so same-fn jobs are
        # adjacent for co-scheduling
        order = sorted(segment_jobs, key=lambda j: (str(j.fn), j.name))
        cohab: dict[int, list[Placement]] = {}   # wid -> placements sharing it

        for job in order:
            # input-less jobs (serving admissions, source jobs) skip the
            # result-directory walk entirely — on a hot admission path this
            # is one dict scan per job per wave
            by_loc = (self._input_bytes_by_location(job, store)
                      if job.inputs else {})
            total_in = sum(by_loc.values())

            # try co-scheduling with an already-placed same-fn job
            placed = None
            want = job.n_threads if job.n_threads > 0 else self.cluster.cores_per_worker
            for wid, plist in cohab.items():
                w = self.cluster.workers[wid]
                if not w.alive:
                    continue
                used = sum(p.n_sequences for p in plist)
                if (all(p.job.fn == job.fn for p in plist)
                        and used + want <= w.cores):
                    placed = Placement(job=job, worker=w, n_sequences=want,
                                       co_scheduled_with=tuple(p.job.name for p in plist))
                    break

            if placed is None:
                if self.strategy == "cost":
                    w, est = self._choose_worker_cost(job, by_loc, total_in, loads)
                else:
                    w, est = self._choose_worker_greedy(job, by_loc, loads), 0.0
                n_seq = min(want, w.cores) if want > 0 else w.cores
                placed = Placement(job=job, worker=w, n_sequences=max(n_seq, 1),
                                   est_cost_s=est)

            local = by_loc.get(placed.worker.wid, 0)
            placed.local_bytes = local
            placed.moved_bytes = total_in - local
            loads[placed.worker.wid] = loads.get(placed.worker.wid, 0) + 1
            cohab.setdefault(placed.worker.wid, []).append(placed)
            placements.append(placed)

        # restore original job order for execution determinism
        idx = {j.name: i for i, j in enumerate(segment_jobs)}
        placements.sort(key=lambda p: idx[p.job.name])
        return placements

    # -- worker choice ---------------------------------------------------------
    def _choose_worker_greedy(self, job: Job, by_loc: Mapping[int | None, int],
                              loads: Mapping[int, int]) -> Worker:
        """Locality first, then least-loaded alive worker, else spawn."""
        best_wid, best_bytes = None, -1
        for loc, nb in sorted(by_loc.items(), key=lambda kv: (-kv[1], str(kv[0]))):
            if loc is None:
                continue
            w = self.cluster.workers[loc]
            if w.alive and nb > best_bytes:
                best_wid, best_bytes = loc, nb
        if best_wid is not None and best_bytes > 0:
            return self.cluster.workers[best_wid]
        alive = self.cluster.alive_workers()
        free = [w for w in alive if loads.get(w.wid, 0) == 0]
        if not free and len(alive) < self.cluster.max_workers:
            return self.cluster.spawn_worker()
        if alive:
            return min(alive, key=lambda w: (loads.get(w.wid, 0), w.wid))
        return self.cluster.spawn_worker()

    def _choose_worker_cost(self, job: Job, by_loc: Mapping[int | None, int],
                            total_in: int, loads: Mapping[int, int]
                            ) -> tuple[Worker, float]:
        """Argmin of the three-term cost estimate over all candidates.

        A to-be-spawned worker is one candidate (zero queue depth, zero
        locality) so the model decides between reusing a loaded worker with
        the data and paying the transfer to an idle one.
        """
        candidates: list[tuple[float, int, Worker | None]] = []
        for w in self.cluster.alive_workers():
            cost = self._est_job_cost(
                job, w, total_in=total_in, local=by_loc.get(w.wid, 0),
                queue_depth=loads.get(w.wid, 0))
            candidates.append((cost, w.wid, w))
        if len(self.cluster.alive_workers()) < self.cluster.max_workers:
            ghost = Worker(wid=len(self.cluster.workers), device=None)
            cost = self._est_job_cost(job, ghost, total_in=total_in, local=0,
                                      queue_depth=0)
            candidates.append((cost, ghost.wid, None))
        if not candidates:
            return self.cluster.spawn_worker(), 0.0
        cost, _, w = min(candidates, key=lambda c: (c[0], c[1]))
        if w is None:
            w = self.cluster.spawn_worker()
        return w, cost
