"""Autotuner subsystem: cache hit/miss, corrupt-cache recovery, selection
determinism under a seeded timer stub, and the cost-model bridge."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tuning
from repro.kernels.tuning import (Autotuner, TuningCache, cache_key,
                                  calibrated_cost_params, shape_bucket)


class SeededTimer:
    """perf_counter stub: each call advances the clock by a seeded
    pseudo-random amount, so measured intervals — and therefore the
    selected config — are deterministic functions of the seed."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.now = 0.0
        self.calls = 0

    def __call__(self) -> float:
        self.calls += 1
        self.now += float(self.rng.random())
        return self.now


CANDS = [{"block": b} for b in (64, 128, 256)]


def _make_call(cfg):
    return lambda: jnp.zeros((4,))


def test_shape_bucketing():
    assert shape_bucket((1000, 1000)) == (1024, 1024)
    assert shape_bucket((1024, 1)) == (1024, 1)
    k1 = cache_key("jacobi_sweep", "cpu", (1000, 1000), jnp.float32)
    k2 = cache_key("jacobi_sweep", "cpu", (1024, 1024), jnp.float32)
    k3 = cache_key("jacobi_sweep", "cpu", (2048, 2048), jnp.float32)
    assert k1 == k2 and k1 != k3
    assert cache_key("jacobi_sweep", "cpu", (1024, 1024), jnp.bfloat16) != k2


def test_cache_miss_times_then_hit_skips_timing(tmp_path):
    timer = SeededTimer(0)
    tuner = Autotuner(TuningCache(str(tmp_path / "t.json")), timer=timer)
    e1 = tuner.tune("k", _make_call, shape=(256, 256), dtype=jnp.float32,
                    candidates=CANDS)
    assert e1["timed"] == len(CANDS)
    assert timer.calls > 0
    calls_after_miss = timer.calls
    e2 = tuner.tune("k", _make_call, shape=(256, 256), dtype=jnp.float32,
                    candidates=CANDS)
    assert timer.calls == calls_after_miss        # hit: nothing re-timed
    assert e2 == e1
    # a second tuner on the same cache file also hits (persistence)
    timer3 = SeededTimer(1)
    tuner3 = Autotuner(TuningCache(str(tmp_path / "t.json")), timer=timer3)
    e3 = tuner3.tune("k", _make_call, shape=(250, 250), dtype=jnp.float32,
                     candidates=CANDS)           # same bucket -> same key
    assert timer3.calls == 0
    assert e3 == e1


def test_corrupt_cache_file_recovers(tmp_path):
    path = tmp_path / "t.json"
    path.write_text("{definitely not json")
    tuner = Autotuner(TuningCache(str(path)), timer=SeededTimer(0))
    entry = tuner.tune("k", _make_call, shape=(64,), dtype=jnp.float32,
                       candidates=CANDS)
    assert entry["config"] in CANDS
    # the rewritten file is valid JSON and round-trips
    data = json.loads(path.read_text())
    assert len(data["entries"]) == 1


def test_truncated_cache_file_recovers(tmp_path):
    path = tmp_path / "t.json"
    path.write_text('{"version": 1, "entries": {"a": ')
    assert TuningCache(str(path)).load() == {}


def test_schema_corrupt_entries_are_dropped(tmp_path):
    """Valid JSON with malformed entries (missing config/median_s) must be
    filtered at load — not crash lookup()/observed_s() in the wrappers."""
    path = tmp_path / "t.json"
    good = {"config": {"block": 64}, "median_s": 1e-3}
    path.write_text(json.dumps({"version": 1, "entries": {
        "k|cpu|interpret|256x256|float32": {"median": 1},    # wrong keys
        "k|cpu|interpret|512x512|float32": {"config": "x", "median_s": 1e-3},
        "k|cpu|interpret|64x64|float32": good,
    }}))
    tuner = Autotuner(TuningCache(str(path)))
    assert tuner.lookup("k", (256, 256), jnp.float32, backend="cpu") is None
    assert tuner.observed_s("k", (512, 512), jnp.float32, backend="cpu") is None
    assert tuner.lookup("k", (64, 64), jnp.float32, backend="cpu") == good["config"]


def test_legacy_four_part_keys_dropped_on_load(tmp_path):
    """Pre-impl-keying cache entries (4-part keys) can't say whether they
    were timed under interpret or the real kernel — they are dropped at
    load, never migrated into either impl's namespace."""
    path = tmp_path / "t.json"
    legacy = {"config": {"block": 64}, "median_s": 1e-3, "backend": "cpu"}
    path.write_text(json.dumps({"version": 1, "entries": {
        "k|cpu|64x64|float32": legacy,                       # legacy schema
        "k|cpu|interpret|64x64|float32": {"config": {"block": 32},
                                          "median_s": 2e-3},
    }}))
    cache = TuningCache(str(path))
    assert list(cache.load()) == ["k|cpu|interpret|64x64|float32"]
    tuner = Autotuner(cache)
    assert tuner.lookup("k", (64, 64), jnp.float32,
                        backend="cpu") == {"block": 32}


def test_unserializable_config_save_is_not_fatal(tmp_path):
    """A non-JSON-serializable candidate value must not discard the tuned
    result or leak mkstemp temp files."""
    tuner = Autotuner(TuningCache(str(tmp_path / "t.json")),
                      timer=SeededTimer(0))
    cands = [{"block": object()}]                 # json.dump -> TypeError
    e = tuner.tune("k", lambda cfg: (lambda: jnp.zeros((2,))), shape=(64,),
                   dtype=jnp.float32, candidates=cands)
    assert e["timed"] == 1                        # tuning result survived
    assert [p.name for p in tmp_path.iterdir()
            if p.suffix == ".tmp"] == []          # no temp-file leak


def test_selection_deterministic_under_seeded_timer(tmp_path):
    picks = []
    for run in range(2):
        tuner = Autotuner(TuningCache(str(tmp_path / f"t{run}.json")),
                          timer=SeededTimer(42))
        e = tuner.tune("k", _make_call, shape=(128, 128), dtype=jnp.float32,
                       candidates=CANDS)
        picks.append(tuple(sorted(e["config"].items())))
    assert picks[0] == picks[1]


def test_failing_candidates_are_skipped(tmp_path):
    def make_call(cfg):
        if cfg["block"] == 128:
            raise ValueError("invalid for shape")
        return lambda: jnp.zeros((2,))

    tuner = Autotuner(TuningCache(str(tmp_path / "t.json")),
                      timer=SeededTimer(0))
    e = tuner.tune("k", make_call, shape=(64,), dtype=jnp.float32,
                   candidates=CANDS)
    assert e["timed"] == len(CANDS) - 1
    assert e["config"]["block"] != 128

    with pytest.raises(RuntimeError):
        tuner.tune("k2", lambda cfg: (_ for _ in ()).throw(ValueError()),
                   shape=(64,), dtype=jnp.float32, candidates=CANDS)


def test_ops_wrappers_consult_cache(tmp_path, monkeypatch):
    """A tuned entry transparently supplies block sizes to the wrappers."""
    path = str(tmp_path / "t.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    cache = TuningCache(path)
    key = cache_key("jacobi_sweep", "cpu", (256, 256), jnp.float32)
    cache.put(key, {"config": {"row_block": 64, "col_block": 32},
                    "median_s": 1e-3, "flops": 0.0, "bytes": 0.0})

    from repro.kernels.jacobi_sweep.ops import _tuned_blocks
    assert _tuned_blocks(256, jnp.float32, None, None) == (64, 32)
    # explicit blocks always win over the cache
    assert _tuned_blocks(256, jnp.float32, 128, 128) == (128, 128)
    # untuned bucket falls back to defaults
    assert _tuned_blocks(4096, jnp.float32, None, None) == (256, 256)


def test_observed_s_nearest_bucket_scaling(tmp_path):
    """A miss with nearest=True falls back to the closest tuned bucket of
    the same kernel/backend/dtype, scaled by the element-count ratio."""
    cache = TuningCache(str(tmp_path / "t.json"))
    cache.put(cache_key("jacobi_sweep", "cpu", (2048, 2048), jnp.float32),
              {"config": {}, "median_s": 1e-2, "backend": "cpu"})
    tuner = Autotuner(cache)
    # exact hit unaffected
    assert tuner.observed_s("jacobi_sweep", (2048, 2048), jnp.float32,
                            backend="cpu") == pytest.approx(1e-2)
    # miss without nearest stays None
    assert tuner.observed_s("jacobi_sweep", (2709, 2709), jnp.float32,
                            backend="cpu") is None
    # nearest: scaled by actual work ratio (2709² / 2048²)
    t = tuner.observed_s("jacobi_sweep", (2709, 2709), jnp.float32,
                         backend="cpu", nearest=True)
    assert t == pytest.approx(1e-2 * 2709 ** 2 / 2048 ** 2)
    # wrong kernel/backend/dtype never match
    assert tuner.observed_s("rmsnorm", (2709, 2709), jnp.float32,
                            backend="cpu", nearest=True) is None
    assert tuner.observed_s("jacobi_sweep", (2709, 2709), jnp.bfloat16,
                            backend="cpu", nearest=True) is None


def test_calibrated_cost_params(tmp_path):
    cache = TuningCache(str(tmp_path / "t.json"))
    tuner = Autotuner(cache)
    base = calibrated_cost_params(tuner=tuner)     # empty cache -> base
    assert base.peak_flops == 100e9

    cache.put("a|cpu|interpret|256x256|float32",
              {"config": {}, "median_s": 1e-3, "flops": 2e9, "bytes": 4e8,
               "backend": "cpu", "impl": "interpret"})
    cache.put("b|cpu|interpret|256x256|float32",
              {"config": {}, "median_s": 1e-3, "flops": 1e9, "bytes": 8e8,
               "backend": "cpu", "impl": "interpret"})
    # a foreign-backend entry must NOT poison the calibration
    cache.put("c|tpu|kernel|256x256|float32",
              {"config": {}, "median_s": 1e-6, "flops": 2e12, "bytes": 4e11,
               "backend": "tpu", "impl": "kernel"})
    p = calibrated_cost_params(tuner=tuner, backend="cpu")
    # best achieved rates across entries
    assert p.peak_flops == pytest.approx(2e9 / 1e-3)
    assert p.mem_bw == pytest.approx(8e8 / 1e-3)
    assert p.link_bw == base.link_bw


def test_interpret_entries_cannot_poison_real_backend(tmp_path):
    """The backend-poisoning regression (ISSUE 10): a cache populated by
    CPU/interpret runs — or by a forced-interpret debug run ON a TPU host
    — must not leak block configs or calibration rates into the TPU kernel
    path.  The interpreter's timings describe the interpreter, not the
    hardware."""
    path = tmp_path / "t.json"
    cache = TuningCache(str(path))
    # a CPU-interpret tune (what CI machines record) ...
    cache.put(cache_key("flash_attention", "cpu", (4, 8, 512, 64),
                        jnp.float32, "interpret"),
              {"config": {"q_block": 128, "kv_block": 128},
               "median_s": 3.0, "flops": 1e9, "bytes": 1e8,
               "backend": "cpu", "impl": "interpret"})
    # ... and the sneaky variant: forced interpret on a TPU host records
    # backend="tpu" with garbage (interpreter) timings
    cache.put(cache_key("flash_attention", "tpu", (4, 8, 512, 64),
                        jnp.float32, "interpret"),
              {"config": {"q_block": 256, "kv_block": 256},
               "median_s": 7.0, "flops": 1e15, "bytes": 1e14,
               "backend": "tpu", "impl": "interpret"})
    tuner = Autotuner(cache)
    # neither entry answers a TPU kernel-path config lookup ...
    assert tuner.lookup("flash_attention", (4, 8, 512, 64), jnp.float32,
                        backend="tpu", impl="kernel") is None
    assert tuner.observed_s("flash_attention", (4, 8, 512, 64), jnp.float32,
                            backend="tpu", impl="kernel",
                            nearest=True) is None
    # ... and neither alters TPU-path calibration (flops/median would give
    # an absurd 1e15/7 "measured" rate here)
    base = calibrated_cost_params(tuner=Autotuner(TuningCache(
        str(tmp_path / "empty.json"))), backend="tpu")
    p = calibrated_cost_params(tuner=tuner, backend="tpu")
    assert p.peak_flops == base.peak_flops and p.mem_bw == base.mem_bw
    # the CPU-interpret entry still serves the CPU path it was timed on
    assert tuner.lookup("flash_attention", (4, 8, 512, 64), jnp.float32,
                        backend="cpu") == {"q_block": 128, "kv_block": 128}
    # and survives the file round-trip under the 5-part schema
    assert len(TuningCache(str(path)).load()) == 2


def test_get_tuner_per_cache_path(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "a.json"))
    ta = tuning.get_tuner()
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "b.json"))
    tb = tuning.get_tuner()
    assert ta is not tb and ta.cache.path != tb.cache.path
