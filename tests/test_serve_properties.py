"""Property-based serve soak (ISSUE 5): hypothesis-driven random traces
through ``ServeScheduler`` + ``PagedEngine`` on the tiny qwen2/mamba2
configs, with the scheduler's structural invariants asserted after EVERY
step:

* no page aliasing across live slots (each outstanding page owned by
  exactly one slot, never the trash page),
* allocator conservation: ``n_free + n_outstanding`` equals the usable
  pool, and the outstanding set equals the union of slot ``page_ids``,
* the engine's live page table mirrors each committed slot's pages
  (mid-prefill and free slots parked on the trash page),
* at drain: zero leaked pages, every admitted request completed exactly
  once, and each request's tokens bit-match its preemption-free
  single-request run (the recompute-resume correctness oracle).

Pool sizes sweep down to near-exhaustion so lifetime mode exercises
deferred admission and demand mode exercises the preempt/resume state
machine.  Engines are cached per draw key (jit programs compile once —
slot and pool reuse across examples is exactly production slot reuse); the
example budget is raised in the tier-2 CI lane via ``SERVE_SOAK_EXAMPLES``.
"""
import dataclasses
import functools
import os

import jax
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve import PagedEngine, ServeScheduler

MAX_EXAMPLES = int(os.environ.get("SERVE_SOAK_EXAMPLES", "10"))
ARCHS = ("qwen2-1.5b", "mamba2-370m")
BATCH, MAX_LEN, PAGE, CHUNK = 3, 64, 8, 16
MAX_POOL = 1 + BATCH * (MAX_LEN // PAGE)     # the engine's physical pool
# near-exhaustion floor: the largest single request (prompt 40, budget 6,
# worst-case resume span 48 tokens) needs 6 usable pages; pools below that
# shed it up front, which is also a path worth soaking
MIN_POOL = 1 + 5
PROMPT_LENS = (3, 9, 12, 23, 30, 40)         # 40 > CHUNK => multi-chunk
STEP_CAP = 800                               # liveness: drain must finish


@functools.lru_cache(maxsize=None)
def _model(arch):
    cfg = dataclasses.replace(get_smoke_config(arch),
                              compute_dtype="float32")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _prompts(arch):
    cfg, _ = _model(arch)
    rng = np.random.default_rng(99)
    return tuple(rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)
                 for n in PROMPT_LENS)


@functools.lru_cache(maxsize=None)
def _engine(arch):
    cfg, params = _model(arch)
    return PagedEngine(cfg, params, batch=BATCH, max_len=MAX_LEN,
                       page_size=PAGE, prefill_chunk=CHUNK)


@functools.lru_cache(maxsize=None)
def _ref_engine(arch):
    cfg, params = _model(arch)
    return PagedEngine(cfg, params, batch=1, max_len=MAX_LEN,
                       page_size=PAGE, prefill_chunk=CHUNK)


@functools.lru_cache(maxsize=None)
def _reference(arch, prompt_idx, max_new):
    """Preemption-free single-request oracle, memoised across examples."""
    sched = ServeScheduler(_ref_engine(arch))
    sched.submit(_prompts(arch)[prompt_idx], max_new=max_new)
    [res] = sched.run()
    return tuple(res.tokens)


def _check_invariants(sched):
    alloc, eng = sched.allocator, sched.engine
    # conservation: free + outstanding is exactly the usable pool
    assert alloc.n_free + alloc.n_outstanding == \
        alloc.num_pages - alloc.n_reserved
    owned = [p for s in sched.slots for p in s.page_ids]
    # no aliasing: every outstanding page belongs to exactly one slot, and
    # the trash page is never owned
    assert len(owned) == len(set(owned))
    assert set(owned) == set(alloc.outstanding)
    assert 0 not in owned
    for s in sched.slots:
        n = len(s.page_ids)
        row = eng.page_table[s.slot]
        if s.request is not None and not s.prefilling:
            # committed slot: live row is its pages, rest trash
            assert row[:n].tolist() == s.page_ids
            assert (row[n:] == 0).all()
        else:
            # free or mid-prefill: parked on the trash page
            assert (row == 0).all()


@given(arch=st.sampled_from(ARCHS),
       reqs=st.lists(st.tuples(st.integers(0, len(PROMPT_LENS) - 1),
                               st.sampled_from((2, 4, 6))),
                     min_size=3, max_size=7),
       pool=st.integers(MIN_POOL, MAX_POOL),
       demand=st.booleans(),
       policy=st.sampled_from(("fewest", "lifo")),
       watermark=st.integers(0, 2))
@settings(max_examples=MAX_EXAMPLES, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
def test_serve_soak_invariants_and_bitmatch(arch, reqs, pool, demand,
                                            policy, watermark):
    eng = _engine(arch)
    # the engine is shared across examples (jit reuse); a PREVIOUS failing
    # example may have left committed rows behind — park everything on the
    # trash page so one genuine failure can't cascade into every later
    # example and poison hypothesis's shrinking
    eng.page_table[:] = 0
    eng._pt_device = None
    sched = ServeScheduler(
        eng, pool_pages=pool,
        reserve="demand" if demand else "lifetime",
        preempt_policy=policy,
        admit_watermark=watermark if demand else 0)
    rids = {}
    for idx, max_new in reqs:
        rid = sched.submit(_prompts(arch)[idx], max_new=max_new)
        if rid is not None:                  # tight pools may shed up front
            rids[rid] = (idx, max_new)

    steps = 0
    while sched.step() or len(sched.queue):
        _check_invariants(sched)
        steps += 1
        assert steps < STEP_CAP, (
            f"drain did not finish in {STEP_CAP} steps "
            f"(reqs={reqs}, pool={pool}, demand={demand})")

    # drain: no leaked pages, table fully parked, queue empty
    _check_invariants(sched)
    assert sched.allocator.n_outstanding == 0
    assert (sched.engine.page_table == 0).all()
    assert not sched._suspended
    # every admitted request completed exactly once…
    done = {}
    for res in sched.results:
        assert res.rid not in done
        done[res.rid] = res
    assert sorted(done) == sorted(rids)
    # …with tokens bit-matching its preemption-free single-request run
    for rid, (idx, max_new) in rids.items():
        assert tuple(done[rid].tokens) == _reference(arch, idx, max_new), (
            f"rid {rid} (prompt {idx}, max_new {max_new}) diverged "
            f"(pool={pool}, demand={demand}, preempts={sched.n_preempted})")


def test_shim_not_active_in_ci():
    """CI installs real hypothesis (requirements-dev.txt); the conftest
    fallback shim silently degrades @given to a fixed sampled-example loop,
    so its presence in CI would quietly gut the soak coverage above."""
    import hypothesis
    if os.environ.get("CI"):
        assert not getattr(hypothesis, "__is_shim__", False), (
            "tests/conftest.py hypothesis shim active in CI — install "
            "requirements-dev.txt")
