"""Property-based serve soak (ISSUE 5): hypothesis-driven random traces
through ``ServeScheduler`` + ``PagedEngine`` on the tiny qwen2/mamba2
configs, with the scheduler's structural invariants asserted after EVERY
step:

* no duplicate pages within a slot, never the trash page; with the
  prefix cache OFF, each outstanding page is owned by exactly one slot,
* refcount accounting (prefix cache ON): every outstanding page's
  reference count equals the number of slots mapping it plus the cache's
  own hold, and writable iff refcount == 1,
* allocator conservation: ``n_free + n_outstanding`` equals the usable
  pool, and the outstanding set equals the union of slot ``page_ids``
  (plus the cache-held pages when sharing is on),
* the engine's live page table mirrors each committed slot's pages
  (mid-prefill and free slots parked on the trash page),
* at drain: outstanding pages are exactly the cache-held ones (zero after
  a flush — no leaked references), every admitted request completed
  exactly once, and each request's tokens bit-match its preemption-free
  single-request run (the recompute-resume correctness oracle) — with
  sharing enabled too, including under demand-mode preemption.

Pool sizes sweep down to near-exhaustion so lifetime mode exercises
deferred admission and demand mode exercises the preempt/resume state
machine; shared-prefix traces (all prompts opening with the same tokens)
exercise cache hits, shared-page admission and cache eviction under
pressure.  A ``device_groups=2`` dimension partitions slots and pages into
two groups (DESIGN.md §13): every invariant above holds per group, plus
group ownership — no slot or cache ever references a page outside its
group's private range.  Engines are cached per draw key (jit programs compile once —
slot and pool reuse across examples is exactly production slot reuse); the
example budget is raised in the tier-2 CI lane via ``SERVE_SOAK_EXAMPLES``.
"""
import dataclasses
import functools
import os

import jax
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve import PagedEngine, ServeScheduler

MAX_EXAMPLES = int(os.environ.get("SERVE_SOAK_EXAMPLES", "10"))
ARCHS = ("qwen2-1.5b", "mamba2-370m")
BATCH, MAX_LEN, PAGE, CHUNK = 3, 64, 8, 16
MAX_POOL = 1 + BATCH * (MAX_LEN // PAGE)     # the engine's physical pool
# near-exhaustion floor: the largest single request (prompt 40, budget 6,
# worst-case resume span 48 tokens) needs 6 usable pages; pools below that
# shed it up front, which is also a path worth soaking
MIN_POOL = 1 + 5
PROMPT_LENS = (3, 9, 12, 23, 30, 40)         # 40 > CHUNK => multi-chunk
STEP_CAP = 800                               # liveness: drain must finish


@functools.lru_cache(maxsize=None)
def _model(arch):
    cfg = dataclasses.replace(get_smoke_config(arch),
                              compute_dtype="float32")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _prompts(arch, share=False):
    """Random prompts per length; with ``share``, every prompt >= 2 pages
    opens with the SAME page-aligned prefix (system-prompt workload) so
    the prefix cache gets real hits."""
    cfg, _ = _model(arch)
    rng = np.random.default_rng(99)
    prompts = [rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)
               for n in PROMPT_LENS]
    if share:
        prefix = rng.integers(0, cfg.vocab_size - 1,
                              (2 * PAGE,)).astype(np.int32)
        prompts = [np.concatenate([prefix, p[len(prefix):]])
                   if len(p) > len(prefix) else p
                   for p in prompts]
    return tuple(prompts)


@functools.lru_cache(maxsize=None)
def _engine(arch):
    cfg, params = _model(arch)
    return PagedEngine(cfg, params, batch=BATCH, max_len=MAX_LEN,
                       page_size=PAGE, prefill_chunk=CHUNK)


@functools.lru_cache(maxsize=None)
def _ref_engine(arch):
    cfg, params = _model(arch)
    return PagedEngine(cfg, params, batch=1, max_len=MAX_LEN,
                       page_size=PAGE, prefill_chunk=CHUNK)


@functools.lru_cache(maxsize=None)
def _reference(arch, prompt_idx, max_new, share=False):
    """Preemption-free, sharing-free single-request oracle, memoised
    across examples.  ``share`` only selects the prompt set — the oracle
    itself never uses the prefix cache, which is exactly what makes it an
    oracle for the sharing path's bit-exactness."""
    sched = ServeScheduler(_ref_engine(arch))
    sched.submit(_prompts(arch, share)[prompt_idx], max_new=max_new)
    [res] = sched.run()
    return tuple(res.tokens)


def _check_invariants(sched):
    from collections import Counter

    eng = sched.engine
    for g in sched.groups:
        alloc = g.allocator
        # per-group conservation: free + outstanding is exactly the
        # group's private pool
        assert alloc.n_free + alloc.n_outstanding == \
            alloc.num_pages - alloc.n_reserved
        owned = [p for i in g.slot_ids for p in sched.slots[i].page_ids]
        mapped = Counter(owned)
        cached = g.prefix.pages() if g.prefix is not None else set()
        # group ownership: every page a group's slot (or its cache) refs
        # lies inside the group's private range — no cross-group refs
        for p in set(mapped) | cached:
            assert g.page_lo <= p < g.page_hi, \
                f"group {g.gid} references foreign page {p}"
        # a slot's own row never repeats a page; the trash page is unowned
        for i in g.slot_ids:
            s = sched.slots[i]
            assert len(s.page_ids) == len(set(s.page_ids))
        assert 0 not in mapped and 0 not in cached
        # outstanding = slot-mapped ∪ cache-held; per-page refcounts are
        # exactly the mapping slots plus the cache's own hold, and a page
        # is writable iff it has a single reference
        assert set(mapped) | cached == set(alloc.outstanding)
        for p in alloc.outstanding:
            assert alloc.refcount(p) == mapped[p] + (1 if p in cached else 0)
            assert alloc.writable(p) == (alloc.refcount(p) == 1)
        if g.prefix is None:
            # sharing off: the original exclusive-ownership invariant
            assert all(c == 1 for c in mapped.values())
    for s in sched.slots:
        n = len(s.page_ids)
        row = eng.page_table[s.slot]
        if s.request is not None and not s.prefilling:
            # committed slot: live row is its pages, rest trash
            assert row[:n].tolist() == s.page_ids
            assert (row[n:] == 0).all()
        else:
            # free or mid-prefill: parked on the trash page
            assert (row == 0).all()


@given(arch=st.sampled_from(ARCHS),
       reqs=st.lists(st.tuples(st.integers(0, len(PROMPT_LENS) - 1),
                               st.sampled_from((2, 4, 6))),
                     min_size=3, max_size=7),
       pool=st.integers(MIN_POOL, MAX_POOL),
       demand=st.booleans(),
       policy=st.sampled_from(("fewest", "lifo")),
       watermark=st.integers(0, 2),
       share=st.booleans(),
       groups=st.sampled_from((1, 2)))
@settings(max_examples=MAX_EXAMPLES, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
def test_serve_soak_invariants_and_bitmatch(arch, reqs, pool, demand,
                                            policy, watermark, share,
                                            groups):
    eng = _engine(arch)
    # the engine is shared across examples (jit reuse); a PREVIOUS failing
    # example may have left committed rows behind — park everything on the
    # trash page so one genuine failure can't cascade into every later
    # example and poison hypothesis's shrinking
    eng.page_table[:] = 0
    eng._pt_device = None
    sched = ServeScheduler(
        eng, pool_pages=pool,
        reserve="demand" if demand else "lifetime",
        preempt_policy=policy,
        admit_watermark=watermark if demand else 0,
        prefix_cache=share,    # mamba2 stays uncached (SSM state): the
        #                        knob must be safe to pass uniformly
        device_groups=groups)  # 2: slots 2/1, pages split — uneven is the
    #                            production case (batch % groups != 0)
    rids = {}
    for idx, max_new in reqs:
        rid = sched.submit(_prompts(arch, share)[idx], max_new=max_new)
        if rid is not None:                  # tight pools may shed up front
            rids[rid] = (idx, max_new)

    steps = 0
    while sched.step() or len(sched.queue):
        _check_invariants(sched)
        steps += 1
        assert steps < STEP_CAP, (
            f"drain did not finish in {STEP_CAP} steps "
            f"(reqs={reqs}, pool={pool}, demand={demand}, share={share}, "
            f"groups={groups})")

    # drain: per group, outstanding pages are exactly the cache-held ones
    # (each at refcount 1 — the cache's own hold), none after a flush;
    # table fully parked, queue empty
    _check_invariants(sched)
    for g in sched.groups:
        cached = g.prefix.pages() if g.prefix is not None else set()
        assert set(g.allocator.outstanding) == cached
        assert all(g.allocator.refcount(p) == 1 for p in cached)
    sched.flush_prefix_cache()
    for g in sched.groups:
        assert g.allocator.n_outstanding == 0, \
            f"group {g.gid} leaked pages after drain"
    assert (sched.engine.page_table == 0).all()
    assert not sched._suspended
    # every admitted request completed exactly once…
    done = {}
    for res in sched.results:
        assert res.rid not in done
        done[res.rid] = res
    assert sorted(done) == sorted(rids)
    # …with tokens bit-matching its preemption-free, SHARING-FREE
    # single-request run — prefix reuse must be invisible in the output
    for rid, (idx, max_new) in rids.items():
        assert tuple(done[rid].tokens) == \
            _reference(arch, idx, max_new, share), (
                f"rid {rid} (prompt {idx}, max_new {max_new}) diverged "
                f"(pool={pool}, demand={demand}, share={share}, "
                f"groups={groups}, preempts={sched.n_preempted})")


def test_paged_kernel_decode_bitmatches_gather_in_serve():
    """ISSUE 10 acceptance: decode through the paged-attention KERNEL (its
    interpret build on CPU — the same kernel body the TPU runs) is
    token-for-token identical to the materialising gather path through a
    full serve drain, with prefix-cache-shared pages (COW refcount>1
    reads) and post-preemption resumed slots in the trace."""
    arch = "qwen2-1.5b"
    cfg, params = _model(arch)
    # demand mode + a tight pool forces preempt/resume; share=True routes
    # every long prompt through shared prefix pages
    reqs = [(5, 6), (4, 6), (3, 4), (5, 4), (1, 6)]
    pool = MIN_POOL + 3

    def run(impl):
        eng = PagedEngine(cfg, params, batch=BATCH, max_len=MAX_LEN,
                          page_size=PAGE, prefill_chunk=CHUNK,
                          attn_impl=impl)
        sched = ServeScheduler(eng, pool_pages=pool, reserve="demand",
                               prefix_cache=True)
        rids = {}
        for idx, max_new in reqs:
            rid = sched.submit(_prompts(arch, True)[idx], max_new=max_new)
            assert rid is not None
            rids[rid] = (idx, max_new)
        results = {r.rid: tuple(r.tokens) for r in sched.run()}
        assert sorted(results) == sorted(rids)
        return results, rids, sched

    got_ref, rids, s_ref = run("ref")
    got_krn, _, s_krn = run("interpret")
    # the trace must actually exercise the paths the docstring claims
    assert s_ref.n_preempted >= 1 and s_krn.n_preempted >= 1
    assert s_ref.n_prefix_hits >= 1 and s_krn.n_prefix_hits >= 1
    assert got_krn == got_ref, "kernel decode diverged from gather path"
    for rid, (idx, max_new) in rids.items():
        assert got_krn[rid] == _reference(arch, idx, max_new, True)


def test_shim_not_active_in_ci():
    """CI installs real hypothesis (requirements-dev.txt); the conftest
    fallback shim silently degrades @given to a fixed sampled-example loop,
    so its presence in CI would quietly gut the soak coverage above."""
    import hypothesis
    if os.environ.get("CI"):
        assert not getattr(hypothesis, "__is_shim__", False), (
            "tests/conftest.py hypothesis shim active in CI — install "
            "requirements-dev.txt")
