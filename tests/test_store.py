"""Unit tests for the sqlite JobStore (tier-1: hermetic tmp-path stores,
no cross-test DB reuse, no processes)."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.store import JobStore, job_key


@pytest.fixture
def store(tmp_path):
    s = JobStore(tmp_path / "jobs.sqlite")
    yield s
    s.close()


# -- content identity ------------------------------------------------------

def test_job_key_deterministic_and_input_sensitive():
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    k1 = job_key("fn", [a])
    assert k1 == job_key("fn", [a.copy()])
    assert k1 != job_key("other_fn", [a])
    assert k1 != job_key("fn", [a + 1])
    assert k1 != job_key("fn", [a.astype(np.float64)])
    assert k1 != job_key("fn", [a.reshape(3, 2)])


def test_job_key_ignores_memory_layout():
    a = np.arange(9, dtype=np.float64).reshape(3, 3)
    assert job_key("fn", [a.T]) == job_key("fn", [np.ascontiguousarray(a.T)])


# -- results ---------------------------------------------------------------

def test_result_roundtrip_inline(store):
    arrays = [np.arange(5.0), np.ones((2, 2), np.int32)]
    store.put_result("k1", arrays, name="J1", fn="f")
    assert store.state("k1") == "done"
    got = store.load_result("k1")
    assert len(got) == 2
    for a, b in zip(arrays, got):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype


def test_result_spills_above_threshold(tmp_path):
    s = JobStore(tmp_path / "jobs.sqlite", spill_bytes=256)
    try:
        big = np.random.default_rng(0).standard_normal((64, 64))
        s.put_result("big", [big])
        files = os.listdir(s.spill_dir)
        assert files == ["big.npz"]
        np.testing.assert_array_equal(s.load_result("big")[0], big)
        assert s.check_leaks() == []
    finally:
        s.close()


def test_load_result_misses(store):
    assert store.load_result("nope") is None
    store.mark_running("k", name="J", fn="f", worker=0)
    assert store.load_result("k") is None  # running, not done


# -- job state machine -----------------------------------------------------

def test_running_then_done_then_running_stays_done(store):
    store.mark_running("k", name="J", fn="f", worker=1)
    assert store.state("k") == "running"
    store.put_result("k", [np.zeros(2)], worker=1)
    assert store.state("k") == "done"
    # a concurrent claim after completion must not regress the state
    store.mark_running("k", worker=2)
    assert store.state("k") == "done"


def test_worker_death_loses_only_its_running_jobs(store):
    store.register_worker(0)
    store.register_worker(1)
    store.mark_running("r0", worker=0)
    store.mark_running("r1", worker=1)
    store.put_result("d0", [np.ones(1)], worker=0)
    lost = store.mark_worker_jobs_lost(0)
    assert lost == ["r0"]
    assert store.state("r0") == "lost"
    assert store.state("r1") == "running"
    assert store.state("d0") == "done"  # persisted results survive the death
    assert store.bump_retries("r0") == 1
    assert store.counts() == {"lost": 1, "running": 1, "done": 1}


# -- heartbeats ------------------------------------------------------------

def test_registration_counts_as_first_beat(store):
    store.register_worker(0, pid=123)
    assert store.expired(10.0) == []
    hb = store.heartbeats()
    assert set(hb) == {0}
    assert time.time() - hb[0] < 5.0


def test_expiry_is_discovered_not_announced(store):
    store.register_worker(0)
    store.register_worker(1)
    time.sleep(0.05)
    store.beat(1)
    assert store.expired(0.04) == [0]
    store.mark_worker_dead(0)
    assert store.heartbeats().keys() == {1}
    assert store.expired(0.04) == []


# -- serve request persistence --------------------------------------------

def test_request_roundtrip_and_delete(store):
    store.put_request("r1", {"tokens": np.array([1, 2, 3]),
                             "token_s": np.array(42.5)})
    store.put_request("r1", {"tokens": np.array([1, 2, 3, 4]),
                             "token_s": np.array(42.5)})
    reqs = store.get_requests()
    assert list(reqs) == ["r1"]
    np.testing.assert_array_equal(reqs["r1"]["tokens"], [1, 2, 3, 4])
    store.delete_request("r1")
    assert store.get_requests() == {}
    assert store.get_request("r1") is None


# -- hygiene ---------------------------------------------------------------

def test_check_leaks_flags_stuck_jobs_and_orphan_spills(tmp_path):
    s = JobStore(tmp_path / "jobs.sqlite", spill_bytes=64)
    try:
        s.register_worker(0)
        s.mark_running("stuck", worker=0)
        s.mark_worker_dead(0)
        os.makedirs(s.spill_dir, exist_ok=True)
        with open(os.path.join(s.spill_dir, "junk.npz"), "wb") as f:
            f.write(b"x")
        problems = s.check_leaks()
        assert any("stuck" in p for p in problems)
        assert any("junk.npz" in p for p in problems)
        s.put_result("stuck", [np.zeros(64)], worker=0)
        os.remove(os.path.join(s.spill_dir, "junk.npz"))
        assert s.check_leaks() == []
    finally:
        s.close()


def test_concurrent_writers_share_one_store(tmp_path):
    """Many threads hammering one connection (the in-process contract; the
    cross-process contract is WAL + busy_timeout, exercised by the
    procworker tests)."""
    s = JobStore(tmp_path / "jobs.sqlite")
    try:
        def work(i):
            for j in range(20):
                s.put_result(f"k{i}_{j}", [np.full(3, i * 100 + j)])
        threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert s.n_done() == 160
        np.testing.assert_array_equal(s.load_result("k7_19")[0], np.full(3, 719))
    finally:
        s.close()


def test_meta_roundtrip(store):
    assert store.get_meta("graph") is None
    store.set_meta("graph", "demo-v1")
    store.set_meta("graph", "demo-v2")
    assert store.get_meta("graph") == "demo-v2"


# -- gc --------------------------------------------------------------------

def test_gc_noop_without_limits(store):
    store.put_result("k", [np.zeros(2)])
    assert store.gc() == {"rows": 0, "spill_files": 0, "request_rows": 0}
    assert store.state("k") == "done"


def test_gc_prunes_by_age(store):
    now = time.time()
    store.put_result("old", [np.zeros(2)])
    store.put_result("fresh", [np.ones(2)])
    with store._lock, store._conn:
        store._conn.execute("UPDATE jobs SET updated_at=? WHERE key='old'",
                            (now - 3600,))
    pruned = store.gc(max_age_s=60, now=now)
    assert pruned == {"rows": 1, "spill_files": 0, "request_rows": 0}
    assert store.state("old") is None
    assert store.load_result("fresh") is not None


def test_gc_caps_rows_keeping_most_recent(store):
    now = time.time()
    for i in range(6):
        store.put_result(f"k{i}", [np.full(2, i)])
        with store._lock, store._conn:
            store._conn.execute("UPDATE jobs SET updated_at=? WHERE key=?",
                                (now - 100 + i, f"k{i}"))
    pruned = store.gc(max_rows=2, now=now)
    assert pruned["rows"] == 4
    assert store.state("k5") == "done" and store.state("k4") == "done"
    assert all(store.state(f"k{i}") is None for i in range(4))


def test_gc_never_touches_running_rows(store):
    """The leak assertion: in-flight scheduling state is structurally
    exempt — neither an ancient age nor a zero row cap may drop a row
    that is not ``done``."""
    now = time.time()
    store.register_worker(0)
    for key, state in (("run", "running"), ("pend", "pending"),
                       ("lost", "lost")):
        store.mark_running(key, worker=0)
    with store._lock, store._conn:
        store._conn.execute("UPDATE jobs SET state='pending' WHERE key='pend'")
        store._conn.execute("UPDATE jobs SET state='lost' WHERE key='lost'")
        store._conn.execute("UPDATE jobs SET updated_at=?", (now - 9999,))
    pruned = store.gc(max_age_s=0, max_rows=0, now=now)
    assert pruned["rows"] == 0 and pruned["spill_files"] == 0
    assert store.state("run") == "running"
    assert store.state("pend") == "pending"
    assert store.state("lost") == "lost"


def test_gc_unlinks_spill_files(tmp_path):
    s = JobStore(tmp_path / "jobs.sqlite", spill_bytes=64)
    try:
        now = time.time()
        s.put_result("big_old", [np.zeros(64)])
        s.put_result("big_new", [np.ones(64)])
        with s._lock, s._conn:
            s._conn.execute(
                "UPDATE jobs SET updated_at=? WHERE key='big_old'",
                (now - 3600,))
        pruned = s.gc(max_age_s=60, now=now)
        assert pruned == {"rows": 1, "spill_files": 1, "request_rows": 0}
        assert sorted(os.listdir(s.spill_dir)) == ["big_new.npz"]
        # pruning left no orphans behind for the hygiene check to flag
        assert s.check_leaks() == []
    finally:
        s.close()


def test_gc_age_and_cap_compose(store):
    now = time.time()
    for i in range(5):
        store.put_result(f"k{i}", [np.full(2, i)])
        with store._lock, store._conn:
            age = 3600 if i < 2 else 100 - i
            store._conn.execute("UPDATE jobs SET updated_at=? WHERE key=?",
                                (now - age, f"k{i}"))
    # age drops k0/k1; of the survivors (k2, k3, k4) the cap keeps the two
    # most recent (k4 is youngest: age 100-i decreases with i)
    pruned = store.gc(max_age_s=600, max_rows=2, now=now)
    assert pruned["rows"] == 3
    assert store.state("k4") == "done" and store.state("k3") == "done"
    assert all(store.state(k) is None for k in ("k0", "k1", "k2"))


def test_gc_prunes_stale_request_rows_exempting_live(store):
    """Serve suspended-token rows: the serving path deletes them at retire,
    so a row older than the cutoff is an orphan of a dead master — UNLESS a
    live run claims it via ``exempt_requests``.  ``max_rows`` never applies
    to requests (age is the only orphan evidence)."""
    now = time.time()
    for rid in ("serve.suspended:0", "serve.suspended:1", "serve.suspended:2"):
        store.put_request(rid, {"tokens": np.array([1, 2, 3])})
    with store._lock, store._conn:
        store._conn.execute(
            "UPDATE requests SET updated_at=? WHERE rid!='serve.suspended:2'",
            (now - 3600,))
    pruned = store.gc(max_age_s=60, now=now,
                      exempt_requests=["serve.suspended:1"])
    assert pruned == {"rows": 0, "spill_files": 0, "request_rows": 1}
    assert store.get_request("serve.suspended:0") is None   # stale orphan
    assert store.get_request("serve.suspended:1") is not None  # live-exempt
    assert store.get_request("serve.suspended:2") is not None  # fresh
    # a rows-only gc leaves request rows alone: no age => no orphan evidence
    pruned = store.gc(max_rows=0, now=now)
    assert pruned["request_rows"] == 0
    assert store.get_request("serve.suspended:1") is not None
