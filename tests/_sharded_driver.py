"""Forced-2-device serving parity driver (run as a subprocess).

The XLA host-device-count flag must be set before jax initialises, and the
main pytest process is long past that — so test_serve_sharded.py runs this
file with ``python tests/_sharded_driver.py <arch> [<arch> ...]``.

For each arch it replays the SAME request trace through a PagedEngine +
ServeScheduler three ways on one two-device process:

* ``base`` — no mesh (today's single-device path),
* ``tp2``  — mesh ``(dp=1, tp=2)``: KV pools sharded over kv_heads,
* ``dp2``  — mesh ``(dp=2, tp=1)`` + two scheduler device groups,

and asserts the generated token streams are identical (TP reassociates the
output-projection reduction, so the guarantee across meshes is
token-identity, not bit-identity of logits — mesh size 1 vs None bit
identity is asserted in-process by test_serve_sharded.py).  For attention
models it also asserts TP=2 halves the per-device page-pool bytes.

Prints ``SHARDED_OK <json>`` on success.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402


def run_variant(cfg, params, mesh, *, device_groups=1, batch=2, num_pages=26):
    from repro.serve import PagedEngine, SamplingParams, ServeScheduler

    eng = PagedEngine(cfg, params, batch=batch, max_len=64, page_size=8,
                      num_pages=num_pages, prefill_chunk=16, mesh=mesh)
    sched = ServeScheduler(eng, sp=SamplingParams(), reserve="demand",
                           admit_watermark=1, device_groups=device_groups)
    rng = np.random.default_rng(7)
    for _ in range(2 * batch):
        sched.submit(rng.integers(1, 50, 12).astype(np.int32), 6)
    toks = [tuple(r.tokens) for r in sorted(sched.run(), key=lambda r: r.rid)]
    return toks, eng.per_device_pool_bytes(), sched


def main() -> None:
    assert len(jax.devices()) == 2, jax.devices()
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.serve.mesh import MeshSpec, build_serve_mesh

    report = {}
    for arch in sys.argv[1:]:
        cfg = dataclasses.replace(get_smoke_config(arch),
                                  compute_dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        base, base_bytes, _ = run_variant(cfg, params, None)
        tp2, tp2_bytes, _ = run_variant(
            cfg, params, build_serve_mesh(MeshSpec(tp=2, dp=1)))
        dp2, dp2_bytes, dp_sched = run_variant(
            cfg, params, build_serve_mesh(MeshSpec(tp=1, dp=2)),
            device_groups=2)
        assert base == tp2, f"{arch}: TP=2 tokens diverged from 1-device"
        assert base == dp2, f"{arch}: DP=2 tokens diverged from 1-device"
        if base_bytes:          # pure-SSM models have no attention pools
            assert 2 * tp2_bytes == base_bytes, \
                f"{arch}: TP=2 pool bytes {tp2_bytes} not half of {base_bytes}"
        assert len(dp_sched.groups) == 2
        for g in dp_sched.groups:
            assert g.allocator.n_outstanding == 0, \
                f"{arch}: group {g.gid} leaked pages after drain"
        report[arch] = {"n_tokens": sum(len(t) for t in base),
                        "base_bytes": base_bytes, "tp2_bytes": tp2_bytes,
                        "dp2_bytes": dp2_bytes}
    print("SHARDED_OK", json.dumps(report))


if __name__ == "__main__":
    main()
