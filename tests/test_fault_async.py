"""Worker failure with the async dispatchers: lineage recovery must
re-execute lost ``no_send_back`` results while jobs are in flight on the
per-worker queues, and ``ExecutionReport.recovered_jobs`` accounting must
stay correct (DESIGN.md §6)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChaosLocalExecutor, ChunkedData, ChunkRef,
                        FaultInjector, FunctionRegistry, Job, JobGraph,
                        LocalExecutor, VirtualCluster)

ASYNC_MODES = ("pipelined", "dataflow")


def _square_sum_graph():
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def produce(c):
        return c * c

    @reg.whole(2)
    def consume(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("P", 1, 0, no_send_back=True)])
    g.add_segment([Job("Q", 2, 1, (ChunkRef("P"),))])
    g.bind_input("P", np.arange(6, dtype=np.float32), n_chunks=3)
    return g, reg


@pytest.mark.parametrize("mode", ASYNC_MODES)
def test_lost_no_send_back_recovered_mid_run(mode):
    g, reg = _square_sum_graph()
    inj = FaultInjector().kill_after_jobs(worker=0, n=1)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=3),
                            reg, inj, mode=mode)
    res, rep = ex.run(g)
    assert rep.recovered_jobs == ["P"], rep.recovered_jobs
    assert inj.killed == [0]
    assert float(res["Q"].to_array()) == pytest.approx(
        float((np.arange(6) ** 2).sum()))


@pytest.mark.parametrize("mode", ASYNC_MODES)
def test_sent_back_results_survive_async_worker_loss(mode):
    """Default (sent-back) results live on the scheduler: a worker death
    must not trigger any recovery in the async paths either."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def f(c):
        return c + 1

    @reg.whole(2)
    def total(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("P", 1, 0)])          # send back (default)
    g.add_segment([Job("Q", 2, 1, (ChunkRef("P"),))])
    g.bind_input("P", np.zeros(4, np.float32), n_chunks=2)
    inj = FaultInjector().kill_after_jobs(worker=0, n=1)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                            reg, inj, mode=mode)
    res, rep = ex.run(g)
    assert rep.recovered_jobs == []
    assert float(res["Q"].to_array()) == pytest.approx(4.0)


@pytest.mark.parametrize("mode", ASYNC_MODES)
def test_mid_segment_kill_multi_worker_chain(mode):
    """Kill a worker between segments of a wide multi-segment chain: every
    retained shard it held must be recovered exactly once and the final
    reduction must be exact."""
    width, depth = 3, 4
    reg = FunctionRegistry()

    @reg.chunkwise("inc")
    def inc(c):
        return c + 1.0

    @reg.whole("sum")
    def total(*cds):
        return ChunkedData.from_arrays(
            [sum(jnp.sum(a) for cd in cds for a in cd.arrays())])

    g = JobGraph()
    for k in range(depth):
        jobs = []
        for i in range(width):
            deps = (ChunkRef(f"J{k - 1}_{i}"),) if k else ()
            jobs.append(Job(f"J{k}_{i}", "inc", 1, deps, no_send_back=True))
        g.add_segment(jobs)
        if k == 0:
            for i, j in enumerate(jobs):
                g.bind_input(j.name, np.full(4, float(i), np.float32), n_chunks=2)
    g.add_segment([Job("OUT", "sum", 1,
                       tuple(ChunkRef(f"J{depth - 1}_{i}")
                             for i in range(width)))])

    inj = FaultInjector().kill_before_segment(worker=1, segment=2)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=width),
                            reg, inj, mode=mode)
    res, rep = ex.run(g)
    # exact expected value: chunk i starts at i, +1 per segment
    expected = sum(4 * (i + depth) for i in range(width))
    assert float(res["OUT"].to_array()) == pytest.approx(expected)
    assert inj.killed == [1]
    # accounting: recovered jobs are real graph jobs, no duplicates
    rec = rep.recovered_jobs
    assert len(rec) == len(set(rec))
    assert all(name in g.names() for name in rec)


@pytest.mark.parametrize("mode", ASYNC_MODES)
def test_recovery_is_recursive_through_lineage(mode):
    """A lost result whose producer's own input was also lost re-executes
    the full lineage (paper §3.1's recompute cost, recursively)."""
    reg = FunctionRegistry()

    @reg.chunkwise("a")
    def a(c):
        return c * 2

    @reg.chunkwise("b")
    def b(c):
        return c + 10

    @reg.whole("out")
    def out(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(x) for x in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("A", "a", 1, no_send_back=True)])
    g.add_segment([Job("B", "b", 1, (ChunkRef("A"),), no_send_back=True)])
    g.add_segment([Job("OUT", "out", 1, (ChunkRef("B"),))])
    g.bind_input("A", np.arange(4, dtype=np.float32), n_chunks=2)

    # single worker holds both retained results; kill it before the last
    # segment so BOTH must re-execute (A first, then B through lineage)
    inj = FaultInjector().kill_before_segment(worker=0, segment=2)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=1),
                            reg, inj, mode=mode)
    res, rep = ex.run(g)
    assert float(res["OUT"].to_array()) == pytest.approx(
        float((np.arange(4) * 2 + 10).sum()))
    assert sorted(set(rep.recovered_jobs)) == ["A", "B"]


def test_dataflow_kill_before_segment_with_dynamic_jobs():
    """Kill-before-segment under ``mode="dataflow"`` where the target
    segment's jobs were added *dynamically* by a control job: the kill lands
    between dataflow frontier waves (no segment barrier exists to hide
    behind) and the retained shard must be recovered for the dynamic
    consumers."""
    from repro.core import ControlContext, FunctionKind

    reg = FunctionRegistry()

    @reg.chunkwise("sq")
    def sq(c):
        return c * c

    def plan(cd, ctx):
        # enqueue one consumer per retained chunk into the NEXT segment
        for i in range(2):
            ctx.add_job(Job(f"DYN{i}", "sq", 1, (ChunkRef("P"),),
                            no_send_back=True), 1)
        return ChunkedData.from_arrays([np.zeros(1, np.float32)])

    reg.register("plan", plan, kind=FunctionKind.CONTROL)

    g = JobGraph()
    g.add_segment([Job("P", "sq", 1, no_send_back=True)])
    g.add_segment([Job("C", "plan", 1, (ChunkRef("P"),))])
    g.bind_input("P", np.arange(4, dtype=np.float32), n_chunks=2)

    inj = FaultInjector().kill_before_segment(worker=0, segment=2)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                            reg, inj, mode="dataflow")
    res, rep = ex.run(g)
    assert inj.killed == [0]
    assert "P" in rep.recovered_jobs
    expected = float((np.arange(4, dtype=np.float32) ** 4).sum())
    for i in range(2):
        got = float(np.asarray(res[f"DYN{i}"].to_array()).sum())
        assert got == pytest.approx(expected), f"DYN{i}"


def test_maybe_kill_targets_wid_not_list_index():
    """After the worker list and wids diverge (a dead worker reaped from
    the list), a fault plan for wid=1 must kill worker 1 — not whatever
    happens to sit at index 1."""
    from repro.core import FaultInjector, ResultStore

    cluster = VirtualCluster(n_schedulers=1, max_workers=3)
    w0 = cluster.spawn_worker()
    w1 = cluster.spawn_worker()
    w2 = cluster.spawn_worker()
    cluster.workers.remove(w0)          # list index 1 now holds wid 2
    store = ResultStore(cluster)
    inj = FaultInjector().kill_after_jobs(worker=1, n=0)
    inj.maybe_kill(cluster, store)
    assert inj.killed == [1]
    assert not w1.alive
    assert w2.alive


def test_heartbeat_replacement_worker_gets_registration_grace():
    """A worker spawned after ``max_missed`` silent rounds must not be
    reaped on the very next tick before it ran a single job."""
    from repro.core import Heartbeat, ResultStore

    cluster = VirtualCluster(n_schedulers=1, max_workers=2)
    w0 = cluster.spawn_worker()
    store = ResultStore(cluster)
    hb = Heartbeat(cluster, max_missed=2)
    hb.beat(w0.wid)
    for _ in range(4):
        hb.tick(store)
    assert not w0.alive                  # silent original: reaped
    repl = cluster.spawn_worker()
    hb.register(repl.wid)
    hb.tick(store)                       # previously killed repl here
    assert repl.alive
    # silence *after* registration still reaps it eventually
    for _ in range(3):
        hb.tick(store)
    assert not repl.alive


def test_async_report_matches_sync_recovery_accounting():
    """Same fault plan, same graph: the async modes must report the same
    recovered set as the sync baseline."""
    recs = {}
    for mode in ("sync",) + ASYNC_MODES:
        g, reg = _square_sum_graph()
        inj = FaultInjector().kill_after_jobs(worker=0, n=1)
        ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=3),
                                reg, inj, mode=mode)
        _, rep = ex.run(g)
        recs[mode] = sorted(rep.recovered_jobs)
    assert recs["pipelined"] == recs["sync"]
    assert recs["dataflow"] == recs["sync"]
