"""Serving-path tests across cache-bearing families: batched prefill parity,
SSM prefill→decode continuation, continuous-batching slot insertion."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.serve import Engine, SamplingParams


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m", "zamba2-1.2b",
                                  "gemma3-4b", "mixtral-8x7b"])
def test_batched_prefill_then_decode_matches_forward(arch):
    """prefill(prompt) + decode(next) must equal forward(prompt+next)."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 17   # prompt length deliberately not a chunk/tile multiple
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, B, S + 8)
    # batched prefill over the prompt
    logits_p, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, toks[:, :S])
    # one decode step
    logits_d, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, toks[:, S:S + 1])
    full, _ = jax.jit(lambda p, t: forward(cfg, p, tokens=t))(params, toks)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full[:, S - 1]), atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full[:, S]), atol=3e-2, rtol=3e-2)


def test_slot_insertion_preserves_other_slots():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0, cfg.vocab_size)
    eng = Engine(cfg, params, batch=3, max_len=32, donate_cache=False)
    eng.prefill(toks)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), eng.cache)
    new_prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                    cfg.vocab_size)
    eng.insert(1, new_prompt)
    after = eng.cache
    # slot 1 changed, slots 0 and 2 untouched
    changed = unchanged = 0
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        a = np.asarray(a)
        if b.shape != a.shape or b.ndim < 2:
            continue
        # leaves are (G, B, ...) group-stacked
        if b.shape[1] == 3:
            if not np.array_equal(b[:, 1], a[:, 1]):
                changed += 1
            assert np.array_equal(b[:, 0], a[:, 0])
            assert np.array_equal(b[:, 2], a[:, 2])
            unchanged += 1
    assert changed >= 1 and unchanged >= 1


def test_temperature_sampling_draws_valid_tokens():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 6), 0, cfg.vocab_size)
    eng = Engine(cfg, params, batch=2, max_len=32)
    out = eng.generate(toks, max_new=6,
                       sp=SamplingParams(temperature=0.8, top_k=16),
                       key=jax.random.PRNGKey(7))
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
