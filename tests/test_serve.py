"""Serving-path tests across cache-bearing families: batched prefill parity,
SSM prefill→decode continuation, continuous-batching slot insertion (compile
count, per-slot positions, encdec enc_out splice, output equality)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.serve import Engine, SamplingParams, count_generated


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


# the heaviest cross-arch parity cases are tier-2 (`pytest -m slow`); qwen2
# (dense+KV) and mamba2 (SSM) keep the fast suite covering both cache kinds
@pytest.mark.parametrize("arch", [
    "qwen2-1.5b", "mamba2-370m",
    pytest.param("zamba2-1.2b", marks=pytest.mark.slow),
    pytest.param("gemma3-4b", marks=pytest.mark.slow),
    pytest.param("mixtral-8x7b", marks=pytest.mark.slow),
])
def test_batched_prefill_then_decode_matches_forward(arch):
    """prefill(prompt) + decode(next) must equal forward(prompt+next)."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 17   # prompt length deliberately not a chunk/tile multiple
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, B, S + 8)
    # batched prefill over the prompt
    logits_p, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, toks[:, :S])
    # one decode step
    logits_d, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, toks[:, S:S + 1])
    full, _ = jax.jit(lambda p, t: forward(cfg, p, tokens=t))(params, toks)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full[:, S - 1]), atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full[:, S]), atol=3e-2, rtol=3e-2)


def test_slot_insertion_preserves_other_slots():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0, cfg.vocab_size)
    eng = Engine(cfg, params, batch=3, max_len=32, donate_cache=False)
    eng.prefill(toks)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), eng.cache)
    new_prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                    cfg.vocab_size)
    eng.insert(1, new_prompt)
    after = eng.cache
    # slot 1 changed, slots 0 and 2 untouched
    changed = unchanged = 0
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        a = np.asarray(a)
        if b.shape != a.shape or b.ndim < 2:
            continue
        # leaves are (G, B, ...) group-stacked
        if b.shape[1] == 3:
            if not np.array_equal(b[:, 1], a[:, 1]):
                changed += 1
            assert np.array_equal(b[:, 0], a[:, 0])
            assert np.array_equal(b[:, 2], a[:, 2])
            unchanged += 1
    assert changed >= 1 and unchanged >= 1


def test_insert_compiles_once():
    """The headline bugfix: N inserts must reuse one cached slot-prefill
    program (the old code built a fresh Engine — two jax.jits — per
    request)."""
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch=3, max_len=48)
    eng.prefill(jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                   cfg.vocab_size))
    assert eng.trace_count("prefill") == 1        # the (3, 8) signature
    for i in range(4):
        prompt = jax.random.randint(jax.random.PRNGKey(10 + i), (1, 8), 0,
                                    cfg.vocab_size)
        eng.insert(i % 3, prompt, true_len=5 + i % 3)
    # 4 inserts -> exactly ONE extra prefill trace (the (1, 8) slot
    # signature) and ONE splice trace; varying slot and true_len must not
    # retrigger compilation (they are traced scalars, not static)
    assert eng.trace_count("prefill") == 2
    assert eng.trace_count("splice") == 1
    assert eng.trace_count("decode") == 0


def test_insert_returns_true_last_token_logits():
    """Bucketed (right-padded) prompts must sample from the true last
    prompt token, not the pad tail."""
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch=2, max_len=48)
    eng.prefill(jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                   cfg.vocab_size))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (1, 5), 0,
                                           cfg.vocab_size), np.int32)
    padded = np.zeros((1, 8), np.int32)
    padded[0, :5] = prompt[0]
    lg_padded = eng.insert(0, jnp.asarray(padded), true_len=5)
    # reference: a batch=1 engine prefilled with the unpadded prompt
    ref = Engine(cfg, params, batch=1, max_len=48)
    lg_ref = ref.prefill(jnp.asarray(prompt))
    np.testing.assert_allclose(np.asarray(lg_padded), np.asarray(lg_ref),
                               atol=1e-4, rtol=1e-4)


def test_insert_splices_enc_out_for_encdec():
    """The old insert silently dropped the mini-engine's enc_out, so an
    inserted request decoded against the previous batch's encoder output."""
    cfg = _fp32(get_smoke_config("whisper-base"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    enc_len = 8
    enc = jnp.asarray(rng.standard_normal((2, enc_len, cfg.d_model),
                                          dtype=np.float32))
    eng = Engine(cfg, params, batch=2, max_len=32, donate_cache=False)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    eng.prefill(toks, enc_embeds=enc)
    enc_before = np.asarray(eng._enc_out).copy()

    new_enc = jnp.asarray(rng.standard_normal((1, enc_len, cfg.d_model),
                                              dtype=np.float32))
    new_prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                                    cfg.vocab_size)
    eng.insert(1, new_prompt, enc_embeds=new_enc)
    enc_after = np.asarray(eng._enc_out)
    assert np.array_equal(enc_after[0], enc_before[0])      # slot 0 untouched
    assert not np.array_equal(enc_after[1], enc_before[1])  # slot 1 spliced

    # the spliced row must equal a standalone encode of the new input
    ref = Engine(cfg, params, batch=1, max_len=32)
    ref.prefill(new_prompt, enc_embeds=new_enc)
    np.testing.assert_allclose(enc_after[1], np.asarray(ref._enc_out)[0],
                               atol=1e-5, rtol=1e-5)

    # insert without enc_embeds must fail loudly, not decode against stale
    # encoder state
    with pytest.raises(ValueError, match="enc_embeds"):
        eng.insert(0, new_prompt)


def test_continuous_batching_preserves_surviving_outputs():
    """Fill all slots, let one finish, insert a new request into the freed
    slot — the surviving slots' generated tokens must be bit-identical to an
    uninterrupted run (extends the cache-equality test to output
    equality)."""
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, steps = 3, 8, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab_size)

    def greedy_ids(logits):
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                          np.int32)

    def run(insert_at: int | None):
        eng = Engine(cfg, params, batch=B, max_len=64)
        logits = eng.prefill(prompts)
        toks = greedy_ids(logits)
        outs = [toks]
        for i in range(steps):
            if insert_at is not None and i == insert_at:
                # slot 1 "finished": a new request takes its place
                new_prompt = jax.random.randint(jax.random.PRNGKey(9), (1, 8),
                                                0, cfg.vocab_size)
                lg = eng.insert(1, new_prompt, true_len=5)
                toks = toks.copy()
                toks[1] = greedy_ids(lg)[0]
            logits = eng.decode(jnp.asarray(toks)[:, None])
            toks = greedy_ids(logits)
            outs.append(toks)
        return np.stack(outs, axis=1)   # (B, steps+1)

    base = run(insert_at=None)
    mixed = run(insert_at=3)
    # slots 0 and 2 never noticed the insertion
    assert np.array_equal(base[0], mixed[0])
    assert np.array_equal(base[2], mixed[2])
    # slot 1 did (new request from step 3 on)
    assert not np.array_equal(base[1], mixed[1])


def test_inserted_request_decodes_at_its_own_position():
    """A short prompt inserted into a batch that has decoded far ahead must
    produce the same tokens as a standalone run of that prompt — i.e. its
    per-slot cache length (not the global one) drives positions, masking
    and cache writes."""
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 3
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0,
                                 cfg.vocab_size)
    short = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 0,
                               cfg.vocab_size)

    eng = Engine(cfg, params, batch=B, max_len=64)
    logits = eng.prefill(prompts)
    toks = np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)
    for _ in range(6):      # decode ahead: global position now 12 + 6
        logits = eng.decode(jnp.asarray(toks)[:, None])
        toks = np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)
    lg = eng.insert(0, short)           # slot 0: fresh 5-token request
    toks = toks.copy()
    toks[0] = int(jnp.argmax(lg[0, -1]))
    got = [toks[0]]
    for _ in range(5):
        logits = eng.decode(jnp.asarray(toks)[:, None])
        toks = np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)
        got.append(int(toks[0]))

    # reference: the short prompt alone in a same-shaped engine (row-wise
    # computation is batch-independent, so tokens must match exactly)
    ref = Engine(cfg, params, batch=B, max_len=64)
    ref_logits = ref.prefill(jnp.tile(short, (B, 1)))
    rt = np.asarray(jnp.argmax(ref_logits[:, -1, :], -1), np.int32)
    want = [int(rt[0])]
    for _ in range(5):
        ref_logits = ref.decode(jnp.asarray(rt)[:, None])
        rt = np.asarray(jnp.argmax(ref_logits[:, -1, :], -1), np.int32)
        want.append(int(rt[0]))
    assert got == want


def test_temperature_sampling_draws_valid_tokens():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 6), 0, cfg.vocab_size)
    eng = Engine(cfg, params, batch=2, max_len=32)
    out = eng.generate(toks, max_new=6,
                       sp=SamplingParams(temperature=0.8, top_k=16),
                       key=jax.random.PRNGKey(7))
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_count_generated_excludes_stop_padding():
    out = np.array([[5, 7, 2, 2, 2],      # stopped at token 3 (stop id 2)
                    [1, 3, 4, 6, 8]])     # never stopped
    assert count_generated(out, stop_token=2) == 3 + 5
    assert count_generated(out, stop_token=-1) == 10
    assert count_generated(np.array([[2, 2, 2]]), stop_token=2) == 1
