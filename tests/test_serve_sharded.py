"""Multi-device serving (DESIGN.md §13): mesh spec parsing, single-device
equivalence of the mesh code path, device-group slot/page partitioning and
cost-model routing, and — in a forced-2-device subprocess — TP/DP parity
with the single-device engine for an attention and an SSM model."""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve import PagedEngine, SamplingParams, ServeScheduler
from repro.serve.mesh import MeshSpec, build_serve_mesh

DRIVER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "_sharded_driver.py")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.fixture(scope="module")
def qwen():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Mesh spec parsing
# ---------------------------------------------------------------------------


def test_mesh_spec_parse():
    assert MeshSpec.parse("2,1") == MeshSpec(tp=2, dp=1)
    assert MeshSpec.parse(" 1 , 2 ") == MeshSpec(tp=1, dp=2)
    assert MeshSpec.parse("1,1").size == 1
    for bad in ("2", "2,2,2", "a,b", "0,1", "1,-1"):
        with pytest.raises(ValueError):
            MeshSpec.parse(bad)


def test_build_mesh_rejects_oversized():
    # the main pytest process has one CPU device; a 2-device mesh must fail
    # loudly with the XLA_FLAGS hint, not sharded-place onto nothing
    if len(jax.devices()) > 1:
        pytest.skip("test wants a single-device process")
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        build_serve_mesh(MeshSpec(tp=2, dp=1))


# ---------------------------------------------------------------------------
# Single-device equivalence: mesh of size 1 == no mesh, bit-identical
# ---------------------------------------------------------------------------


def test_mesh_of_one_is_bit_identical(qwen):
    cfg, params = qwen

    def drive(mesh):
        eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                          num_pages=25, prefill_chunk=16, mesh=mesh)
        rng = np.random.default_rng(3)
        prompt = rng.integers(1, 50, 12).astype(np.int32)
        pages = list(range(1, 1 + eng.pages_needed(12, 4)))
        logits = [np.asarray(eng.insert(0, prompt, page_ids=pages,
                                        max_new=4))]
        tok = np.argmax(logits[-1][0])
        for _ in range(4):
            step = np.full((eng.batch, 1), int(tok), np.int32)
            out = np.asarray(eng.decode(step,
                                        live_mask=np.array([True, False])))
            logits.append(out)
            tok = np.argmax(out[0])
        return logits

    ref = drive(None)
    mesh1 = drive(build_serve_mesh(MeshSpec(tp=1, dp=1)))
    for a, b in zip(ref, mesh1):
        np.testing.assert_array_equal(a, b)


def test_mesh_of_one_pool_bytes_equal(qwen):
    cfg, params = qwen
    mk = lambda m: PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                               num_pages=25, prefill_chunk=16, mesh=m)
    assert (mk(None).per_device_pool_bytes()
            == mk(build_serve_mesh(MeshSpec(1, 1))).per_device_pool_bytes())


# ---------------------------------------------------------------------------
# Device groups: partitioning, routing, compat accessors (no mesh needed —
# group ownership is host-side scheduler state)
# ---------------------------------------------------------------------------


def test_device_group_partitioning(qwen):
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=5, max_len=64, page_size=8,
                      num_pages=30, prefill_chunk=16)
    sched = ServeScheduler(eng, sp=SamplingParams(), reserve="demand",
                           device_groups=2)
    g0, g1 = sched.groups
    # contiguous, disjoint, covering: slots and the usable page range
    assert g0.slot_ids + g1.slot_ids == tuple(range(5))
    assert g0.page_lo == 1 and g1.page_hi == 30
    assert g0.page_hi == g1.page_lo
    # per-group conservation is the single-allocator invariant
    for g in (g0, g1):
        a = g.allocator
        assert a.n_free == a.num_pages - a.n_reserved
    # the pre-§13 single-allocator accessors refuse to guess a group
    with pytest.raises(RuntimeError, match="groups"):
        sched.allocator
    with pytest.raises(RuntimeError, match="groups"):
        sched.prefix


def test_device_groups_validation(qwen):
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                      num_pages=25, prefill_chunk=16)
    with pytest.raises(ValueError, match="batch slots"):
        ServeScheduler(eng, sp=SamplingParams(), device_groups=3)
    with pytest.raises(ValueError, match=">= 1"):
        ServeScheduler(eng, sp=SamplingParams(), device_groups=0)


def test_routing_balances_groups_and_isolates_pages(qwen):
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=4, max_len=64, page_size=8,
                      num_pages=33, prefill_chunk=16)
    sched = ServeScheduler(eng, sp=SamplingParams(), reserve="demand",
                           admit_watermark=1, device_groups=2)
    rng = np.random.default_rng(11)
    for _ in range(8):
        sched.submit(rng.integers(1, 50, 12).astype(np.int32), 6)
    results = sched.run()
    assert len(results) == 8
    # cost-model routing spread work over BOTH groups
    occ = sched.group_occupancy
    assert len(occ) == 2 and all(o > 0.0 for o in occ), occ
    # and nothing crossed a group boundary or leaked
    for g in sched.groups:
        assert g.allocator.n_outstanding == 0
        assert g.allocator.n_free == (g.allocator.num_pages
                                      - g.allocator.n_reserved)


def test_group_local_preemption(qwen):
    # pool small enough that decode appends exhaust a group: preemption
    # must pick a victim from the SAME group and the run still completes
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=4, max_len=64, page_size=8,
                      num_pages=15, prefill_chunk=16)
    sched = ServeScheduler(eng, sp=SamplingParams(), reserve="demand",
                           admit_watermark=1, device_groups=2)
    rng = np.random.default_rng(5)
    for _ in range(6):
        sched.submit(rng.integers(1, 50, 10).astype(np.int32), 12)
    results = sched.run()
    assert len(results) == 6
    for g in sched.groups:
        assert g.allocator.n_outstanding == 0


# ---------------------------------------------------------------------------
# Forced-2-device parity (subprocess): attention + SSM models
# ---------------------------------------------------------------------------


def test_sharded_decode_matches_single_device():
    r = subprocess.run(
        [sys.executable, DRIVER, "qwen2-1.5b", "mamba2-370m"],
        capture_output=True, text=True, cwd=REPO, timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "JAX_PLATFORMS": "cpu"})
    assert "SHARDED_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
