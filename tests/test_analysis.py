"""HLO analyzer validation: parser vs XLA cost_analysis, scan correction,
trip-count parsing, collective accounting (multi-device cases run in a
subprocess so the main pytest process keeps 1 device)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo, xla_cost_analysis
from repro.analysis.roofline import V5E, RooflineTerms, roofline_from_compiled


def test_unrolled_dot_flops_match_cost_analysis():
    W = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128,), jnp.float32)

    def f(ws, x):
        for i in range(4):
            x = jnp.tanh(ws[i] @ x)
        return x

    c = jax.jit(f).lower(W, x).compile()
    a = analyze_hlo(c.as_text())
    assert a.flops == pytest.approx(xla_cost_analysis(c)["flops"], rel=1e-6)
    assert a.flops == pytest.approx(4 * 2 * 128 * 128, rel=1e-6)


def test_scan_trip_multiplier():
    L = 12
    W = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64,), jnp.float32)

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(w @ c), None
        return jax.lax.scan(body, x, ws)[0]

    c = jax.jit(f).lower(W, x).compile()
    a = analyze_hlo(c.as_text())
    assert list(a.while_trips.values()) == [L]
    assert a.flops == pytest.approx(L * 2 * 64 * 64, rel=1e-6)
    # XLA's own analysis counts the body once — the discrepancy this module
    # exists to fix
    assert xla_cost_analysis(c)["flops"] < a.flops / 2


def test_nested_scan_multipliers():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    a = analyze_hlo(c.as_text())
    assert a.flops == pytest.approx(5 * 3 * 2 * 32 ** 3, rel=1e-6)


def test_trip_override():
    def f(ws, x):
        def body(c, w):
            return w @ c, None
        return jax.lax.scan(body, x, ws)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((6, 32, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32,), jnp.float32)).compile()
    a6 = analyze_hlo(c.as_text())
    body = list(a6.while_trips)[0]
    a2 = analyze_hlo(c.as_text(), trip_overrides={body: 2})
    assert a2.flops == pytest.approx(a6.flops / 3, rel=1e-6)


def test_traffic_scan_consistent_with_unrolled():
    L = 8

    def scan_f(ws, x):
        def body(c, w):
            return jnp.tanh(w @ c), None
        return jax.lax.scan(body, x, ws)[0]

    def unroll_f(ws, x):
        for i in range(L):
            x = jnp.tanh(ws[i] @ x)
        return x

    W = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128,), jnp.float32)
    a_s = analyze_hlo(jax.jit(scan_f).lower(W, x).compile().as_text())
    a_u = analyze_hlo(jax.jit(unroll_f).lower(W, x).compile().as_text())
    assert a_s.traffic_bytes == pytest.approx(a_u.traffic_bytes, rel=0.25)


def test_roofline_terms_and_dominance():
    t = RooflineTerms(compute_s=1e-3, memory_s=5e-3, collective_s=2e-3,
                      flops=1.0, traffic_bytes=1.0, collective_bytes=1.0,
                      model_flops=100.0)
    assert t.dominant == "memory"
    assert t.step_s == 5e-3


MULTIDEV_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo import analyze_hlo, xla_cost_analysis
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("d",))
f = jax.jit(lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P(None, "d")),
                          NamedSharding(mesh, P("d", None))),
            out_shardings=NamedSharding(mesh, P()))
c = f.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
a = analyze_hlo(c.as_text())
# per-device partial matmul: 2*256*256*(256/8)
assert abs(a.flops - 2 * 256 * 256 * 32) / a.flops < 1e-6, a.flops
assert a.collectives.counts["all-reduce"] == 1, a.collectives.counts
# ring all-reduce bytes ~ 2 x buffer
assert abs(a.collectives.total_bytes - 2 * 256 * 256 * 4) < 1e3
print("MULTIDEV_OK")
"""


def test_collective_accounting_multidevice():
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd=".")
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
