"""Prefix caching + copy-on-write page sharing (DESIGN.md §11), and the
allocator/engine safety fixes that ride along:

* ``PageAllocator`` refcounts: writable iff refcount==1, ``share``/``free``
  reference lifecycle, and batch-validated ``free`` (an invalid batch
  leaves the allocator UNTOUCHED instead of half-freed),
* ``PagedEngine.commit_slot`` / ``append_page`` fail-fast validation
  (zero id mid-row, out-of-range ids, over-long rows),
* ``chunk_plan(start=)`` suffix property — the bit-exactness contract
  chunk-floored sharing relies on,
* ``PrefixCache`` chain semantics: lookup/insert, deepest-first eviction,
  refcount protection, flush,
* end-to-end: a cache-hit admission bit-matches the no-cache run, and a
  forged shared page on the decode write path triggers COW (copy + remap)
  without changing the generated tokens.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve import (PageAllocator, PagedEngine, PrefixCache,
                         ServeScheduler, chunk_buckets_for, chunk_plan)


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              compute_dtype="float32")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engine(qwen):
    cfg, params = qwen
    return PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                       prefill_chunk=16)


def _fresh(eng):
    eng.page_table[:] = 0
    eng._pt_device = None
    return eng


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)


# ---------------------------------------------------------------------------
# Allocator refcounts (no jax)
# ---------------------------------------------------------------------------


def test_allocator_share_refcount_and_writable():
    a = PageAllocator(8)
    [p, q] = a.alloc(2)
    assert a.refcount(p) == 1 and a.writable(p)
    a.share([p])
    assert a.refcount(p) == 2 and not a.writable(p)
    assert a.writable(q)                     # unshared page unaffected
    a.free([p])                              # drops ONE reference
    assert a.refcount(p) == 1 and a.writable(p)
    assert p in a.outstanding                # still held -> not recycled
    a.free([p, q])
    assert a.refcount(p) == 0 and a.n_outstanding == 0
    with pytest.raises(ValueError):          # sharing a free page
        a.share([p])
    assert a.refcount(3) == 0 and not a.writable(0)


def test_allocator_free_validates_whole_batch_before_mutating():
    """A bad batch (double free / foreign page) must leave the allocator
    EXACTLY as it was — the old implementation freed the leading pages
    before raising mid-loop, breaking conservation for the rest of the
    run."""
    a = PageAllocator(8)
    pages = a.alloc(4)
    free_before, out_before = a.n_free, set(a.outstanding)
    with pytest.raises(ValueError):
        a.free([pages[0], pages[1], 99])     # foreign page last
    assert a.n_free == free_before
    assert set(a.outstanding) == out_before
    assert all(a.refcount(p) == 1 for p in pages)
    # over-free within one batch: page listed twice but refcount 1
    with pytest.raises(ValueError):
        a.free([pages[0], pages[0]])
    assert a.refcount(pages[0]) == 1
    # ...but two frees of a DOUBLY-referenced page in one batch are fine
    a.share([pages[0]])
    a.free([pages[0], pages[0]])
    assert a.refcount(pages[0]) == 0
    a.free(pages[1:])
    assert a.n_outstanding == 0 and a.n_free == 7


# ---------------------------------------------------------------------------
# Engine validation + chunk-plan suffix property
# ---------------------------------------------------------------------------


def test_commit_slot_rejects_zero_mid_row_and_overlong(engine):
    eng = _fresh(engine)
    eng.ensure_batch()
    with pytest.raises(ValueError):          # zero id would truncate the
        eng.commit_slot(0, [1, 0, 2])        # nonzero prefix appends scan
    with pytest.raises(ValueError):          # out of range
        eng.commit_slot(0, [1, eng.num_pages])
    with pytest.raises(ValueError):          # over-long row
        eng.commit_slot(0, list(range(1, eng.max_pages + 2)))
    assert (eng.page_table[0] == 0).all()    # nothing installed
    eng.commit_slot(0, [1, 2])
    assert eng.page_table[0, :2].tolist() == [1, 2]
    eng.free_slot(0)


def test_append_page_bounds_checks_pool_size(engine):
    eng = _fresh(engine)
    eng.ensure_batch()
    eng.commit_slot(0, [1])
    with pytest.raises(ValueError):
        eng.append_page(0, eng.num_pages)    # foreign id: device pool OOB
    with pytest.raises(ValueError):
        eng.append_page(0, 0)
    eng.append_page(0, 2)
    assert eng.page_table[0, :2].tolist() == [1, 2]
    eng.free_slot(0)


def test_chunk_plan_start_is_suffix_of_full_plan():
    buckets = chunk_buckets_for(16, 8)
    for true_len in (17, 33, 40, 48, 61):
        full = chunk_plan(true_len, 16, buckets)
        for k in range(1, len(full)):
            start = full[k][0]
            assert chunk_plan(true_len, 16, buckets, start=start) == full[k:]
    with pytest.raises(ValueError):          # non-chunk-aligned start
        chunk_plan(40, 16, buckets, start=8)
    with pytest.raises(ValueError):          # start past the stream
        chunk_plan(16, 16, buckets, start=16)


# ---------------------------------------------------------------------------
# PrefixCache chain semantics (no jax)
# ---------------------------------------------------------------------------


def test_prefix_cache_lookup_insert_chain():
    a = PageAllocator(16)
    pc = PrefixCache(page_size=4)
    toks = np.arange(11, dtype=np.int32)     # 2 full pages + tail
    pages = a.alloc(3)
    assert pc.insert(toks, pages, a) == 2    # only FULL pages cached
    assert all(a.refcount(p) == 2 for p in pages[:2])
    assert a.refcount(pages[2]) == 1
    assert pc.lookup(toks) == pages[:2]
    # longer stream sharing the 2-page prefix: chain stops at the break
    longer = np.concatenate([toks[:8], np.full(8, 7, np.int32)])
    assert pc.lookup(longer) == pages[:2]
    # different FIRST page: no hit at all (keys chain through the prefix)
    other = np.concatenate([np.full(4, 9, np.int32), toks[4:]])
    assert pc.lookup(other) == []
    # re-insert under the same keys keeps the original pages (no steal)
    dup = a.alloc(2)
    assert pc.insert(toks[:8], dup, a) == 0
    assert pc.lookup(toks) == pages[:2]
    a.free(dup)
    pc.flush(a)
    a.free(pages)
    assert a.n_outstanding == 0


def test_prefix_cache_eviction_deepest_first_and_refcount_guard():
    a = PageAllocator(8)                     # 7 usable
    pc = PrefixCache(page_size=4)
    toks = np.arange(12, dtype=np.int32)     # 3 full pages
    pages = a.alloc(3)
    pc.insert(toks, pages, a)
    a.free(pages)                            # cache is now the only holder
    assert set(a.outstanding) == set(pages) and len(pc) == 3
    # a slot still maps the depth-2 page: it must survive eviction
    a.share([pages[1]])
    freed = pc.evict_for(a, a.n_free + 3)
    # deepest-first: page 3 then page 1 freed; page 2 protected (refcount 2)
    assert freed == 2
    assert set(a.outstanding) == {pages[1]}
    assert len(pc) == 1 and pc.pages() == {pages[1]}
    a.free([pages[1]])                       # the "slot's" ref
    assert pc.flush(a) == 1
    assert a.n_outstanding == 0 and a.n_free == 7


# ---------------------------------------------------------------------------
# End-to-end: hit bit-match + forged-sharing COW
# ---------------------------------------------------------------------------


def test_cache_hit_admission_bitmatches_no_cache_run(qwen):
    """Sequential identical-prefix requests on a batch=1 engine: the first
    populates the cache, the second admits onto shared pages and prefills
    only the tail chunk — its tokens must bit-match the cache-off run.
    Covers the aligned-prompt case too (prompt = whole chunks): the floor
    keeps the final chunk unshared so its logits are reproduced exactly."""
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=1, max_len=64, page_size=8,
                      prefill_chunk=16)
    rng = np.random.default_rng(3)
    prefix = _prompt(rng, cfg, 24)
    prompts = [np.concatenate([prefix, _prompt(rng, cfg, 7)]),
               np.concatenate([prefix, _prompt(rng, cfg, 9)]),
               np.concatenate([prefix, _prompt(rng, cfg, 8)])]  # aligned: 32

    def run(share):
        sched = ServeScheduler(eng, prefix_cache=share)
        _fresh(eng)
        out = []
        for p in prompts:                    # batch=1 => strictly sequential
            sched.submit(p, max_new=5)
            out.append(sched.run()[-1].tokens)
        if share:
            assert sched.n_prefix_hits >= 2  # requests 2 and 3 hit
            assert sched.pages_shared > 0
            cached = sched.prefix.pages()
            assert set(sched.allocator.outstanding) == cached
            sched.flush_prefix_cache()
        assert sched.allocator.n_outstanding == 0
        return out

    assert run(False) == run(True)


def test_forged_shared_page_triggers_cow_on_decode(qwen):
    """Force the writable-iff-refcount==1 enforcement: mid-decode, take an
    extra reference on the slot's current write page.  The next decode
    step must copy-on-write (fresh page, pool-block copy, table remap) —
    and the generated tokens must be unchanged, which proves the copy
    carries the real K/V bits."""
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=1, max_len=64, page_size=8,
                      prefill_chunk=16)
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, cfg, 12)

    def run(forge):
        sched = ServeScheduler(eng, reserve="demand")
        _fresh(eng)
        sched.submit(prompt, max_new=10)
        forged = []
        while sched.step():
            st = sched.slots[0]
            if forge and st.request is not None and not st.prefilling \
                    and not forged:
                # second holder on EVERY current page: decode must COW the
                # write page before its next in-place KV write
                forged = list(st.page_ids)
                sched.allocator.share(forged)
        [res] = sched.results
        if forge:
            assert sched.n_cow_copies >= 1
            st = sched.slots[0]
            # the forged refs keep the originals outstanding; release them
            assert set(forged) <= set(sched.allocator.outstanding)
            sched.allocator.free(forged)
        assert sched.allocator.n_outstanding == 0
        return res.tokens

    assert run(False) == run(True)


def test_admit_after_inserts_on_second_sight():
    """Insert-on-second-sight gate (ROADMAP 2b): a one-off prompt's first
    sighting takes NO allocator references — only a prefix seen again is
    worth caching."""
    a = PageAllocator(16)
    pc = PrefixCache(page_size=4, admit_after=2)
    toks = np.arange(8, dtype=np.int32)      # 2 full pages
    pages = a.alloc(2)
    # first sight: deferred, host-side count only, no refs taken
    assert pc.insert(toks, pages, a) == 0
    assert pc.n_insert_deferred == 2 and len(pc) == 0
    assert all(a.refcount(p) == 1 for p in pages)
    assert pc.lookup(toks) == []
    # second sight: admitted, one cache ref per entry
    assert pc.insert(toks, pages, a) == 2
    assert all(a.refcount(p) == 2 for p in pages)
    assert pc.lookup(toks) == pages
    assert pc._seen == {}                    # counts retired on admit
    pc.flush(a)
    a.free(pages)
    assert a.n_outstanding == 0


def test_admit_after_broken_chain_defers_children():
    """Once a key in a walk is deferred, deeper keys must defer too even if
    their own sight count qualifies — an entry without its parent would be
    unreachable now and could alias a different page later."""
    a = PageAllocator(16)
    pc = PrefixCache(page_size=4, admit_after=2)
    toks = np.arange(12, dtype=np.int32)     # 3 full pages
    # pre-seed page 2's and 3's counts via a DIFFERENT walk is impossible
    # (keys chain through the prefix), so force the shape directly: admit
    # pages 1-2, then evict page 1 — page 2 survives only while reachable,
    # which deepest-first eviction guarantees; here we test insert instead.
    pages = a.alloc(3)
    pc.insert(toks[:8], pages[:2], a)        # sight 1 of pages 1-2
    pc.insert(toks, pages, a)                # sight 2 of 1-2 (admitted)...
    assert len(pc) == 2                      # ...but page 3 was sight 1
    assert pc.n_insert_deferred == 2 + 1
    pc.insert(toks, pages, a)                # sight 2 of page 3: admitted
    assert len(pc) == 3
    pc.flush(a)
    a.free(pages)
    assert a.n_outstanding == 0


def test_admit_after_flush_clears_sight_counts():
    a = PageAllocator(16)
    pc = PrefixCache(page_size=4, admit_after=2)
    toks = np.arange(4, dtype=np.int32)
    pages = a.alloc(1)
    pc.insert(toks, pages, a)
    assert pc._seen and pc.flush(a) == 0
    assert pc._seen == {}                    # a flush forgets first sights
    pc.insert(toks, pages, a)
    assert len(pc) == 0                      # back to square one
    a.free(pages)


def test_admit_after_validation():
    with pytest.raises(ValueError, match="admit_after"):
        PrefixCache(page_size=4, admit_after=0)


def test_scheduler_prefix_admit_gates_first_sight(qwen):
    """End to end: with ``prefix_admit=2`` the first wave of a repeated
    prefix only counts sightings (``cache_insert_deferred`` stat), later
    waves insert and then hit."""
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=1, max_len=64, page_size=8,
                      prefill_chunk=16)
    rng = np.random.default_rng(6)
    prefix = _prompt(rng, cfg, 16)
    prompts = [np.concatenate([prefix, _prompt(rng, cfg, 7)])
               for _ in range(3)]
    sched = ServeScheduler(eng, prefix_cache=True, prefix_admit=2)
    _fresh(eng)
    out = []
    for p in prompts:
        sched.submit(p, max_new=4)
        out.append(sched.run()[-1].tokens)
    # request 1: first sight (deferred, nothing cached, no lookup hit);
    # request 2: no hit yet but second sight inserts; request 3: hits
    assert sched.n_cache_insert_deferred >= 1
    assert sched.n_prefix_hits == 1
    assert sched.pages_shared > 0
    sched.flush_prefix_cache()
    assert sched.allocator.n_outstanding == 0


def test_prefix_cache_requires_paged_and_gates_ssm(qwen):
    cfg, params = qwen
    from repro.serve import Engine
    dense = Engine(cfg, params, batch=1, max_len=32)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeScheduler(dense, prefix_cache=True)
    mcfg = dataclasses.replace(get_smoke_config("mamba2-370m"),
                               compute_dtype="float32")
    mparams = init_params(mcfg, jax.random.PRNGKey(0))
    meng = PagedEngine(mcfg, mparams, batch=1, max_len=32, page_size=8,
                       prefill_chunk=16)
    assert not meng.supports_prefix_cache    # per-slot SSM state: no pages
    sched = ServeScheduler(meng, prefix_cache=True)
    assert sched.prefix is None              # knob accepted, sharing inert
    assert not sched.prefix_cache_active
