"""Reserve-on-demand paging + vLLM-style preemption (DESIGN.md §10):
prompt-span admission, lazy decode-page appends, victim policy with
anti-thrash/starvation guards, resume-as-chunked-re-prefill bit-match, and
the HyPar preempt/re-place + fail() interactions."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve import (HyParRequestTracker, PageAllocator, PagedEngine,
                         ServeScheduler, chunk_plan)


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.fixture(scope="module")
def qwen():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)


def _reference_tokens(cfg, params, prompts, max_new):
    """Preemption-free single-request runs: one batch=1 paged engine, one
    request at a time — the bit-match oracle for every preemption test."""
    eng = PagedEngine(cfg, params, batch=1, max_len=64, page_size=8,
                      prefill_chunk=16)
    out = []
    for p in prompts:
        sched = ServeScheduler(eng)
        sched.submit(p, max_new=max_new)
        out.append(sched.run()[0].tokens)
    return out


# ---------------------------------------------------------------------------
# Allocator watermark + engine append units
# ---------------------------------------------------------------------------


def test_allocator_watermark_blocks_admissions_not_appends():
    a = PageAllocator(8, watermark=2)       # 7 usable, 2 held back
    assert a.admit(6) is None               # would leave 1 < watermark
    got = a.admit(5)
    assert got is not None and a.n_free == 2
    assert a.admit(1) is None               # admissions stop at watermark
    assert a.alloc(1) is not None           # appends may dip below it
    assert a.n_free == 1


def test_append_page_validation(qwen):
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=2, max_len=32, page_size=8,
                      prefill_chunk=16)
    eng.ensure_batch()
    with pytest.raises(ValueError):         # trash page is never appendable
        eng.append_page(0, 0)
    with pytest.raises(ValueError):         # uncommitted slot has no prefix
        eng.append_page(0, 3)
    eng.commit_slot(0, [1, 2])
    eng.append_page(0, 3)
    assert eng.page_table[0, :3].tolist() == [1, 2, 3]
    eng.append_page(0, 4)
    with pytest.raises(ValueError):         # table width max_pages=4
        eng.append_page(0, 5)


# ---------------------------------------------------------------------------
# Victim policy + guards (host-side units)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def demand_sched(qwen):
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=4, max_len=64, page_size=8,
                      prefill_chunk=16)
    return ServeScheduler(eng, reserve="demand")


def _fake_slot(sched, slot, *, n_tokens, admit_seq, pages, resume_base=0):
    st = sched.slots[slot]
    st.request = object()                   # host-side only: never decoded
    st.tokens = list(range(n_tokens))
    st.admit_seq = admit_seq
    st.page_ids = list(pages)
    # the victim policy counts pages the ALLOCATOR knows as exclusively
    # held (shared pages yield nothing when freed) — register the fake
    # slot's pages as real allocations
    alloc = sched.allocator
    for p in pages:
        if alloc.refcount(p) == 0:
            alloc._free.remove(p)
            alloc._ref[p] = 1
    st.resume_base = resume_base
    st.pending_chunks, st.finished = [], False
    return st


def _clear_slots(sched):
    for st in sched.slots:
        if st.page_ids:
            sched.allocator.free(st.page_ids)
        st.request, st.tokens, st.page_ids = None, [], []
        st.resume_base, st.admit_seq, st.pending_chunks = 0, 0, []


def test_victim_policy_fewest_with_lifo_tiebreak(demand_sched):
    sched = demand_sched
    _clear_slots(sched)
    _fake_slot(sched, 0, n_tokens=5, admit_seq=1, pages=[1])
    _fake_slot(sched, 1, n_tokens=2, admit_seq=2, pages=[2])
    _fake_slot(sched, 2, n_tokens=2, admit_seq=3, pages=[3])
    # fewest generated: slots 1 and 2 tie at 2 tokens; LIFO tiebreak picks
    # the later-admitted slot 2
    assert sched._choose_victim(sched.groups[0]).slot == 2
    sched.preempt_policy = "lifo"
    try:
        # latest admitted outright
        assert sched._choose_victim(sched.groups[0]).slot == 2
        _fake_slot(sched, 0, n_tokens=5, admit_seq=9, pages=[1])
        assert sched._choose_victim(sched.groups[0]).slot == 0
    finally:
        sched.preempt_policy = "fewest"
        _clear_slots(sched)


def test_anti_thrash_guard_requires_covering_victim(demand_sched):
    """Preempting a victim whose pages cannot cover the shortfall is pure
    thrash — the guard must skip it even when it is lowest priority."""
    sched = demand_sched
    _clear_slots(sched)
    _fake_slot(sched, 0, n_tokens=1, admit_seq=1, pages=[1])        # 1 page
    _fake_slot(sched, 1, n_tokens=8, admit_seq=2, pages=[2, 3, 4])  # 3 pages
    assert sched._choose_victim(sched.groups[0], shortfall=2).slot == 1
    assert sched._choose_victim(sched.groups[0], shortfall=4) is None
    _clear_slots(sched)


def test_resume_progress_floor_protects_resumed_slots(demand_sched):
    """A freshly resumed request is not a victim again until it has
    generated resume_floor NEW tokens; with every slot protected the
    chooser returns None and the caller falls back to self-preemption
    (exercised end-to-end by the bitmatch test below)."""
    sched = demand_sched
    _clear_slots(sched)
    floor = sched.resume_floor
    resumed = _fake_slot(sched, 0, n_tokens=3, admit_seq=2, pages=[1],
                         resume_base=3)        # 0 new tokens since resume
    fresh = _fake_slot(sched, 1, n_tokens=3 + floor, admit_seq=1, pages=[2])
    assert sched._choose_victim(sched.groups[0]) is fresh     # resumed slot is protected
    resumed.tokens = list(range(3 + floor))    # floor reached: eligible,
    # and the token-count tie breaks LIFO to the later-admitted slot 0
    assert sched._choose_victim(sched.groups[0]) is resumed
    fresh.request = None
    resumed.tokens = list(range(3))            # protected again
    assert sched._choose_victim(sched.groups[0]) is None
    _clear_slots(sched)


def test_watermark_rejected_outside_demand_mode(qwen):
    """Lifetime reservation has no decode appends, so a watermark there
    would let _fits admit requests admit() can never serve — a livelock.
    The combination is refused outright."""
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=2, max_len=32, page_size=8,
                      prefill_chunk=16)
    with pytest.raises(ValueError, match="admit_watermark"):
        ServeScheduler(eng, reserve="lifetime", admit_watermark=3)


def test_declared_budget_drives_admission_not_generation(qwen):
    """``Request.budget_new`` is the declared cap: lifetime reservation
    provisions it, demand admission ignores it (prompt span only), and
    never-fits uses it in both modes — while generation still stops at the
    realised ``max_new``."""
    from repro.serve.scheduler import Request
    cfg, params = qwen
    eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                      prefill_chunk=16)
    lt = ServeScheduler(eng, reserve="lifetime")
    dm = ServeScheduler(eng, reserve="demand")
    req = Request(rid=0, tokens=np.zeros(5, np.int32), max_new=4,
                  budget_new=40)
    assert req.declared_new == 40
    # lifetime reserves the cap: ceil((5+40)/8) = 6 pages; demand only the
    # prompt span + first write: ceil(8/8) = 1
    assert lt._admission_pages(req, lt._prefill_stream(req)) == 6
    assert dm._admission_pages(req, dm._prefill_stream(req)) == 1
    # never-fits uses the cap in both modes
    too_big = Request(rid=1, tokens=np.zeros(5, np.int32), max_new=4,
                      budget_new=60)                  # 5 + 60 > max_len
    assert not lt._fits(too_big) and not dm._fits(too_big)
    assert lt._fits(req) and dm._fits(req)
    # and the realised length still caps generation
    rng = np.random.default_rng(26)
    sched = ServeScheduler(eng, reserve="demand")
    sched.submit(_prompt(rng, cfg, 5), max_new=3, budget_new=40)
    [res] = sched.run()
    assert res.n_generated == 3


# ---------------------------------------------------------------------------
# End-to-end: preempt, resume, bit-match (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m"])
def test_preempt_resume_bitmatch_and_bounded_compiles(arch):
    """A page-constrained demand-mode run must preempt at least once, still
    complete every request, produce tokens that bit-match each request's
    preemption-free single-request run, and compile nothing beyond the
    existing chunk/decode buckets."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    lens = (5, 40, 12, 23, 9, 30)
    prompts = [_prompt(rng, cfg, n) for n in lens]
    refs = _reference_tokens(cfg, params, prompts, max_new=6)

    eng = PagedEngine(cfg, params, batch=3, max_len=64, page_size=8,
                      prefill_chunk=16)
    sched = ServeScheduler(eng, reserve="demand", pool_pages=1 + 10)
    rids = [sched.submit(p, max_new=6) for p in prompts]
    assert all(r is not None for r in rids)
    results = {r.rid: r.tokens for r in sched.run()}

    assert sched.n_preempted >= 1                     # actually exercised
    assert sched.resume_tokens_recomputed > 0
    assert sorted(results) == sorted(rids)            # all completed
    assert all(results[rid] == refs[i] for i, rid in enumerate(rids))
    assert sched.allocator.n_outstanding == 0         # zero leaked pages
    assert (eng.page_table == 0).all()
    # recompute-based resume reuses the existing chunk programs: no new
    # trace kinds beyond the chunk buckets + the one decode program
    assert eng.trace_count("chunk_prefill") <= len(eng.chunk_buckets)
    assert eng.trace_count("decode") == 1


def test_demand_admits_where_lifetime_defers(qwen):
    """The point of reserve-on-demand: a pool too small for two full
    lifetime reservations still runs two prompt spans concurrently, where
    lifetime reservation serialises (defers admission)."""
    cfg, params = qwen
    rng = np.random.default_rng(22)
    # prompt 10 -> span 16 -> 2 prompt pages, lifetime ceil(30/8) = 4 pages;
    # 6 usable pages hold one lifetime reservation but two prompt spans
    prompts = [_prompt(rng, cfg, 10) for _ in range(2)]

    def run(reserve):
        eng = PagedEngine(cfg, params, batch=2, max_len=32, page_size=8,
                          prefill_chunk=16)
        sched = ServeScheduler(eng, reserve=reserve, pool_pages=1 + 6)
        for p in prompts:
            assert sched.submit(p, max_new=20) is not None
        results = sched.run()
        return sched, results

    lt, lt_res = run("lifetime")
    dm, dm_res = run("demand")
    assert len(lt_res) == len(dm_res) == 2
    assert lt.n_admit_deferred > 0            # lifetime had to serialise
    assert lt.occupancy <= 0.75
    assert dm.occupancy > lt.occupancy        # demand ran them together
    # same tokens either way
    assert ({r.rid: r.tokens for r in lt_res}
            == {r.rid: r.tokens for r in dm_res})


@pytest.mark.parametrize("arch", ["mamba2-370m", "qwen2-1.5b"])
def test_resume_chunk_logits_match_uninterrupted_decode(arch):
    """Logits-level recompute fidelity: the final chunk of a resume
    re-prefill (prompt + generated[:-1]) must reproduce the decode logits
    the uninterrupted run sampled its last retained token from — for mamba2
    this is the SSM-state-rebuilt-by-the-chunk-path assert."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(23)
    prompt = _prompt(rng, cfg, 11)
    g = 5                                      # tokens generated pre-preempt

    # uninterrupted: prefill + g-1 decode steps, capturing each logits
    eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                      prefill_chunk=16)
    alloc = PageAllocator(eng.num_pages)
    pages = alloc.alloc(eng.pages_needed(len(prompt), g + 2))
    lg = eng.insert(0, prompt, page_ids=pages, max_new=g + 2)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(g - 1):
        step = np.array([[toks[-1]], [0]], np.int32)
        lg = eng.decode(jnp.asarray(step),
                        live_mask=np.array([True, False]))
        toks.append(int(jnp.argmax(lg[0, -1, :])))
    want = np.asarray(lg[0])                  # logits that sampled toks[-1]

    # preempt: pages reclaimed; resume: chunked re-prefill of
    # prompt + generated[:-1] into the other slot of the same engine
    alloc.free(pages)
    eng.free_slot(0)
    stream = np.concatenate([prompt, np.asarray(toks[:-1], np.int32)])
    pages = alloc.alloc(eng.pages_needed(len(stream), 1))
    got = None
    for start, blen, vlen in chunk_plan(len(stream), eng.chunk_len,
                                        eng.chunk_buckets):
        ck = np.zeros((1, blen), np.int32)
        ck[0, :vlen] = stream[start:start + vlen]
        got = eng.prefill_chunk(1, ck, pages, start, vlen)
    eng.commit_slot(1, pages)
    np.testing.assert_allclose(np.asarray(got)[0], want,
                               atol=1e-5, rtol=1e-5)
    # and the rebuilt state decodes the same next token
    lg = eng.decode(np.array([[0], [toks[-1]]], np.int32),
                    live_mask=np.array([False, True]))
    assert int(jnp.argmax(lg[1, -1, :])) == int(jnp.argmax(want[-1]))


# ---------------------------------------------------------------------------
# HyPar tracker + fail() interactions
# ---------------------------------------------------------------------------


def test_hypar_demand_preempted_jobs_replace_and_gc(qwen):
    """Preempted dynamic jobs leave the graph and re-place through the next
    place_batch wave; results match direct demand mode and the graph/store
    are fully GC'd at drain."""
    cfg, params = qwen
    rng = np.random.default_rng(24)
    prompts = [_prompt(rng, cfg, n) for n in (5, 40, 12, 23, 9, 30)]

    def run(tracker):
        eng = PagedEngine(cfg, params, batch=3, max_len=64, page_size=8,
                          prefill_chunk=16)
        sched = ServeScheduler(eng, reserve="demand", pool_pages=1 + 10,
                               tracker=tracker)
        rids = [sched.submit(p, max_new=6) for p in prompts]
        assert all(r is not None for r in rids)
        return sched, {r.rid: r.tokens for r in sched.run()}

    direct_sched, direct = run(None)
    tracker = HyParRequestTracker(3, strategy="greedy")
    hypar_sched, hypar = run(tracker)
    assert direct == hypar
    assert direct_sched.n_preempted >= 1
    assert tracker.n_preempted == hypar_sched.n_preempted
    assert tracker.graph.n_jobs() == 0            # preempt+retire GC'd all
    assert all(not w.retained for w in tracker.cluster.workers)


def test_fail_slot_under_demand_resumes_with_retained_tokens(qwen):
    """Worker failure under reserve-on-demand reuses the resume machinery:
    generated tokens live host-side, so recovery recomputes prompt +
    retained tokens instead of regenerating from scratch — the result still
    bit-matches the unfailed run."""
    cfg, params = qwen
    rng = np.random.default_rng(25)
    prompt = _prompt(rng, cfg, 9)
    [ref] = _reference_tokens(cfg, params, [prompt], max_new=8)

    eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                      prefill_chunk=16)
    sched = ServeScheduler(eng, reserve="demand")
    rid = sched.submit(prompt, max_new=8)
    for _ in range(4):                         # prefill + a few tokens
        assert sched.step()
    st = next(s for s in sched.slots if s.request is not None)
    assert len(st.tokens) >= 2
    tokens_before = list(st.tokens)
    n_before = len(tokens_before)
    assert sched.fail_slot(st.slot) == rid
    assert sched._suspended[rid].tokens == tokens_before
    results = sched.run()
    assert [r.rid for r in results] == [rid]
    assert results[0].tokens == ref
    # recovery recomputed (resume path), it did not regenerate: the resume
    # re-prefilled prompt + retained tokens
    assert sched.resume_tokens_recomputed >= len(prompt) + n_before - 1
    assert sched.allocator.n_outstanding == 0


def test_fail_slot_mid_resume_prefill_recovers_and_persists(qwen, tmp_path):
    """The worker dies AGAIN while the resume prefill is still chunking:
    the retained tokens must survive the second failure (the in-memory
    record moved onto the slot; ``fail_slot`` puts it back), the durable
    store must hold them throughout, and the eventual result still
    bit-matches the unfailed run."""
    from repro.core.store import JobStore

    cfg, params = qwen
    rng = np.random.default_rng(26)
    prompt = _prompt(rng, cfg, 20)             # resume stream spans 2 chunks
    [ref] = _reference_tokens(cfg, params, [prompt], max_new=8)

    jobstore = JobStore(tmp_path / "serve.sqlite")
    tracker = HyParRequestTracker(2, jobstore=jobstore)
    eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                      prefill_chunk=16)
    sched = ServeScheduler(eng, reserve="demand", tracker=tracker)
    try:
        rid = sched.submit(prompt, max_new=8)
        for _ in range(4):                     # prefill + a few tokens
            assert sched.step()
        st = next(s for s in sched.slots if s.request is not None)
        tokens_before = list(st.tokens)
        assert len(tokens_before) >= 2
        assert sched.fail_slot(st.slot) == rid
        # first failure persisted the retained tokens durably
        assert tracker.restore_suspended()[rid][0] == tokens_before

        # step until the resume prefill is mid-flight, then fail it again
        mid = None
        for _ in range(30):
            mid = next((s for s in sched.slots
                        if s.resume is not None and s.prefilling), None)
            if mid is not None:
                break
            assert sched.step()
        assert mid is not None, "resume never went mid-prefill"
        assert sched.fail_slot(mid.slot) == rid
        # the record moved back intact: a failed resume retry is NOT a new
        # preemption, so the counter stays put
        assert sched._suspended[rid].tokens == tokens_before
        assert sched._suspended[rid].n_preempts == 1
        assert tracker.restore_suspended()[rid][0] == tokens_before

        results = sched.run()
        assert [r.rid for r in results] == [rid]
        assert results[0].tokens == ref
        # two failures → the resume recompute ran (at least) twice
        assert sched.resume_tokens_recomputed >= 2 * (len(prompt)
                                                      + len(tokens_before) - 1)
        assert sched.allocator.n_outstanding == 0
        # retirement dropped the durable record
        assert tracker.restore_suspended() == {}
    finally:
        jobstore.close()


def test_master_restart_restores_suspended_from_store(qwen, tmp_path):
    """Kill the MASTER while a request sits preempted: a fresh scheduler
    over the same store re-seeds the suspended table, the resubmitted
    request (same rid — submission order reproduces) resumes by recompute
    instead of regenerating, and the output bit-matches."""
    from repro.core.store import JobStore

    cfg, params = qwen
    rng = np.random.default_rng(27)
    prompt = _prompt(rng, cfg, 9)
    [ref] = _reference_tokens(cfg, params, [prompt], max_new=8)

    def make(store_path):
        jobstore = JobStore(store_path)
        tracker = HyParRequestTracker(2, jobstore=jobstore)
        eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                          prefill_chunk=16)
        return jobstore, ServeScheduler(eng, reserve="demand",
                                        tracker=tracker)

    path = tmp_path / "serve.sqlite"
    store_a, sched_a = make(path)
    rid_a = sched_a.submit(prompt, max_new=8)
    for _ in range(4):
        assert sched_a.step()
    st = next(s for s in sched_a.slots if s.request is not None)
    n_retained = len(st.tokens)
    assert n_retained >= 2
    assert sched_a.fail_slot(st.slot) == rid_a
    store_a.close()                            # "master dies" here

    store_b, sched_b = make(path)
    try:
        assert sched_b.restore_suspended() == 1
        rid_b = sched_b.submit(prompt, max_new=8)
        assert rid_b == rid_a                  # rids reproduce from zero
        results = sched_b.run()
        assert [r.rid for r in results] == [rid_b]
        assert results[0].tokens == ref
        # the restart resumed: it recomputed prompt + retained tokens
        assert sched_b.resume_tokens_recomputed >= len(prompt) + n_retained - 1
        assert sched_b.tracker.restore_suspended() == {}
    finally:
        store_b.close()


def test_restore_suspended_across_device_groups(qwen, tmp_path):
    """Master restart with ``device_groups=2`` (DESIGN.md §13 + §14): the
    suspended record restores, and with the request's ORIGINAL group
    quarantined on the restarted master the resume re-places onto the
    healthy group — page ownership never crosses a group boundary, KV
    recomputes from the new group's pool, and the output still
    bit-matches."""
    from repro.core.store import JobStore

    cfg, params = qwen
    rng = np.random.default_rng(31)
    prompt = _prompt(rng, cfg, 11)
    [ref] = _reference_tokens(cfg, params, [prompt], max_new=8)

    def make(store_path):
        jobstore = JobStore(store_path)
        tracker = HyParRequestTracker(4, jobstore=jobstore)
        eng = PagedEngine(cfg, params, batch=4, max_len=64, page_size=8,
                          prefill_chunk=16)
        return jobstore, ServeScheduler(eng, reserve="demand",
                                        tracker=tracker, device_groups=2)

    path = tmp_path / "serve.sqlite"
    store_a, sched_a = make(path)
    rid_a = sched_a.submit(prompt, max_new=8)
    for _ in range(4):
        assert sched_a.step()
    st = next(s for s in sched_a.slots if s.request is not None)
    gid_a = sched_a._slot_group[st.slot].gid
    n_retained = len(st.tokens)
    assert n_retained >= 2
    assert sched_a.fail_slot(st.slot) == rid_a
    store_a.close()                            # "master dies" here

    store_b, sched_b = make(path)
    try:
        assert sched_b.restore_suspended() == 1
        sched_b.fail_group(gid_a, reason="device lost across restart")
        rid_b = sched_b.submit(prompt, max_new=8)
        assert rid_b == rid_a                  # rids reproduce from zero
        results = sched_b.run()
        assert [r.rid for r in results] == [rid_b]
        assert results[0].tokens == ref
        assert sched_b.outcomes[rid_b].outcome == "completed"
        assert sched_b.resume_tokens_recomputed >= \
            len(prompt) + n_retained - 1
        # it ran (and only ran) on the surviving group's slots and pages
        assert sched_b.groups[1 - gid_a].occupied_slot_steps > 0
        assert sched_b.groups[gid_a].occupied_slot_steps == 0
        for g in sched_b.groups:
            assert g.allocator.n_outstanding == 0
    finally:
        store_b.close()
