"""Test-suite bootstrap.

Two responsibilities:

1. Make ``src/`` importable when the suite is run without an installed
   package (the tier-1 command exports PYTHONPATH=src, but IDEs and plain
   ``pytest`` invocations should work too).
2. Provide a thin fallback shim for ``hypothesis`` so the property tests
   still *run* (as deterministic sampled-example tests) on machines where
   hypothesis is not installed.  With real hypothesis present the shim is
   inert.  Install the real thing with ``pip install -e .[dev]``.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Hermetic kernel-autotune cache: the suite must neither read a
# previously-tuned user-level cache (tuned block sizes would change which
# kernel configs the wrappers pick) nor write test entries into it.
# Individual tests override this with monkeypatch/tmp_path as needed.
if "REPRO_TUNE_CACHE" not in os.environ:
    import tempfile
    os.environ["REPRO_TUNE_CACHE"] = os.path.join(
        tempfile.mkdtemp(prefix="repro-tune-test-"), "kernel_tune.json")

try:
    import hypothesis  # noqa: F401  (real library available — shim not needed)
except ImportError:
    # CI must NEVER run on the shim: it silently degrades property tests to
    # a fixed deterministic example loop, so a green CI would overstate the
    # suite's coverage.  requirements-dev.txt installs the real library;
    # failing collection here makes a broken install loud.  Bare local runs
    # (no hypothesis, no CI env) keep the shim below.
    if os.environ.get("CI"):
        raise ImportError(
            "hypothesis is not installed but CI=1: the tests/conftest.py "
            "fallback shim would silently degrade property tests to "
            "single-stream sampled examples. Install requirements-dev.txt "
            "(pip install -r requirements-dev.txt).")
    import functools
    import inspect
    import random
    import types

    class _Strategy:
        """Minimal strategy: a callable drawing one example from an RNG."""

        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")
            return _Strategy(draw)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _lists(elem, min_size=0, max_size=None, **_kw):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(lambda rng: [elem._draw(rng)
                                      for _ in range(rng.randint(min_size, hi))])

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elems))

    def _settings(max_examples=20, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(*arg_strats, **kw_strats):
        def deco(fn):
            n = getattr(fn, "_shim_max_examples", 20)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # deterministic per-test stream: same examples every run
                rng = random.Random(f"hypar-shim:{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    drawn = [s._draw(rng) for s in arg_strats]
                    drawn_kw = {k: s._draw(rng) for k, s in kw_strats.items()}
                    try:
                        fn(*args, *drawn, **kwargs, **drawn_kw)
                    except Exception as e:  # pragma: no cover - failure path
                        raise AssertionError(
                            f"falsifying example #{i}: args={drawn} "
                            f"kwargs={drawn_kw}") from e
            # pytest must not mistake the drawn parameters for fixtures:
            # hide the wrapped signature entirely
            wrapper.__dict__.pop("__wrapped__", None)
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.booleans = _booleans
    _st.floats = _floats
    _st.lists = _lists
    _st.tuples = _tuples
    _st.sampled_from = _sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
