"""Tests for ``benchmarks/compare.py`` (the BENCH-json differ CI leans on):
threshold exit codes, NaN-aware rows, missing-row handling, and schema
drift — a differ that crashes or silently passes on malformed input is
worse than no differ."""
import json
import math

import pytest

from benchmarks import compare


def _bench(path, rows, schema_version=1):
    doc = {"schema_version": schema_version, "rows": rows}
    path.write_text(json.dumps(doc))
    return str(path)


def _row(name, median_s, **extra):
    r = {"name": name, "backend": "cpu", "shape": [4, 8], "dtype": "int32",
         "median_s": median_s}
    if median_s is None:
        del r["median_s"]
    r.update(extra)
    return r


def test_identical_files_exit_zero(tmp_path, capsys):
    base = _bench(tmp_path / "a.json", [_row("k", 1e-3), _row("j", 2e-3)])
    assert compare.main([base, base]) == 0
    assert "no regression" in capsys.readouterr().out


def test_threshold_exit_codes(tmp_path, capsys):
    base = _bench(tmp_path / "a.json", [_row("k", 1e-3)])
    new = _bench(tmp_path / "b.json", [_row("k", 1.2e-3)])  # +20%
    assert compare.main([base, new, "--threshold", "10"]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    assert compare.main([base, new, "--threshold", "25"]) == 0
    # improvements never fail, whatever the magnitude
    faster = _bench(tmp_path / "c.json", [_row("k", 1e-4)])
    assert compare.main([base, faster, "--threshold", "10"]) == 0
    assert "improved" in capsys.readouterr().out


def test_missing_rows_reported_but_never_fail(tmp_path, capsys):
    base = _bench(tmp_path / "a.json", [_row("old", 1e-3), _row("k", 1e-3)])
    new = _bench(tmp_path / "b.json", [_row("new", 2e-3), _row("k", 1e-3)])
    assert compare.main([base, new, "--threshold", "10"]) == 0
    out = capsys.readouterr().out
    assert "(row removed)" in out and "(new row)" in out


def test_nan_baseline_skipped_nan_new_regresses(tmp_path, capsys):
    nan = float("nan")
    base = _bench(tmp_path / "a.json",
                  [_row("sick_base", nan), _row("zero_base", 0.0),
                   _row("sick_new", 1e-3)])
    new = _bench(tmp_path / "b.json",
                 [_row("sick_base", 1e-3), _row("zero_base", 1e-3),
                  _row("sick_new", nan)])
    assert compare.main([base, new]) == 1      # NEW NaN = broken run
    out = capsys.readouterr().out
    assert out.count("baseline median unusable, skipped") == 2
    assert "NEW median is NaN" in out
    # sanity: json round-trips the NaN we think it does
    assert math.isnan(json.load(open(new))["rows"][2]["median_s"])


def test_schema_drift_no_rows_key_is_fatal(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema_version": 1, "medians": []}))
    good = _bench(tmp_path / "good.json", [_row("k", 1e-3)])
    with pytest.raises(SystemExit, match="not a BENCH file"):
        compare.main([str(bad), good])


def test_schema_drift_row_without_median_regresses(tmp_path, capsys):
    """A row that lost its median_s (schema drift in a generator) must be
    flagged as a regression, not crash the differ or silently pass."""
    base = _bench(tmp_path / "a.json", [_row("k", 1e-3)])
    new = _bench(tmp_path / "b.json", [_row("k", None, note="drifted")])
    assert compare.main([base, new]) == 1
    assert "schema drift" in capsys.readouterr().out
    # and a brand-new row without median_s is reported, exit 0
    extra = _bench(tmp_path / "c.json",
                   [_row("k", 1e-3), _row("fresh", None)])
    assert compare.main([base, extra]) == 0


def test_robustness_extras_informational_never_gate(tmp_path, capsys):
    """Goodput/shed-counter extras on a row (serve_overload) are printed as
    informational deltas but never counted: the counters describe how much
    of an overload trace was shed, not how fast a kernel ran — and a
    baseline that predates the extras must not read as schema drift."""
    base = _bench(tmp_path / "a.json",
                  [_row("serve_overload", 1e-3, goodput_tok_per_s=100.0,
                        shed_deadline=4)])
    new = _bench(tmp_path / "b.json",
                 [_row("serve_overload", 1e-3, goodput_tok_per_s=10.0,
                       shed_deadline=40, watchdog_trips=3)])
    assert compare.main([base, new, "--threshold", "10"]) == 0
    out = capsys.readouterr().out
    assert "goodput_tok_per_s 100 -> 10" in out
    assert "shed_deadline 4 -> 40" in out
    assert "watchdog_trips=3 (new extra, informational)" in out
    assert "REGRESSION" not in out
    # an old baseline without any extras compares clean against a new file
    # that has them — and median_s still gates regardless of extras
    plain = _bench(tmp_path / "c.json", [_row("serve_overload", 1e-3)])
    assert compare.main([plain, new, "--threshold", "10"]) == 0
    slow = _bench(tmp_path / "d.json",
                  [_row("serve_overload", 5e-3, goodput_tok_per_s=500.0)])
    assert compare.main([base, slow, "--threshold", "10"]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_mesh_change_noted_never_regresses(tmp_path, capsys):
    """A row re-measured on a different device mesh moved because the run's
    shape changed, not because code got slower — the differ must note the
    mesh change instead of counting the delta as a regression."""
    base = _bench(tmp_path / "a.json",
                  [_row("serve_sharded", 1e-3, mesh=None),
                   _row("k", 1e-3)])
    new = _bench(tmp_path / "b.json",
                 [_row("serve_sharded", 5e-3, mesh="1,2"),   # 5x slower
                  _row("k", 1e-3)])
    assert compare.main([base, new, "--threshold", "10"]) == 0
    out = capsys.readouterr().out
    assert "mesh changed" in out and "not comparable" in out
    # same mesh on both sides: the ordinary threshold applies again
    same = _bench(tmp_path / "c.json",
                  [_row("serve_sharded", 5e-3, mesh=None), _row("k", 1e-3)])
    assert compare.main([base, same, "--threshold", "10"]) == 1
    assert "REGRESSION" in capsys.readouterr().out
