"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates its REDUCED config and runs one forward + one train step
on CPU, asserting output shapes and no NaNs; cache-bearing archs also run a
decode step and check it against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells_for, get_config, get_smoke_config
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn, param_count)
from repro.optim import OptimizerSpec
from repro.train import TrainState, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S], "labels": toks[:, 1:],
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        dec = min(cfg.decoder_len, S)
        batch["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.float32)
        batch["tokens"] = toks[:, :dec]
        batch["labels"] = toks[:, 1:dec + 1]
        batch["mask"] = jnp.ones((B, dec), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    logits, aux = jax.jit(
        lambda p, b: forward(cfg, p, tokens=b["tokens"],
                             enc_embeds=b.get("enc_embeds")))(params, batch)
    T = batch["tokens"].shape[1]
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN logits"

    spec = OptimizerSpec(kind="adamw", lr=1e-3)
    state = TrainState.create(cfg, spec, key)
    step = jax.jit(make_train_step(cfg, spec))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert int(state2.step) == 1
    # params actually changed
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(state2.params)))
    assert d > 0, f"{arch}: optimizer step was a no-op"


@pytest.mark.parametrize("arch", [a for a in ARCHS])
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "encdec":
        pytest.skip("cross-attention decode checked in test_serve")
    # fp32 for a tight comparison
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, 16), 0, cfg.vocab_size)
    full, _ = jax.jit(lambda p, t: forward(cfg, p, tokens=t))(params, toks)
    cache = init_cache(cfg, B, 20)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    outs = []
    for t in range(16):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_full_configs_match_published_sizes():
    expect = {
        "whisper-base": (0.07e9, 0.11e9),
        "qwen2-1.5b": (1.4e9, 1.7e9),
        "deepseek-coder-33b": (32e9, 35e9),
        "gemma3-4b": (3.5e9, 4.5e9),
        "llama3-405b": (400e9, 412e9),
        "zamba2-1.2b": (1.0e9, 1.4e9),
        "mixtral-8x7b": (45e9, 48e9),
        "qwen2-moe-a2.7b": (13e9, 15e9),
        "chameleon-34b": (33e9, 35.5e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }
    for arch in ARCHS:
        n = get_config(arch).n_params()
        lo, hi = expect[arch]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    mix = get_config("mixtral-8x7b")
    assert 12e9 <= mix.active_params() <= 14e9
    qm = get_config("qwen2-moe-a2.7b")
    assert 2.2e9 <= qm.active_params() <= 3.2e9


def test_cell_assignment_documented():
    """34 runnable cells + 6 documented long_500k skips = 40 assigned."""
    total = sum(len(cells_for(a)) for a in ARCHS)
    assert total == 34
    for a in ("mamba2-370m", "zamba2-1.2b", "gemma3-4b", "mixtral-8x7b"):
        assert "long_500k" in cells_for(a)
    for a in ("qwen2-1.5b", "llama3-405b", "whisper-base", "chameleon-34b",
              "deepseek-coder-33b", "qwen2-moe-a2.7b"):
        assert "long_500k" not in cells_for(a)
