"""Tier-2 crash soak for the durable process runtime (DESIGN.md §12).

Two hard-kill scenarios against the same content-keyed store:

* SIGKILL *worker processes* (twice, including a replacement) mid-run —
  heartbeat expiry alone recovers them and the run completes bit-identically
  with zero store leaks;
* SIGKILL the *master process* mid-run — a fresh master over the same store
  serves the finished prefix as memo hits and completes bit-identically.

Both use ``REPRO_PROCDEMO_SLEEP`` to hold jobs in flight long enough for
the kill to land mid-work.  Slow-marked: boots real spawn workers many
times over.
"""
import os
import signal
import sqlite3
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.apps import procdemo
from repro.core import ProcessExecutor, VirtualCluster
from repro.core.store import JobStore

pytestmark = pytest.mark.slow

SHAPE = dict(width=3, depth=4, dim=8, seed=11)
N_JOBS = SHAPE["width"] * (SHAPE["depth"] + 1) + 1
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRIVER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "_soak_driver.py")


def _assert_bitwise(results, expected):
    for name, arrays in expected.items():
        got = results[name]
        for a, b in zip(arrays, got.arrays()):
            np.testing.assert_array_equal(a, np.asarray(b), err_msg=name)


def _store_worker_pids(path) -> list[int]:
    con = sqlite3.connect(path)
    try:
        return [int(r[0]) for r in con.execute(
            "SELECT pid FROM workers WHERE pid IS NOT NULL")]
    finally:
        con.close()


def _kill(pid: int) -> None:
    try:
        os.kill(pid, signal.SIGKILL)
    except ProcessLookupError:
        pass


def test_worker_sigkill_soak_recovers_twice(tmp_path, monkeypatch):
    """Kill a booted worker, then — once its replacement has booted — kill
    again: two heartbeat-expiry recoveries in one run, bit-identical result,
    clean store."""
    monkeypatch.setenv("REPRO_PROCDEMO_SLEEP", "0.15")
    expected = procdemo.expected_results(**SHAPE)
    path = tmp_path / "soak.sqlite"
    ex = ProcessExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                         procdemo.make_registry(), procdemo.WORKER_FNS_SPEC,
                         store=path, heartbeat_interval_s=0.1,
                         heartbeat_max_missed=2, job_timeout_s=30.0)
    killed: list[int] = []

    def killer():
        deadline = time.monotonic() + 60.0
        while len(killed) < 2 and time.monotonic() < deadline:
            for pid in _store_worker_pids(path):
                if pid not in killed:
                    _kill(pid)
                    killed.append(pid)
                    time.sleep(1.5)   # let the replacement boot + take jobs
                    break
            else:
                time.sleep(0.05)

    try:
        ex._ensure_started()
        t = threading.Thread(target=killer, daemon=True)
        t.start()
        results, report = ex.run(procdemo.build_graph(**SHAPE))
        t.join(timeout=60.0)
        _assert_bitwise(results, expected)
        assert len(killed) == 2
        assert ex.jobstore.n_done() == N_JOBS
    finally:
        ex.close()
    s = JobStore(path)
    try:
        assert s.check_leaks() == []
    finally:
        s.close()


def test_master_sigkill_resume_serves_done_prefix(tmp_path):
    """SIGKILL the whole master process mid-run; a fresh master over the
    same store memoises every finished job and completes bit-identically."""
    expected = procdemo.expected_results(**SHAPE)
    path = tmp_path / "soak.sqlite"
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_PROCDEMO_SLEEP="0.2")
    args = [sys.executable, DRIVER, str(path)] + [
        str(SHAPE[k]) for k in ("width", "depth", "dim", "seed")]
    proc = subprocess.Popen(args, env=env, cwd=REPO,
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    try:
        # wait for real progress, then murder the master mid-segment
        deadline = time.monotonic() + 120.0
        n_done = 0
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                pytest.fail("driver finished before the kill landed — "
                            "raise REPRO_PROCDEMO_SLEEP")
            if path.exists():
                s = JobStore(path)
                try:
                    n_done = s.n_done()
                finally:
                    s.close()
                if n_done >= 3:
                    break
            time.sleep(0.1)
        assert n_done >= 3, "driver made no progress before timeout"
        proc.kill()
        proc.wait(timeout=10.0)
    finally:
        if proc.poll() is None:
            proc.kill()
    # SIGKILL orphans the spawn children (daemon cleanup never ran): reap
    # them so they stop beating into the store mid-resume
    for pid in _store_worker_pids(path):
        _kill(pid)

    ex = ProcessExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                         procdemo.make_registry(), procdemo.WORKER_FNS_SPEC,
                         store=path, heartbeat_interval_s=0.1,
                         heartbeat_max_missed=3)
    try:
        results, report = ex.run(procdemo.build_graph(**SHAPE))
        _assert_bitwise(results, expected)
        assert ex.n_memoised > 0, "nothing served from the store"
        assert ex.n_executed < N_JOBS, "resume re-executed everything"
        assert ex.n_memoised + ex.n_executed == N_JOBS
        assert ex.n_memoised >= n_done
        assert sorted(set(report.memoised_jobs)) == sorted(report.memoised_jobs)
    finally:
        ex.close()
    s = JobStore(path)
    try:
        assert s.check_leaks() == []
        assert s.counts() == {"done": N_JOBS}
    finally:
        s.close()
