"""Executor behaviour: scheduling, locality, co-scheduling, dynamic jobs,
fault recovery, stragglers (paper §3 + DESIGN.md §6)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import (ChaosLocalExecutor, ChunkedData, ChunkRef,
                        FaultInjector, FunctionRegistry, Job, JobGraph,
                        LocalExecutor, ParallelSegment, VirtualCluster)


def max_registry():
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def search_max(chunk):
        return jnp.max(chunk)

    @reg.whole(2)
    def combine(*cds):
        vals = [a for cd in cds for a in cd.arrays()]
        return ChunkedData.from_arrays([jnp.max(jnp.stack(vals))])

    return reg


def paper_max_graph(A, split=60, k1=6, k2=4):
    g = JobGraph()
    g.add_segment([Job("J1", 1, 0), Job("J2", 1, 0)])
    g.add_segment([Job("J3", 2, 1, (ChunkRef("J1"), ChunkRef("J2")))])
    g.bind_input("J1", A[:split], n_chunks=k1)
    g.bind_input("J2", A[split:], n_chunks=k2)
    return g


@given(st.integers(10, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_paper_max_example_correct(n, seed):
    """Paper §2.2's motivating example returns the true maximum for any
    data and any chunking."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal(n).astype(np.float32)
    split = max(1, min(n - 1, n * 3 // 5))
    g = paper_max_graph(A, split=split,
                        k1=min(6, split), k2=min(4, n - split))
    ex = LocalExecutor(VirtualCluster(n_schedulers=2, max_workers=4),
                       max_registry())
    res, _ = ex.run(g)
    assert float(res["J3"].to_array()) == pytest.approx(float(A.max()))


def test_no_send_back_keeps_results_on_worker():
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def double(c):
        return c * 2

    @reg.whole(2)
    def total(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("P", 1, 0, no_send_back=True)])
    g.add_segment([Job("Q", 2, 1, (ChunkRef("P"),))])
    g.bind_input("P", np.arange(8, dtype=np.float32), n_chunks=4)
    cluster = VirtualCluster(n_schedulers=1, max_workers=2)
    ex = LocalExecutor(cluster, reg)
    res, rep = ex.run(g)
    rec = ex.store.get("P")
    assert not rec.sent_back and rec.owner_worker is not None
    assert cluster.workers[rec.owner_worker].retained.get("P") is not None
    assert float(res["Q"].to_array()) == pytest.approx(2 * np.arange(8).sum())


def test_locality_aware_placement():
    """A consumer of a retained result is placed on the producing worker
    (zero moved bytes on one device, local bytes accounted)."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def ident(c):
        return c

    g = JobGraph()
    g.add_segment([Job("A", 1, 0, no_send_back=True)])
    g.add_segment([Job("B", 1, 1, (ChunkRef("A"),))])
    g.bind_input("A", np.ones(16, np.float32), n_chunks=2)
    ex = LocalExecutor(VirtualCluster(n_schedulers=1, max_workers=3), reg)
    _, rep = ex.run(g)
    seg1 = rep.segments[1]
    assert seg1.local_bytes > 0 and seg1.moved_bytes == 0


def test_co_scheduling_same_function_jobs():
    """Paper §3.3: two jobs wanting 2 threads each share one 4-core worker."""
    reg = FunctionRegistry()

    @reg.chunkwise(7)
    def f(c):
        return c + 1

    g = JobGraph()
    g.add_segment([Job("J1", 7, 2), Job("J2", 7, 2)])
    g.bind_input("J1", np.zeros(4, np.float32), n_chunks=2)
    g.bind_input("J2", np.zeros(4, np.float32), n_chunks=2)
    ex = LocalExecutor(VirtualCluster(n_schedulers=1, cores_per_worker=4,
                                      max_workers=4), reg)
    _, rep = ex.run(g)
    assert rep.segments[0].co_scheduled, "expected co-scheduling event"


def test_dynamic_jobs_iterate_to_convergence():
    """Paper §3.3/§4: a control job re-enqueues work until a condition —
    the Jacobi pattern."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def halve(c):
        return c / 2

    state = {"last": "H0", "iters": 0}

    @reg.control(9)
    def check(cd, ctx):
        v = float(np.max(np.abs(np.asarray(cd.get_data_chunk(0).data))))
        if v > 1.0:
            state["iters"] += 1
            nxt = f"H{state['iters']}"
            ctx.add_job(Job(nxt, 1, 0, (ChunkRef(state["last"]),)), 1)
            ctx.add_job(Job(f"C{state['iters']}", 9, 1, (ChunkRef(nxt),)), 2)
            state["last"] = nxt
        return cd

    g = JobGraph()
    g.add_segment([Job("H0", 1, 0)])
    g.add_segment([Job("C0", 9, 1, (ChunkRef("H0"),))])
    g.bind_input("H0", np.array([64.0]), n_chunks=1)
    ex = LocalExecutor(VirtualCluster(n_schedulers=1, max_workers=2), reg)
    res, _ = ex.run(g)
    # H0 already halves (64 -> 32); C_k re-enqueues until the value hits 1.0:
    # 32,16,8,4,2,1 -> five dynamic re-adds
    assert state["iters"] == 5
    final = float(np.asarray(res[state["last"]].to_array()).reshape(-1)[0])
    assert final <= 1.0


def test_fault_recovery_recomputes_lost_results():
    reg = FunctionRegistry()
    calls = {"n": 0}

    @reg.chunkwise(1)
    def produce(c):
        calls["n"] += 1
        return c * c

    @reg.whole(2)
    def consume(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("P", 1, 0, no_send_back=True)])
    g.add_segment([Job("Q", 2, 1, (ChunkRef("P"),))])
    g.bind_input("P", np.arange(6, dtype=np.float32), n_chunks=3)
    inj = FaultInjector().kill_after_jobs(worker=0, n=1)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=3),
                            reg, inj)
    res, rep = ex.run(g)
    assert rep.recovered_jobs == ["P"]
    assert inj.killed == [0]
    assert float(res["Q"].to_array()) == pytest.approx(float((np.arange(6) ** 2).sum()))


def test_sent_back_results_survive_worker_loss():
    """Results sent back to the scheduler (default) are NOT lost when the
    worker dies — only retained (no_send_back) ones are (paper §3.1)."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def f(c):
        return c + 1

    @reg.whole(2)
    def g_(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("P", 1, 0)])  # send back (default)
    g.add_segment([Job("Q", 2, 1, (ChunkRef("P"),))])
    g.bind_input("P", np.zeros(4, np.float32), n_chunks=2)
    inj = FaultInjector().kill_after_jobs(worker=0, n=1)
    ex = ChaosLocalExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                            reg, inj)
    res, rep = ex.run(g)
    assert rep.recovered_jobs == []            # nothing to recompute
    assert float(res["Q"].to_array()) == pytest.approx(4.0)   # 4 x (0+1)


def test_straggler_speculation():
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def f(c):
        return c

    g = JobGraph()
    g.add_segment([Job("A", 1, 1)])
    g.add_segment([Job("B", 1, 1, (ChunkRef("A"),))])
    g.bind_input("A", np.zeros(2, np.float32), n_chunks=1)
    cluster = VirtualCluster(n_schedulers=1, max_workers=2)
    w0 = cluster.spawn_worker()
    w1 = cluster.spawn_worker()
    w0.slowdown = 10.0                          # degraded worker
    ex = LocalExecutor(cluster, reg, speculative_slowdown_threshold=2.0)
    _, rep = ex.run(g)
    assert any(s.speculated_jobs for s in rep.segments)


def test_release_consumed_results():
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def f(c):
        return c

    g = JobGraph()
    g.add_segment([Job("A", 1, 0, no_send_back=True)])
    g.add_segment([Job("B", 1, 0, (ChunkRef("A"),))])
    g.bind_input("A", np.zeros(4, np.float32), n_chunks=2)
    ex = LocalExecutor(VirtualCluster(n_schedulers=1, max_workers=2), reg)
    res, _ = ex.run(g, release_consumed=True)
    assert ex.store.records["A"].data is None   # released after consumption
    assert "B" in res
