"""Serve-layer robustness (DESIGN.md §14): deadlines, overload shedding,
the step watchdog, device-group failover and the chaos soak.

Deterministic tier-1 tests drive each mechanism alone through a
``ServeChaosInjector`` (faults are injected into MEASUREMENTS and plans,
never slept or raced — the tests are fast and exactly reproducible), then a
composed smoke run staggers a group kill, a slow-step window and allocator
pressure through one drain.

``test_chaos_soak`` is the tier-2 lane's randomised version: hypothesis
draws a trace and a chaos plan, structural invariants are checked after
EVERY step (the test_serve_properties checks, extended with chaos-held
pages), and at drain the no-request-left-behind contract is asserted:

* every submitted request reached EXACTLY ONE typed terminal outcome
  (``completed | shed_queue | shed_deadline | expired | failed``) — the
  exactly-once half is structural (``_record_outcome`` raises on a second
  recording), the soak asserts the coverage half,
* a request has a result iff its outcome is ``completed``, and every
  completed request's tokens BIT-MATCH its uninterrupted single-request
  run (greedy decoding makes recovery observable-or-absent, never
  approximate),
* every group's allocator drains to zero outstanding pages — kills,
  watchdog evictions and injected pressure leak nothing.

The example budget rises in CI tier-2 via ``SERVE_CHAOS_EXAMPLES``.
"""
import dataclasses
import functools
import os

import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.core.fault import ServeChaosInjector
from repro.models.transformer import init_params
from repro.serve import TERMINAL_OUTCOMES, PagedEngine, ServeScheduler

MAX_EXAMPLES = int(os.environ.get("SERVE_CHAOS_EXAMPLES", "6"))
ARCH = "qwen2-1.5b"
BATCH, MAX_LEN, PAGE, CHUNK = 4, 64, 8, 16
MAX_POOL = 1 + BATCH * (MAX_LEN // PAGE)
MIN_POOL = 1 + 6                 # largest request's worst-case resume span
PROMPT_LENS = (5, 11, 19, 30)    # 30 > CHUNK => multi-chunk prefill
STEP_CAP = 1500


class FakeClock:
    """Manually advanced scheduler clock — deadline tests move time by
    assignment instead of sleeping."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@functools.lru_cache(maxsize=None)
def _model():
    cfg = dataclasses.replace(get_smoke_config(ARCH),
                              compute_dtype="float32")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _prompts(share=False):
    cfg, _ = _model()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)
               for n in PROMPT_LENS]
    if share:
        prefix = rng.integers(0, cfg.vocab_size - 1,
                              (2 * PAGE,)).astype(np.int32)
        prompts = [np.concatenate([prefix, p[len(prefix):]])
                   if len(p) > len(prefix) else p for p in prompts]
    return tuple(prompts)


@functools.lru_cache(maxsize=None)
def _engine():
    cfg, params = _model()
    return PagedEngine(cfg, params, batch=BATCH, max_len=MAX_LEN,
                       page_size=PAGE, prefill_chunk=CHUNK)


@functools.lru_cache(maxsize=None)
def _ref_engine():
    cfg, params = _model()
    return PagedEngine(cfg, params, batch=1, max_len=MAX_LEN,
                       page_size=PAGE, prefill_chunk=CHUNK)


@functools.lru_cache(maxsize=None)
def _reference(prompt_idx, max_new, share=False):
    """Fault-free, sharing-free single-request oracle."""
    sched = ServeScheduler(_ref_engine())
    sched.submit(_prompts(share)[prompt_idx], max_new=max_new)
    [res] = sched.run()
    return tuple(res.tokens)


def _sched(**kw):
    eng = _engine()
    # shared engine across tests: park every row so a previous failure
    # cannot cascade (same hygiene as test_serve_properties)
    eng.page_table[:] = 0
    eng._pt_device = None
    return ServeScheduler(eng, **kw)


def _drain(sched, chaos=None, check=None):
    steps = 0
    while sched.step() or len(sched.queue):
        if check is not None:
            check(sched, chaos)
        steps += 1
        assert steps < STEP_CAP, "drain did not finish"
    # recovery tail: the last completion may land in the same wave as a
    # watchdog trip, ending the drain with a group still quarantined.
    # Idle step() calls keep the probe clock advancing; every finite chaos
    # plan lifts, so health must return within a bounded number of calls.
    extra = 0
    while not all(g.healthy for g in sched.groups):
        sched.step()
        extra += 1
        assert extra < 100, "groups did not recover after drain"
    return steps


def _assert_no_leaks(sched, chaos=None):
    if chaos is not None:
        chaos.release_pages(sched)
    sched.flush_prefix_cache()
    for g in sched.groups:
        assert g.allocator.n_outstanding == 0, \
            f"group {g.gid} leaked pages"
    assert (sched.engine.page_table == 0).all()
    assert not sched._suspended


def _check_invariants(sched, chaos=None):
    """The test_serve_properties structural checks, chaos-aware: pages the
    injector holds for its pressure plan count toward each group's
    expected outstanding set (at refcount 1 — nothing else maps them)."""
    from collections import Counter

    eng = sched.engine
    for g in sched.groups:
        alloc = g.allocator
        assert alloc.n_free + alloc.n_outstanding == \
            alloc.num_pages - alloc.n_reserved
        owned = [p for i in g.slot_ids for p in sched.slots[i].page_ids]
        mapped = Counter(owned)
        cached = g.prefix.pages() if g.prefix is not None else set()
        held = set(chaos.held_pages(g.gid)) if chaos is not None else set()
        for p in set(mapped) | cached | held:
            assert g.page_lo <= p < g.page_hi, \
                f"group {g.gid} references foreign page {p}"
        for i in g.slot_ids:
            s = sched.slots[i]
            assert len(s.page_ids) == len(set(s.page_ids))
        assert 0 not in mapped and 0 not in cached
        assert set(mapped) | cached | held == set(alloc.outstanding)
        for p in alloc.outstanding:
            want = (mapped[p] + (1 if p in cached else 0)
                    + (1 if p in held else 0))
            assert alloc.refcount(p) == want
            assert alloc.writable(p) == (alloc.refcount(p) == 1)
    for s in sched.slots:
        n = len(s.page_ids)
        row = eng.page_table[s.slot]
        if s.request is not None and not s.prefilling:
            assert row[:n].tolist() == s.page_ids
            assert (row[n:] == 0).all()
        else:
            assert (row == 0).all()


def _assert_outcome_coverage(sched, n_submitted):
    """No request left behind: every rid ever created reached exactly one
    typed outcome, and has a result iff that outcome is ``completed``."""
    assert sorted(sched.outcomes) == list(range(n_submitted))
    for o in sched.outcomes.values():
        assert o.outcome in TERMINAL_OUTCOMES
    completed = {rid for rid, o in sched.outcomes.items()
                 if o.outcome == "completed"}
    by_rid = {}
    for res in sched.results:
        assert res.rid not in by_rid        # completed exactly once
        by_rid[res.rid] = res
    assert set(by_rid) == completed
    return by_rid


# -- deadlines -------------------------------------------------------------

def test_admission_sheds_unmeetable_ttft_deadline():
    clock = FakeClock()
    clock.t = 100.0
    sched = _sched(clock=clock)
    # waited 10s in some upstream queue, first token due within 1s: even
    # with cold (permissive) EWMAs the predicted TTFT is already blown
    rid = sched.submit(_prompts()[0], max_new=4, arrival_s=90.0,
                       ttft_deadline_s=1.0)
    assert rid is None
    assert sched.queue.shed_deadline == 1
    [(rid0, o)] = sched.outcomes.items()
    assert o.outcome == "shed_deadline"
    # the same request is admitted when enforcement is off (the baseline
    # configuration the overload bench compares against)
    sched = _sched(clock=clock, enforce_deadlines=False)
    rid = sched.submit(_prompts()[0], max_new=4, arrival_s=90.0,
                       ttft_deadline_s=1.0)
    assert rid is not None
    [res] = sched.run()
    assert sched.outcomes[rid].outcome == "completed"
    # …and counted against goodput: it finished, but past its deadline
    assert sched.goodput_tokens == 0
    assert res.n_generated == 4


def test_total_deadline_expires_in_queue_and_mid_flight():
    clock = FakeClock()
    sched = _sched(clock=clock)
    # q: sits in the queue past its whole-answer deadline -> expired at pop
    rid_q = sched.submit(_prompts()[0], max_new=4, total_deadline_s=5.0)
    clock.t = 10.0
    sched.step()
    assert sched.outcomes[rid_q].outcome == "expired"
    # m: placed and decoding, then the deadline passes mid-flight -> the
    # slot frees immediately (remaining decode steps are pure waste)
    rid_m = sched.submit(_prompts()[0], max_new=30, total_deadline_s=5.0)
    sched.step()
    assert any(s.request is not None for s in sched.slots)
    clock.t = 20.0
    sched.step()
    assert sched.outcomes[rid_m].outcome == "expired"
    assert all(s.request is None for s in sched.slots)
    assert sched.n_expired == 2 and not sched.results
    _assert_no_leaks(sched)


def test_completed_within_deadlines_counts_toward_goodput():
    sched = _sched()
    rid = sched.submit(_prompts()[1], max_new=6, ttft_deadline_s=60.0,
                       total_deadline_s=60.0)
    [res] = sched.run()
    assert sched.outcomes[rid].outcome == "completed"
    assert sched.goodput_tokens == res.n_generated > 0


# -- step watchdog ---------------------------------------------------------

def test_watchdog_trip_requeues_and_output_bitmatches():
    # steps 2..3 report +100s of measured duration — far over the 50s
    # budget that no real CPU step approaches, so exactly the injected
    # window trips.  unhealthy_after is out of reach: this isolates the
    # evict-and-requeue path from failover.
    chaos = ServeChaosInjector(slow=(2, 2, 100.0))
    sched = _sched(reserve="demand", watchdog_budget_s=50.0,
                   unhealthy_after=10 ** 6, chaos=chaos)
    rids = {sched.submit(_prompts()[i], max_new=6): i for i in range(3)}
    _drain(sched, chaos, check=_check_invariants)
    assert sched.watchdog_trips >= 1 and chaos.n_slow_steps >= 1
    assert all(g.healthy for g in sched.groups)
    by_rid = _assert_outcome_coverage(sched, len(rids))
    for rid, idx in rids.items():
        assert tuple(by_rid[rid].tokens) == _reference(idx, 6), \
            f"rid {rid} diverged after watchdog eviction"
    _assert_no_leaks(sched, chaos)


def test_repeated_trips_drive_group_unhealthy():
    chaos = ServeChaosInjector(slow=(1, 30, 100.0))
    sched = _sched(watchdog_budget_s=50.0, unhealthy_after=2,
                   probe_interval_steps=3, chaos=chaos)
    for i in range(3):
        sched.submit(_prompts()[0], max_new=4)
    _drain(sched, chaos)
    assert sched.n_group_failovers >= 1
    # the slow window ends; probes bring every group back
    assert all(g.healthy for g in sched.groups)
    assert sched.n_group_rejoins >= 1
    _assert_outcome_coverage(sched, 3)
    _assert_no_leaks(sched, chaos)


# -- restart budget --------------------------------------------------------

def test_restart_budget_fails_poison_request():
    sched = _sched(max_restarts=0)
    rid = sched.submit(_prompts()[0], max_new=8)
    sched.step()
    slot = next(s.slot for s in sched.slots if s.request is not None)
    assert sched.fail_slot(slot) == rid
    assert sched.outcomes[rid].outcome == "failed"
    assert sched.n_failed == 1
    sched.run()
    assert not sched.results          # terminally failed, never re-queued
    _assert_no_leaks(sched)


def test_restart_budget_survives_within_limit():
    sched = _sched(max_restarts=2, reserve="demand")
    rid = sched.submit(_prompts()[2], max_new=6)
    sched.step()
    slot = next(s.slot for s in sched.slots if s.request is not None)
    sched.fail_slot(slot)
    [res] = sched.run()
    assert sched.outcomes[rid].outcome == "completed"
    assert tuple(res.tokens) == _reference(2, 6)
    _assert_no_leaks(sched)


# -- group failover --------------------------------------------------------

def test_group_failover_reroutes_and_rejoins():
    # group 1 dies at call 3 and stays dead for 6 calls: its in-flight
    # requests re-route to group 0 (placement never crosses a page-range
    # boundary — the request simply re-prefills from the healthy pool) and
    # the group rejoins via the health probe once the fault lifts.
    chaos = ServeChaosInjector(kill_group=(1, 3, 6))
    sched = _sched(device_groups=2, reserve="demand",
                   probe_interval_steps=2, chaos=chaos)
    rids = {}
    for i, (idx, max_new) in enumerate([(0, 4), (1, 6), (2, 4), (3, 6),
                                        (0, 6), (2, 6)]):
        rids[sched.submit(_prompts()[idx], max_new=max_new)] = (idx, max_new)
    _drain(sched, chaos, check=_check_invariants)
    assert chaos.n_kills == 1
    assert sched.n_group_failovers == 1 and sched.n_group_rejoins == 1
    assert all(g.healthy for g in sched.groups)
    by_rid = _assert_outcome_coverage(sched, len(rids))
    for rid, (idx, max_new) in rids.items():
        assert tuple(by_rid[rid].tokens) == _reference(idx, max_new), \
            f"rid {rid} diverged across group failover"
    _assert_no_leaks(sched, chaos)


def test_failed_group_quarantine_holds_until_probe():
    sched = _sched(device_groups=2, probe_interval_steps=10 ** 6)
    sched.fail_group(1, reason="test")
    assert not sched.groups[1].healthy
    # admission only sees group 0's slots while 1 is quarantined
    for i in range(4):
        sched.submit(_prompts()[0], max_new=2)
    sched.step()
    assert all(sched.slots[s].request is None
               for s in sched.groups[1].slot_ids)
    sched.run()
    assert sched.probe_group(1)       # manual probe rejoins it
    assert sched.groups[1].healthy and sched.n_group_rejoins == 1
    _assert_outcome_coverage(sched, 4)
    _assert_no_leaks(sched)


def test_flaky_group_rejoin_backoff():
    """ROADMAP 5c: a group that flaps — rejoins, then fails again shortly
    after — is probed at exponentially growing intervals, capped at
    ``rejoin_backoff_cap``; a long stable stretch forgives the history.
    ``rejoin_backoff_s`` accumulates the (FakeClock) seconds groups spend
    down waiting between probes."""
    clock = FakeClock()
    sched = _sched(device_groups=2, probe_interval_steps=2,
                   rejoin_backoff_cap=8, clock=clock)

    def steps_to_rejoin():
        n = 0
        while not sched.groups[1].healthy:
            clock.t += 1.0
            sched.step()
            n += 1
            assert n < 200, "group never rejoined"
        return n

    # first incident probes at the base cadence (multiplier 1)
    sched.fail_group(1, reason="flap")
    assert sched.groups[1].probe_backoff == 1
    assert steps_to_rejoin() == 2
    # immediate re-failures double the interval: 2 -> 4 -> 8, capped at 8
    for expect in (2, 4, 8, 8):
        sched.fail_group(1, reason="flap")
        assert sched.groups[1].probe_backoff == expect
        assert steps_to_rejoin() == 2 * expect
    # each down stretch waited 1s per step on the FakeClock
    assert sched.rejoin_backoff_s == pytest.approx(2 * (1 + 2 + 4 + 8 + 8))
    # a stable stretch of probe_interval_steps * cap calls resets the
    # multiplier: the next incident is fresh, back at base cadence
    for _ in range(2 * 8):
        sched.step()
    sched.fail_group(1, reason="fresh")
    assert sched.groups[1].probe_backoff == 1
    assert steps_to_rejoin() == 2
    assert sched.n_group_rejoins == 6
    _assert_no_leaks(sched)


def test_dead_group_probes_back_off_exponentially():
    """A group whose probes KEEP failing is probed exponentially less
    often — constant-cadence probing of a dead device was the 5c bug."""
    chaos = ServeChaosInjector(kill_group=(1, 2, 50))
    sched = _sched(device_groups=2, probe_interval_steps=2,
                   rejoin_backoff_cap=8, chaos=chaos)
    n = 0
    while not all(g.healthy for g in sched.groups) or sched.step_calls < 3:
        sched.step()
        n += 1
        assert n < 200, "group never rejoined"
    # failed probes at calls 4, 8, 16, 32, 48 double the multiplier to the
    # cap; the fault lifts at call 52 and the NEXT backed-off probe (call
    # 64) rejoins — 6 probe attempts where constant cadence would make 31
    assert sched.step_calls == 64
    assert sched.groups[1].probe_backoff == 8
    assert chaos.n_kills == 1 and sched.n_group_rejoins == 1
    _assert_no_leaks(sched, chaos)


# -- allocator pressure ----------------------------------------------------

def test_chaos_pressure_is_held_then_released_leak_free():
    chaos = ServeChaosInjector(pressure=(0, 1, 4, MAX_POOL))
    sched = _sched(reserve="demand", pool_pages=MIN_POOL + 4, chaos=chaos)
    rids = [sched.submit(_prompts()[i % 3], max_new=4) for i in range(4)]
    _drain(sched, chaos, check=_check_invariants)
    assert chaos.n_pressure_pages > 0
    assert not chaos.held_pages(0)    # window ended -> released in-run
    _assert_outcome_coverage(sched, len(rids))
    _assert_no_leaks(sched, chaos)


# -- composed smoke + the tier-2 soak --------------------------------------

def test_chaos_smoke_composed():
    """The tier-1 smoke drain: a group kill, a slow-step window and
    allocator pressure staggered through one deterministic trace."""
    chaos = ServeChaosInjector(kill_group=(1, 4, 6),
                               slow=(14, 6, 100.0), slow_gid=0,
                               pressure=(0, 2, 4, 2))
    sched = _sched(device_groups=2, reserve="demand",
                   watchdog_budget_s=50.0, unhealthy_after=2,
                   probe_interval_steps=3, chaos=chaos)
    rids = {}
    for idx, max_new in [(0, 6), (1, 8), (2, 6), (3, 8), (0, 8), (2, 8),
                         (1, 6), (3, 6)]:
        rids[sched.submit(_prompts()[idx], max_new=max_new)] = (idx, max_new)
    _drain(sched, chaos, check=_check_invariants)
    assert chaos.n_kills == 1 and chaos.n_pressure_pages > 0
    assert sched.n_group_failovers >= 1 and sched.n_group_rejoins >= 1
    assert all(g.healthy for g in sched.groups)
    by_rid = _assert_outcome_coverage(sched, len(rids))
    assert all(o.outcome == "completed" for o in sched.outcomes.values())
    for rid, (idx, max_new) in rids.items():
        assert tuple(by_rid[rid].tokens) == _reference(idx, max_new), \
            f"rid {rid} diverged under composed chaos"
    _assert_no_leaks(sched, chaos)


@given(reqs=st.lists(st.tuples(st.integers(0, len(PROMPT_LENS) - 1),
                               st.sampled_from((2, 4, 6, 8))),
                     min_size=3, max_size=7),
       pool=st.integers(MIN_POOL, MAX_POOL),
       demand=st.booleans(),
       share=st.booleans(),
       kill_after=st.sampled_from((None, 1, 2, 4, 6, 8)),
       slow_after=st.sampled_from((None, 1, 2, 4, 6, 8)),
       pressurize=st.booleans(),
       ttft_deadline=st.sampled_from((None, 0.0, 60.0)))
@settings(max_examples=MAX_EXAMPLES, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
def test_chaos_soak(reqs, pool, demand, share, kill_after, slow_after,
                    pressurize, ttft_deadline):
    chaos = ServeChaosInjector(
        kill_group=(1, kill_after, 5) if kill_after is not None else None,
        slow=(slow_after, 4, 100.0) if slow_after is not None else None,
        pressure=(0, 2, 5, 3) if pressurize else None)
    eng = _engine()
    eng.page_table[:] = 0
    eng._pt_device = None
    sched = ServeScheduler(
        eng, pool_pages=pool,
        reserve="demand" if demand else "lifetime",
        prefix_cache=share, device_groups=2,
        watchdog_budget_s=50.0, unhealthy_after=2,
        probe_interval_steps=3, chaos=chaos)
    admitted = {}
    n_submitted = 0
    for idx, max_new in reqs:
        rid = sched.submit(_prompts(share)[idx], max_new=max_new,
                           ttft_deadline_s=ttft_deadline)
        n_submitted += 1
        if rid is not None:
            admitted[rid] = (idx, max_new)

    steps = 0
    while sched.step() or len(sched.queue):
        _check_invariants(sched, chaos)
        steps += 1
        assert steps < STEP_CAP, (
            f"drain did not finish (reqs={reqs}, pool={pool}, "
            f"demand={demand}, share={share}, kill={kill_after}, "
            f"slow={slow_after}, pressure={pressurize})")
    # recovery tail (see _drain): idle calls until every probe lands
    extra = 0
    while not all(g.healthy for g in sched.groups):
        sched.step()
        extra += 1
        assert extra < 100, "groups did not recover after drain"

    _check_invariants(sched, chaos)
    by_rid = _assert_outcome_coverage(sched, n_submitted)
    # tokens of everything that completed bit-match the fault-free,
    # sharing-free oracle — kills, trips and pressure are invisible in
    # the output or the request did not complete, never in between
    for rid, res in by_rid.items():
        idx, max_new = admitted[rid]
        assert tuple(res.tokens) == _reference(idx, max_new, share), (
            f"rid {rid} diverged (pool={pool}, demand={demand}, "
            f"share={share}, kill={kill_after}, slow={slow_after})")
    # a finite chaos plan always lifts: every group must be healthy again
    assert all(g.healthy for g in sched.groups)
    _assert_no_leaks(sched, chaos)
