"""Integration: fused train step vs HyPar-scheduled training, loss descent,
serving engine, end-to-end driver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLMStream
from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_params
from repro.optim import OptimizerSpec
from repro.serve import Engine, SamplingParams
from repro.train import HyParTrainer, TrainState, make_train_step

CFG = ModelConfig(name="ti", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  compute_dtype="float32")
SPEC = OptimizerSpec(kind="adamw", lr=1e-3)


def test_hypar_training_equals_fused_step():
    """The paper's scheduled execution must be numerically equivalent to the
    tailored implementation (its Fig. 3 compares only *runtime*)."""
    dc = DataConfig(global_batch=4, seq_len=32)
    stream = SyntheticLMStream(CFG, dc)
    step = jax.jit(make_train_step(CFG, SPEC, grad_accum=2))
    state = TrainState.create(CFG, SPEC, jax.random.PRNGKey(0))
    for s in range(3):
        b = jax.tree.map(jnp.asarray, stream.batch(s))
        state, _ = step(state, b)

    trainer = HyParTrainer(CFG, SPEC, n_micro=2)
    batches = []
    for s in range(3):
        b = stream.batch(s)
        batches.append([
            {k: jnp.asarray(v[i * 2:(i + 1) * 2]) for k, v in b.items()}
            for i in range(2)])
    fp, fo, report = trainer.run(batches, key=jax.random.PRNGKey(0))

    for a, b in zip(jax.tree.leaves(fp), jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)
    # gradients were retained on workers (no_send_back), not shipped
    grad_jobs = [j for s_ in report.segments for j in s_.jobs
                 if j.startswith("G")]
    assert grad_jobs, "graph contained no grad jobs"


def test_loss_decreases_over_training():
    dc = DataConfig(global_batch=8, seq_len=64, zipf_a=1.5)
    stream = SyntheticLMStream(CFG, dc)
    step = jax.jit(make_train_step(CFG, SPEC))
    state = TrainState.create(CFG, SPEC, jax.random.PRNGKey(1))
    losses = []
    for s in range(30):
        b = jax.tree.map(jnp.asarray, stream.batch(s % 4))  # small cycle
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]


def test_grad_accum_invariance():
    """accum=1 on batch B equals accum=2 on the same batch (mean-of-grads)."""
    dc = DataConfig(global_batch=4, seq_len=16, pad_frac=0.0)
    stream = SyntheticLMStream(CFG, dc)
    b = jax.tree.map(jnp.asarray, stream.batch(0))
    s1 = TrainState.create(CFG, SPEC, jax.random.PRNGKey(2))
    s2 = TrainState.create(CFG, SPEC, jax.random.PRNGKey(2))
    st1, _ = jax.jit(make_train_step(CFG, SPEC, grad_accum=1))(s1, b)
    st2, _ = jax.jit(make_train_step(CFG, SPEC, grad_accum=2))(s2, b)
    for a, c in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_engine_prefill_matches_forward():
    params = init_params(CFG, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, 255)
    eng = Engine(CFG, params, batch=2, max_len=32)
    pre = eng.prefill(toks)
    full, _ = jax.jit(lambda p, t: forward(CFG, p, tokens=t))(params, toks)
    np.testing.assert_allclose(np.asarray(pre[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_engine_greedy_generation_deterministic():
    params = init_params(CFG, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, 255)
    eng = Engine(CFG, params, batch=2, max_len=64)
    out1 = eng.generate(toks, max_new=8)
    out2 = eng.generate(toks, max_new=8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)
    assert (out1 >= 0).all() and (out1 < CFG.padded_vocab).all()


def test_engine_generation_matches_stepwise_forward():
    """Greedy engine output == argmax over repeated full forwards."""
    params = init_params(CFG, jax.random.PRNGKey(7))
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (1, 6), 0, 255))
    eng = Engine(CFG, params, batch=1, max_len=32)
    gen = eng.generate(jnp.asarray(toks), max_new=4)

    seq = toks.copy()
    fwd = jax.jit(lambda p, t: forward(CFG, p, tokens=t))
    for i in range(4):
        logits, _ = fwd(params, jnp.asarray(seq))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        assert nxt == int(gen[0, i]), f"mismatch at step {i}"
        seq = np.concatenate([seq, [[nxt]]], axis=1)


def test_engine_stop_tokens():
    params = init_params(CFG, jax.random.PRNGKey(9))
    toks = jax.random.randint(jax.random.PRNGKey(10), (2, 4), 0, 255)
    eng = Engine(CFG, params, batch=2, max_len=32)
    greedy = eng.generate(toks, max_new=6)
    stop = int(greedy[0, 1])    # force a stop at the second generated token
    out = eng.generate(toks, max_new=6,
                       sp=SamplingParams(stop_token=stop))
    row = out[0].tolist()
    assert stop in row
    after = row[row.index(stop):]
    assert all(t == stop for t in after)


def test_engine_encdec_generation():
    cfg = ModelConfig(name="ed", family="encdec", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                      n_encoder_layers=2, use_rope=False, norm="layernorm",
                      act="gelu", max_seq=128, compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(11))
    toks = jax.random.randint(jax.random.PRNGKey(12), (2, 4), 0, 255)
    enc = jax.random.normal(jax.random.PRNGKey(13), (2, 16, cfg.d_model))
    eng = Engine(cfg, params, batch=2, max_len=32)
    out = eng.generate(toks, max_new=5, enc_embeds=enc)
    assert out.shape == (2, 5)
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# end-to-end driver
# ---------------------------------------------------------------------------


def test_train_driver_end_to_end(tmp_path):
    """launch/train.py main(): a few steps incl. checkpoint + resume."""
    from repro.launch.train import main
    argv = ["--arch", "qwen2-1.5b", "--smoke", "--steps", "6", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
            "--log-every", "3"]
    loss1 = main(argv)
    assert np.isfinite(loss1)
    # resume from the step-6 checkpoint and continue to 8
    argv_resume = list(argv)
    argv_resume[argv.index("--steps") + 1] = "8"
    loss2 = main(argv_resume)
    assert np.isfinite(loss2)
