"""ProcessExecutor: real spawn workers, durable memoisation, heartbeat
recovery (tier-1 — kept fast: tiny graphs, 2 workers, numpy-only children)."""
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.apps import procdemo
from repro.core import ProcessExecutor, VirtualCluster
from repro.core.store import JobStore

SHAPE = dict(width=2, depth=3, dim=8, seed=3)


def _make_executor(store, **kw):
    kw.setdefault("mode", "pipelined")
    kw.setdefault("heartbeat_interval_s", 0.1)
    kw.setdefault("heartbeat_max_missed", 3)
    return ProcessExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                           procdemo.make_registry(),
                           procdemo.WORKER_FNS_SPEC,
                           store=store, **kw)


def _assert_bitwise(results, expected):
    for name, arrays in expected.items():
        got = results[name]
        for a, b in zip(arrays, got.arrays()):
            np.testing.assert_array_equal(a, np.asarray(b), err_msg=name)


@pytest.mark.parametrize("mode", ["pipelined", "dataflow"])
def test_process_executor_matches_oracle(tmp_path, mode):
    expected = procdemo.expected_results(**SHAPE)
    with _make_executor(tmp_path / "jobs.sqlite", mode=mode) as ex:
        results, report = ex.run(procdemo.build_graph(**SHAPE))
        _assert_bitwise(results, expected)
        assert ex.n_executed == len(expected)
        assert ex.n_memoised == 0
        assert report.memoised_jobs == []
        assert ex.jobstore.n_done() == len(expected)


def test_restarted_run_serves_every_job_from_the_store(tmp_path):
    """Master-restart memoisation: a second executor over the same store
    path (fresh processes, fresh cluster) re-executes nothing."""
    path = tmp_path / "jobs.sqlite"
    expected = procdemo.expected_results(**SHAPE)
    with _make_executor(path) as ex:
        first, _ = ex.run(procdemo.build_graph(**SHAPE))
    with _make_executor(path) as ex2:
        second, report = ex2.run(procdemo.build_graph(**SHAPE))
        assert ex2.n_executed == 0
        assert ex2.n_memoised == len(expected)
        assert sorted(report.memoised_jobs) == sorted(expected)
    _assert_bitwise(second, expected)
    for name in expected:
        for a, b in zip(first[name].arrays(), second[name].arrays()):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sigkill_worker_recovered_by_heartbeat_expiry(tmp_path, monkeypatch):
    """SIGKILL one live worker process mid-run: nobody calls fail() — the
    monitor discovers the silence, re-places in-flight jobs, spawns a
    replacement, and the run completes bit-identically."""
    monkeypatch.setenv("REPRO_PROCDEMO_SLEEP", "0.15")
    expected = procdemo.expected_results(**SHAPE)
    ex = _make_executor(tmp_path / "jobs.sqlite", heartbeat_max_missed=2,
                        job_timeout_s=20.0)
    try:
        ex._ensure_started()
        victim_wid, victim = next(iter(ex.procs.items()))
        n_workers0 = len(ex.cluster.workers)

        def kill_once_booted():
            # kill only after the child stamped its pid: expiry then runs on
            # the beat timeout, not the (long) boot grace
            deadline = time.monotonic() + 15.0
            while time.monotonic() < deadline:
                if victim_wid in ex.jobstore.booted_wids():
                    os.kill(victim.process.pid, signal.SIGKILL)
                    return
                time.sleep(0.02)

        killer = threading.Thread(target=kill_once_booted, daemon=True)
        killer.start()
        results, report = ex.run(procdemo.build_graph(**SHAPE))
        killer.join(timeout=15.0)
        _assert_bitwise(results, expected)
        # discovery happened: the slot was failed and replaced
        deadline = time.monotonic() + 5.0
        while not victim.lost and time.monotonic() < deadline:
            time.sleep(0.05)
        assert victim.lost
        assert not any(w.alive and w.wid == victim_wid
                       for w in ex.cluster.workers)
        assert len(ex.cluster.workers) > n_workers0
        assert ex.jobstore.heartbeats().keys() == {
            w.wid for w in ex.cluster.alive_workers()}
    finally:
        ex.close()


def test_store_survives_for_inspection_after_close(tmp_path):
    path = tmp_path / "jobs.sqlite"
    with _make_executor(path) as ex:
        ex.run(procdemo.build_graph(**SHAPE))
    s = JobStore(path)
    try:
        assert s.check_leaks() == []
        assert s.counts() == {"done": s.n_done()}
    finally:
        s.close()
