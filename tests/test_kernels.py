"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle
across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.jacobi_sweep.ops import jacobi_sweep, jacobi_sweep_residual
from repro.kernels.jacobi_sweep.ref import (jacobi_sweep_ref,
                                            jacobi_sweep_residual_ref)
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.ssd_scan.ops import ssd_intra_chunk

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, S, H, KV, D, causal, window, qb, kb
    (1, 128, 4, 4, 64, True, None, 64, 64),
    (2, 256, 4, 2, 64, True, None, 128, 128),
    (1, 512, 8, 2, 32, True, 64, 128, 64),
    (2, 256, 8, 8, 32, False, None, 256, 64),
    (1, 384, 4, 1, 64, True, 100, 128, 128),
    (1, 256, 2, 2, 128, True, None, 64, 256),
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    B, S, H, KV, D, causal, window, qb, kb = case
    q = jax.random.normal(KEYS[0], (B, S, H, D), dtype)
    k = jax.random.normal(KEYS[1], (B, S, KV, D), dtype)
    v = jax.random.normal(KEYS[2], (B, S, KV, D), dtype)
    ref = flash_attention(q, k, v, causal=causal, window=window, impl="ref")
    ker = flash_attention(q, k, v, causal=causal, window=window,
                          impl="interpret", q_block=qb, kv_block=kb)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ker, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_fully_masked_rows_are_finite():
    """Sliding window + causal can fully mask early rows of a late q block —
    output must stay finite (0/denominator guard)."""
    B, S, H, D = 1, 256, 2, 32
    q = jax.random.normal(KEYS[3], (B, S, H, D))
    k = jax.random.normal(KEYS[4], (B, S, H, D))
    v = jax.random.normal(KEYS[5], (B, S, H, D))
    out = flash_attention(q, k, v, causal=True, window=1, impl="interpret",
                          q_block=64, kv_block=64)
    assert np.isfinite(np.asarray(out, np.float32)).all()


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------

SSD_CASES = [
    (2, 2, 64, 32, 16),
    (4, 1, 128, 64, 32),
    (1, 8, 32, 16, 64),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_oracle(case, dtype):
    BC, H, Q, P, N = case
    xh = jax.random.normal(KEYS[0], (BC, H, Q, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(KEYS[1], (BC, H, Q, 1))).astype(dtype)
    a = (-jnp.exp(jax.random.normal(KEYS[2], (BC, H, Q, 1)) * 0.3)
         * dt.astype(jnp.float32)).astype(dtype)
    Bm = jax.random.normal(KEYS[3], (BC, Q, N), dtype)
    Cm = jax.random.normal(KEYS[4], (BC, Q, N), dtype)
    y_r, s_r = ssd_intra_chunk(xh, dt, a, Bm, Cm, impl="ref")
    y_k, s_k = ssd_intra_chunk(xh, dt, a, Bm, Cm, impl="interpret")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               atol=tol, rtol=tol)


def test_ssd_chunked_model_path_kernel_parity():
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 96, 4, 16, 8     # S not a chunk multiple: pad path
    xh = jax.random.normal(KEYS[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(KEYS[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(KEYS[2], (H,)) * 0.2)
    Bm = jax.random.normal(KEYS[3], (B, S, N))
    Cm = jax.random.normal(KEYS[4], (B, S, N))
    y1 = ssd_chunked(xh, dt, A, Bm, Cm, chunk=32, impl="jnp")
    y2 = ssd_chunked(xh, dt, A, Bm, Cm, chunk=32, impl="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_ssd_sequential_oracle():
    """Chunked SSD == naive per-step recurrence."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 1, 40, 2, 8, 4
    xh = jax.random.normal(KEYS[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(KEYS[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(KEYS[2], (H,)) * 0.2)
    Bm = jax.random.normal(KEYS[3], (B, S, N))
    Cm = jax.random.normal(KEYS[4], (B, S, N))
    y = ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)
    # naive recurrence
    state = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])   # (B,H)
        upd = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                        np.asarray(Bm[:, t]), np.asarray(xh[:, t]))
        state = state * dec[:, :, None, None] + upd
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), state))
    y_naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_naive, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64, 128), (256, 512), (8, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    x = jax.random.normal(KEYS[0], shape, dtype)
    g = (jax.random.normal(KEYS[1], (shape[-1],)) + 1.0).astype(jnp.float32)
    r = rmsnorm(x, g, impl="ref")
    k = rmsnorm(x, g, impl="interpret")
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(k, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# jacobi sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,rb,cb", [(256, 128, 128), (512, 256, 128),
                                     (512, 128, 512)])
def test_jacobi_sweep_matches_oracle(n, rb, cb):
    A = jax.random.normal(KEYS[0], (n, n)) / n + jnp.eye(n) * 3.0
    x = jax.random.normal(KEYS[1], (n,))
    b = jax.random.normal(KEYS[2], (n,))
    d = jnp.diag(A)
    r = jacobi_sweep(A, x, b, d, impl="ref")
    k = jacobi_sweep(A, x, b, d, impl="interpret", row_block=rb, col_block=cb)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def _jacobi_system(n, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    A = jax.random.normal(ks[0], (n, n)) / n + jnp.eye(n) * 3.0
    x = jax.random.normal(ks[1], (n,)).astype(dtype)
    b = jax.random.normal(ks[2], (n,))
    return A, x, b, jnp.diag(A)


@pytest.mark.parametrize("n,rb,cb", [(256, 128, 128), (512, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_jacobi_fused_residual_matches_unfused(n, rb, cb, dtype):
    """Fused kernel: x' identical to the unfused sweep, and the emitted
    residual equals ‖b - A·x‖ of the incoming iterate."""
    A, x, b, d = _jacobi_system(n, dtype)
    x2, res = jacobi_sweep_residual(A, x, b, d, impl="interpret",
                                    row_block=rb, col_block=cb)
    ref = jacobi_sweep_ref(A, x, b, d)
    res_true = float(jnp.linalg.norm(b - A @ x.astype(jnp.float32)))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(x2, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(float(res), res_true, rtol=max(tol, 1e-5))
    assert x2.dtype == x.dtype


@pytest.mark.parametrize("n", [200, 300, 333])
def test_jacobi_fused_residual_padding(n):
    """Non-divisible N: the wrapper zero-pads up to the block lcm; padded
    lanes must contribute exactly zero to x' and the residual."""
    A, x, b, d = _jacobi_system(n)
    x2, res = jacobi_sweep_residual(A, x, b, d, impl="interpret",
                                    row_block=128, col_block=128)
    x2r, rsqr = jacobi_sweep_residual_ref(A, x, b, d)
    assert x2.shape == (n,)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x2r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(res), float(jnp.sqrt(rsqr)), rtol=1e-5)
    # plain sweep wrapper pads too
    k = jacobi_sweep(A, x, b, d, impl="interpret", row_block=128,
                     col_block=128)
    np.testing.assert_allclose(np.asarray(k),
                               np.asarray(jacobi_sweep_ref(A, x, b, d)),
                               atol=1e-5, rtol=1e-5)


def test_jacobi_fused_iteration_halves_flops():
    """The paper's hot loop: one fused iteration (sweep + residual) must
    cost ~half the FLOPs of the unfused sweep-then-residual pair — i.e.
    exactly one A-matvec instead of two."""
    from repro.analysis.hlo import xla_cost_analysis
    A, x, b, d = _jacobi_system(256)

    def unfused_iter(A, x, b, d):
        x2 = jacobi_sweep_ref(A, x, b, d)
        return x2, jnp.linalg.norm(b - A @ x2.astype(jnp.float32))

    def fused_iter(A, x, b, d):
        x2, rsq = jacobi_sweep_residual_ref(A, x, b, d)
        return x2, jnp.sqrt(rsq)

    cu = jax.jit(unfused_iter).lower(A, x, b, d).compile()
    cf = jax.jit(fused_iter).lower(A, x, b, d).compile()
    flops_unfused = xla_cost_analysis(cu).get("flops", 0.0)
    flops_fused = xla_cost_analysis(cf).get("flops", 0.0)
    if not flops_unfused:
        pytest.skip("cost_analysis reports no flops on this backend")
    assert flops_fused < 0.6 * flops_unfused, (flops_fused, flops_unfused)


def test_jacobi_fused_loop_matches_unfused_loop():
    """A fixed-iteration fused loop (lagged residual) produces the same
    iterates as the classic two-matvec loop."""
    n, iters = 128, 50
    A, x0, b, d = _jacobi_system(n)
    x_f = x0
    for _ in range(iters):
        x_f, _ = jacobi_sweep_residual(A, x_f, b, d, impl="ref")
    x_u = x0
    for _ in range(iters):
        x_u = jacobi_sweep_ref(A, x_u, b, d)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_u),
                               atol=1e-6, rtol=1e-6)


def test_jacobi_iteration_converges():
    """500 sweeps of a diagonally-dominant system reach the solution —
    the paper's §4 experiment in miniature."""
    n = 128
    A = np.asarray(jax.random.normal(KEYS[0], (n, n))) / n
    np.fill_diagonal(A, 4.0)
    x_true = np.asarray(jax.random.normal(KEYS[1], (n,)))
    b = A @ x_true
    A, b = jnp.asarray(A), jnp.asarray(b)
    d = jnp.diag(A)
    x = jnp.zeros((n,))
    for _ in range(500):
        x = jacobi_sweep(A, x, b, d, impl="ref")
    np.testing.assert_allclose(np.asarray(x), x_true, atol=1e-5)


# ---------------------------------------------------------------------------
# paged flash-decode attention
# ---------------------------------------------------------------------------

def _paged_case(key, B, H, KV, D, page_size, n_slot_pages, kv_lens, *,
                share=False, trash_tail=0):
    """Build a pool + page table exercising the serve layouts: ragged
    lengths, trailing trash-page entries, optionally slots SHARING physical
    pages (the prefix-cache / COW refcount>1 read case — DESIGN.md §11) and
    mid-prefill slots whose last ``trash_tail`` in-range logical pages still
    point at trash page 0.  Page 0 is filled with NaN: the masking contract
    says its contents must never reach an output."""
    ks = jax.random.split(key, 5)
    n_pool = 1 + B * n_slot_pages
    k_pool = jax.random.normal(ks[0], (n_pool, KV, page_size, D), jnp.float32)
    v_pool = jax.random.normal(ks[1], (n_pool, KV, page_size, D), jnp.float32)
    k_pool = k_pool.at[0].set(jnp.nan)
    v_pool = v_pool.at[0].set(jnp.nan)
    table = np.zeros((B, n_slot_pages), np.int32)
    nxt = 1
    for b, L in enumerate(kv_lens):
        need = -(-max(int(L), 1) // page_size)
        for i in range(need):
            if share and b > 0 and i == 0:
                table[b, i] = table[0, 0]      # shared prefix page
            else:
                table[b, i] = nxt
                nxt += 1
        for i in range(max(need - trash_tail, 0), need):
            table[b, i] = 0                    # mid-prefill: unwritten page
    q = jax.random.normal(ks[2], (B, 1, H, D), jnp.float32)
    kt = jax.random.normal(ks[3], (B, KV, 1, D), jnp.float32)
    vt = jax.random.normal(ks[4], (B, KV, 1, D), jnp.float32)
    return q, k_pool, v_pool, jnp.asarray(table), \
        jnp.asarray(kv_lens, jnp.int32), kt, vt


PA_CASES = [
    # B, H, KV, D, page_size, n_slot_pages, kv_lens, window, share, trash
    (3, 4, 2, 64, 8, 4, (5, 17, 0), None, False, 0),
    (2, 8, 8, 64, 16, 3, (31, 16), None, False, 0),
    (4, 4, 4, 32, 8, 6, (40, 23, 8, 1), 11, False, 0),
    (3, 2, 2, 64, 16, 4, (33, 33, 50), None, True, 0),   # shared/COW pages
    (2, 4, 2, 32, 8, 5, (37, 21), None, False, 1),       # mid-prefill trash
    (2, 4, 1, 64, 16, 2, (9, 25), 7, True, 0),
]


@pytest.mark.parametrize("case", PA_CASES)
@pytest.mark.parametrize("head_block", [1, 2])
def test_paged_attention_kernel_matches_ref(case, head_block):
    from repro.kernels.paged_attention.ops import paged_decode_attention
    B, H, KV, D, ps, n, lens, window, share, trash = case
    if head_block > KV:
        pytest.skip("head_block exceeds KV heads")
    q, kp, vp, tbl, kv_len, kt, vt = _paged_case(
        KEYS[3], B, H, KV, D, ps, n, lens, share=share, trash_tail=trash)
    ref = paged_decode_attention(q, kp, vp, tbl, kv_len, kt, vt,
                                 window=window, impl="ref")
    out = paged_decode_attention(q, kp, vp, tbl, kv_len, kt, vt,
                                 window=window, impl="interpret",
                                 head_block=head_block)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("case", PA_CASES[:4])
def test_paged_attention_matches_models_gather_oracle(case):
    """Kernel vs the MODELS-level path it replaces: gather_pages (dense
    view materialisation, trash rows zeroed) + _decode_attn_plus_self.
    This pins the cross-layer contract, not just the in-package ref."""
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.models.attention import _decode_attn_plus_self, gather_pages
    B, H, KV, D, ps, n, lens, window, share, trash = case
    q, kp, vp, tbl, kv_len, kt, vt = _paged_case(
        KEYS[4], B, H, KV, D, ps, n, lens, share=share, trash_tail=trash)
    kc = gather_pages(kp, tbl)
    vc = gather_pages(vp, tbl)
    want = _decode_attn_plus_self(q, kc, vc, kv_len, kt, vt, window=window)
    got = paged_decode_attention(q, kp, vp, tbl, kv_len, kt, vt,
                                 window=window, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


def test_paged_attention_all_trash_slot_is_finite():
    """A free slot (kv_len 0, whole table row on the NaN-poisoned trash
    page) must produce the degenerate self-only answer, not NaN."""
    from repro.kernels.paged_attention.ops import paged_decode_attention
    q, kp, vp, tbl, kv_len, kt, vt = _paged_case(
        KEYS[5], 2, 4, 2, 32, 8, 3, (0, 0))
    for impl in ("ref", "interpret"):
        out = np.asarray(paged_decode_attention(q, kp, vp, tbl, kv_len,
                                                kt, vt, impl=impl))
        assert np.isfinite(out).all()
        # kv_len 0 -> softmax collapses onto the self term: out == vt
        want = np.repeat(np.asarray(vt)[:, :, 0, :], 4 // 2, axis=1)
        np.testing.assert_allclose(out[:, 0], want, atol=1e-6, rtol=1e-6)
