"""Executor parity: every job-graph fixture must produce numerically
identical results through LocalExecutor sync, pipelined and dataflow
dispatch, and (for SPMD-compatible fixtures) through SpmdExecutor — the
contract test for the BaseExecutor ABC (DESIGN.md §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BaseExecutor, ChunkedData, ChunkRef, ExecutionReport,
                        FunctionRegistry, Job, JobGraph, LocalExecutor,
                        SpmdExecutor, VirtualCluster)

LOCAL_MODES = ("sync", "pipelined", "dataflow")


# ---------------------------------------------------------------------------
# fixtures: factories returning (graph, registry); chunk counts divide evenly
# so the SPMD stacked form is well defined
# ---------------------------------------------------------------------------


def fix_chunkwise_chain():
    """Two chained chunkwise segments (8 equal chunks)."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def scale(c):
        return c * 2.0 + 1.0

    @reg.chunkwise(2)
    def shift(c):
        return jnp.tanh(c) + 3.0

    g = JobGraph()
    g.add_segment([Job("J1", 1, 0)])
    g.add_segment([Job("J2", 2, 0, (ChunkRef("J1"),))])
    g.bind_input("J1", np.arange(32, dtype=np.float32).reshape(8, 4), n_chunks=8)
    return g, reg


def fix_chunkwise_reduce():
    """Chunkwise map then whole-function reduction."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def square(c):
        return c * c

    @reg.whole(2)
    def total(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("P", 1, 0, no_send_back=True)])
    g.add_segment([Job("Q", 2, 1, (ChunkRef("P"),))])
    g.bind_input("P", np.arange(16, dtype=np.float32).reshape(4, 4), n_chunks=4)
    return g, reg


def fix_sliced_refs():
    """Consumers reading disjoint slices of one producer (paper R1[a..b])."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def ident(c):
        return c + 0.5

    @reg.whole(2)
    def total(cd):
        return ChunkedData.from_arrays([sum(jnp.sum(a) for a in cd.arrays())])

    g = JobGraph()
    g.add_segment([Job("J1", 1, 0)])
    g.add_segment([Job("LO", 2, 1, (ChunkRef("J1", 0, 3),)),
                   Job("HI", 2, 1, (ChunkRef("J1", 3, 6),))])
    g.bind_input("J1", np.arange(24, dtype=np.float32).reshape(6, 4), n_chunks=6)
    return g, reg


def fix_two_producers():
    """Two chunkwise producers combined by a whole function."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def double(c):
        return c * 2.0

    @reg.whole(2)
    def combine(*cds):
        vals = [a for cd in cds for a in cd.arrays()]
        return ChunkedData.from_arrays([jnp.max(jnp.stack(vals))])

    g = JobGraph()
    g.add_segment([Job("J1", 1, 0), Job("J2", 1, 0)])
    g.add_segment([Job("J3", 2, 1, (ChunkRef("J1"), ChunkRef("J2")))])
    g.bind_input("J1", np.arange(8, dtype=np.float32).reshape(4, 2), n_chunks=4)
    g.bind_input("J2", -np.arange(8, dtype=np.float32).reshape(4, 2), n_chunks=4)
    return g, reg


def fix_dynamic_control():
    """Control job re-enqueueing until convergence (Jacobi pattern).
    Local-only: SpmdExecutor fuses this shape via IterativeSpec instead."""
    reg = FunctionRegistry()

    @reg.chunkwise(1)
    def halve(c):
        return c / 2

    state = {"last": "H0", "iters": 0}

    @reg.control(9)
    def check(cd, ctx):
        v = float(np.max(np.abs(np.asarray(cd.get_data_chunk(0).data))))
        if v > 1.0:
            state["iters"] += 1
            nxt = f"H{state['iters']}"
            ctx.add_job(Job(nxt, 1, 0, (ChunkRef(state["last"]),)), 1)
            ctx.add_job(Job(f"C{state['iters']}", 9, 1, (ChunkRef(nxt),)), 2)
            state["last"] = nxt
        return cd

    g = JobGraph()
    g.add_segment([Job("H0", 1, 0)])
    g.add_segment([Job("C0", 9, 1, (ChunkRef("H0"),))])
    g.bind_input("H0", np.array([[48.0, -64.0]]), n_chunks=1)
    return g, reg


SPMD_FIXTURES = {
    "chunkwise-chain": fix_chunkwise_chain,
    "chunkwise-reduce": fix_chunkwise_reduce,
    "sliced-refs": fix_sliced_refs,
    "two-producers": fix_two_producers,
}
ALL_FIXTURES = dict(SPMD_FIXTURES, **{"dynamic-control": fix_dynamic_control})


def _normalize(val) -> np.ndarray:
    """Executor-independent view of one job's result: flat concatenation of
    its chunks (Local) / stacked rows (SPMD)."""
    if isinstance(val, ChunkedData):
        return np.concatenate([np.asarray(c.data).ravel() for c in val])
    return np.asarray(val).ravel()


def _run_local(factory, mode, strategy="greedy"):
    g, reg = factory()
    ex = LocalExecutor(VirtualCluster(n_schedulers=1, max_workers=4), reg,
                      mode=mode, strategy=strategy)
    assert isinstance(ex, BaseExecutor)
    results, report = ex.run(g)
    assert isinstance(report, ExecutionReport) and report.mode == mode
    return {k: _normalize(v) for k, v in results.items()}


# ---------------------------------------------------------------------------
# the parity assertions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(ALL_FIXTURES))
def test_local_mode_parity(name):
    factory = ALL_FIXTURES[name]
    base = _run_local(factory, "sync")
    for mode in LOCAL_MODES[1:]:
        other = _run_local(factory, mode)
        assert set(other) == set(base), mode
        for job in base:
            np.testing.assert_array_equal(base[job], other[job],
                                          err_msg=f"{name}/{mode}/{job}")


@pytest.mark.parametrize("name", list(ALL_FIXTURES))
def test_cost_strategy_parity(name):
    """Placement strategy may move jobs; numerics must not change."""
    factory = ALL_FIXTURES[name]
    base = _run_local(factory, "sync")
    other = _run_local(factory, "dataflow", strategy="cost")
    for job in base:
        np.testing.assert_array_equal(base[job], other[job],
                                      err_msg=f"{name}/cost/{job}")


@pytest.mark.parametrize("name", list(SPMD_FIXTURES))
def test_spmd_parity(name):
    factory = SPMD_FIXTURES[name]
    base = _run_local(factory, "sync")
    g, reg = factory()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    ex = SpmdExecutor(mesh, reg)
    assert isinstance(ex, BaseExecutor)
    results, report = ex.run(g)
    assert isinstance(report, ExecutionReport) and report.mode == "spmd"
    assert set(results) == set(base)
    for job in base:
        np.testing.assert_allclose(_normalize(results[job]), base[job],
                                   rtol=1e-6, err_msg=f"{name}/spmd/{job}")


def test_reports_are_structurally_consistent():
    """Every mode fills the report: one SegmentReport per segment, all jobs
    accounted, byte accounting consistent with the unified summary()."""
    for mode in LOCAL_MODES:
        g, reg = fix_sliced_refs()
        ex = LocalExecutor(VirtualCluster(n_schedulers=1, max_workers=4), reg,
                           mode=mode)
        _, report = ex.run(g)
        assert len(report.segments) == len(g.segments)
        named = sorted(j for s in report.segments for j in s.jobs)
        assert named == sorted(g.names())
        assert report.moved_bytes + report.local_bytes > 0
        assert mode in report.summary()
