"""Sharding-rule properties + multi-device subprocess tests (the main
pytest process keeps 1 device; mesh cases run in children with
--xla_force_host_platform_device_count)."""
import subprocess
import sys
import textwrap

import hypothesis.strategies as st
import jax
import pytest
from hypothesis import given, settings

from repro.parallel.sharding import DEFAULT_RULES, ShardingRules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def rules(meshshape):
    return ShardingRules(mesh=FakeMesh(meshshape), rules=dict(DEFAULT_RULES))


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 4))
@settings(max_examples=60, deadline=None)
def test_spec_never_violates_divisibility(d0, d1, pick):
    """For any tensor dims, every mesh axis in the resolved spec divides the
    corresponding dim — the safety property GSPMD requires."""
    r = rules({"pod": 2, "data": 4, "model": 8})
    names = [("batch", None), ("batch", "d_ff"), ("vocab", "embed_fsdp"),
             ("heads", None), ("experts", "d_ff")][pick]
    spec = r.spec_for(names, dims=(d0, d1))
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= r.mesh.shape[a]
        assert (d0, d1)[i] % size == 0


def test_no_axis_reused_across_dims():
    r = rules({"data": 4, "model": 4})
    spec = r.spec_for(("seq", "heads"), dims=(16, 16))  # both want "model"
    axes = [a for entry in spec if entry
            for a in (entry if isinstance(entry, tuple) else (entry,))]
    assert len(axes) == len(set(axes))


def test_missing_mesh_axis_is_dropped():
    r = rules({"data": 4, "model": 4})          # no "pod"
    spec = r.spec_for(("batch", None), dims=(8, 8))
    assert spec == jax.sharding.PartitionSpec("data")


def test_param_tree_axes_cover_all_leaves():
    """Every leaf of every arch's param tree resolves to a sharding."""
    from repro.configs import ARCHS, get_smoke_config
    from repro.models.transformer import init_params
    from repro.parallel.partition import tree_logical_axes

    for arch in ARCHS[:4]:
        cfg = get_smoke_config(arch)
        params = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.random.PRNGKey(0))
        axes = tree_logical_axes(params, kind="params")
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
        assert len(flat_p) == len(flat_a)
        for leaf, ax in zip(flat_p, flat_a):
            assert len(ax) == len(leaf.shape), (arch, ax, leaf.shape)


def test_vocab_and_ff_sharded_on_model():
    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.parallel.partition import tree_logical_axes
    cfg = get_config("qwen2-1.5b")
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    axes = tree_logical_axes(params, kind="params")
    assert axes["embed"]["table"] == ("vocab", "embed_fsdp")
    up = axes["groups"][0]["mlp"]["up"]["w"]
    assert up == (None, "embed_fsdp", "d_ff")   # stacked layer dim + TP


SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import dataclasses
    from repro.models.config import ModelConfig
    from repro.models.transformer import init_params, forward
    from repro.parallel.sharding import use_rules, DEFAULT_RULES
    from repro.parallel.partition import tree_shardings
    from repro.parallel.sharding import ShardingRules
    from repro.train import TrainState, make_train_step
    from repro.optim import OptimizerSpec
    from repro.data import SyntheticLMStream, DataConfig

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=4, top_k=2, moe_d_ff=96,
                      compute_dtype="float32")
    spec = OptimizerSpec(kind="adamw", lr=1e-3)
    dc = DataConfig(global_batch=8, seq_len=32)
    stream = SyntheticLMStream(cfg, dc)
    batches = [jax.tree.map(jnp.asarray, stream.batch(s)) for s in range(3)]

    # single-device reference
    state0 = TrainState.create(cfg, spec, jax.random.PRNGKey(0))
    step0 = jax.jit(make_train_step(cfg, spec))
    s_ref = state0
    for b in batches:
        s_ref, m_ref = step0(s_ref, b)

    # 4x2 mesh (EP over model for 4 experts? model=2 divides 4: EP engaged)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4, 2), ("data", "model"))
    rules = ShardingRules(mesh=mesh, rules=dict(DEFAULT_RULES))
    with use_rules(mesh, rules.rules):
        state_struct = jax.eval_shape(
            lambda k: TrainState.create(cfg, spec, k), jax.random.PRNGKey(0))
        sh = tree_shardings(state_struct, rules, kind="state")
        step1 = jax.jit(make_train_step(cfg, spec),
                        in_shardings=(sh, None), out_shardings=(sh, None))
        s_mesh = jax.jit(lambda k: TrainState.create(cfg, spec, k),
                         out_shardings=sh)(jax.random.PRNGKey(0))
        for b in batches:
            s_mesh, m_mesh = step1(s_mesh, b)

    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(s_ref.params),
                            jax.tree.leaves(s_mesh.params)))
    assert d < 5e-3, f"param divergence {d}"
    print("MESH_PARITY_OK", d)
""")


def test_sharded_training_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SUB], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin"}, cwd=".")
    assert "MESH_PARITY_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


SPMD_EXEC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (ChunkedData, ChunkRef, FunctionRegistry, Job,
                            JobGraph, SpmdExecutor, IterativeSpec)

    reg = FunctionRegistry()
    @reg.chunkwise(1)
    def square(c):
        return c * c

    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((8,), ("data",))
    g = JobGraph()
    g.add_segment([Job("J1", 1, 0), Job("J2", 1, 0)])
    g.bind_input("J1", np.arange(16, dtype=np.float32).reshape(16, 1), n_chunks=16)
    g.bind_input("J2", np.arange(8, dtype=np.float32).reshape(8, 1), n_chunks=8)
    ex = SpmdExecutor(mesh, reg, chunk_axes=("data",))
    res, report = ex.run(g)
    assert report.mode == "spmd" and len(report.segments) == 1
    np.testing.assert_allclose(np.asarray(res["J1"]).ravel(),
                               (np.arange(16) ** 2))
    # fused while_loop iteration
    spec = IterativeSpec(body=lambda c: c * 0.5,
                         cond=lambda c: jnp.max(c) > 1.0, max_iters=100)
    final, iters = ex.run_iterative(spec, jnp.asarray([64.0]))
    assert iters == 6 and float(final[0]) == 1.0, (iters, final)
    print("SPMD_EXEC_OK")
""")


def test_spmd_executor_multidevice():
    r = subprocess.run([sys.executable, "-c", SPMD_EXEC], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin"}, cwd=".")
    assert "SPMD_EXEC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
