"""Round-trip property tests for the paper §3.3 job-file grammar:
``parse_job_text ∘ format_job_text`` is the identity on formatted text, for
whole refs, sliced refs ``R1[0..5]``, ``no_send_back`` flags and symbolic
function names — plus the malformed-input error paths."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (ChunkRef, GraphValidationError, Job, JobGraph,
                        ParallelSegment, format_job_text, parse_job_text)


def _roundtrip(graph: JobGraph) -> JobGraph:
    text = format_job_text(graph)
    parsed = parse_job_text(text)
    assert format_job_text(parsed) == text
    return parsed


def _assert_graphs_equal(a: JobGraph, b: JobGraph) -> None:
    assert len(a.segments) == len(b.segments)
    for sa, sb in zip(a.segments, b.segments):
        assert sa.names() == sb.names()
        for ja, jb in zip(sa.jobs, sb.jobs):
            assert (ja.fn, ja.n_threads, ja.inputs, ja.no_send_back) == \
                   (jb.fn, jb.n_threads, jb.inputs, jb.no_send_back), ja.name


# ---------------------------------------------------------------------------
# parametrized round trips over the grammar's feature matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("graph", [
    # minimal: one job, no inputs
    JobGraph([ParallelSegment([Job("J1", 1, 0)])]),
    # whole refs, several jobs per segment
    JobGraph([ParallelSegment([Job("J1", 1, 0), Job("J2", 2, 1)]),
              ParallelSegment([Job("J3", 3, 0,
                                   (ChunkRef("J1"), ChunkRef("J2")))])]),
    # sliced refs (paper's R1[0..5])
    JobGraph([ParallelSegment([Job("J1", 1, 0)]),
              ParallelSegment([Job("J2", 2, 2, (ChunkRef("J1", 0, 5),),
                                   no_send_back=True),
                               Job("J3", 2, 2, (ChunkRef("J1", 5, 10),),
                                   no_send_back=True)])]),
    # symbolic function names (extension) survive the trip
    JobGraph([ParallelSegment([Job("A", "sweep", 4)]),
              ParallelSegment([Job("B", "residual", 0, (ChunkRef("A"),))])]),
], ids=["minimal", "whole-refs", "sliced-refs", "symbolic-fns"])
def test_roundtrip_parametrized(graph):
    _assert_graphs_equal(graph, _roundtrip(graph))


def test_paper_sample_roundtrip_preserves_slices_and_flags():
    text = """J1(1,0,0), J2(2,1,0);
J3(2,2,R1[0..5],true), J4(2,2,R1[5..10],true), J5(3,0,R1 R2);
J7(5,1, R2 R3 R4 R5);"""
    g = parse_job_text(text)
    g2 = _roundtrip(g)
    j3 = g2.job("J3")
    assert j3.no_send_back and j3.inputs == (ChunkRef("J1", 0, 5),)
    assert not g2.job("J5").no_send_back
    assert [r.job for r in g2.job("J7").inputs] == ["J2", "J3", "J4", "J5"]


def test_comments_and_trailing_separators_are_tolerated():
    g = parse_job_text("# header comment\nJ1(1,0,0);  # inline\nJ2(1,0,R1);;")
    assert g.names() == ["J1", "J2"]
    _assert_graphs_equal(g, _roundtrip(g))


# ---------------------------------------------------------------------------
# property: random DAGs with the full feature mix survive the trip
# ---------------------------------------------------------------------------


@given(st.lists(st.lists(st.tuples(
    st.integers(1, 9),          # fn id
    st.integers(0, 4),          # n_threads
    st.booleans(),              # no_send_back
    st.integers(0, 2),          # 0 = no ref, 1 = whole ref, 2 = sliced ref
), min_size=1, max_size=4), min_size=1, max_size=5))
@settings(max_examples=40, deadline=None)
def test_roundtrip_random_graphs(spec):
    segments, counter = [], 0
    prev_names: list[str] = []
    for seg in spec:
        jobs = []
        for fid, nt, nsb, ref_kind in seg:
            counter += 1
            if prev_names and ref_kind == 1:
                deps = (ChunkRef(prev_names[counter % len(prev_names)]),)
            elif prev_names and ref_kind == 2:
                lo = counter % 3
                deps = (ChunkRef(prev_names[counter % len(prev_names)],
                                 lo, lo + 1 + counter % 4),)
            else:
                deps = ()
            jobs.append(Job(f"J{counter}", fid, nt, deps, no_send_back=nsb))
        segments.append(ParallelSegment(jobs))
        prev_names = [j.name for j in jobs]
    g = JobGraph(segments)
    _assert_graphs_equal(g, _roundtrip(g))


# ---------------------------------------------------------------------------
# malformed input error paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    "J1(1,0",                    # unbalanced parens
    "J1(1)",                     # too few args
    "J1(1,0,0,true,extra)",      # too many args
    "J1(1,0,R1[3..2x])",         # malformed slice
    "J1(1,0,0,maybe)",           # bad no_send_back literal
    "J1(1,0,Q1)",                # refs must start with R
    "(1,0,0)",                   # missing job name
], ids=["unbalanced", "few-args", "many-args", "bad-slice", "bad-flag",
        "bad-ref", "no-name"])
def test_malformed_inputs_rejected(bad):
    with pytest.raises(GraphValidationError):
        parse_job_text(bad + ";")


def test_structural_errors_surface_through_parser():
    # grammar-valid but graph-invalid: same-segment dependency
    with pytest.raises(GraphValidationError):
        parse_job_text("J1(1,0,0), J2(1,0,R1);")
    # unknown producer
    with pytest.raises(GraphValidationError):
        parse_job_text("J1(1,0,R9);")
