"""Substrate tests: optimizer, data pipeline, checkpointing, MoE invariants."""
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import DataConfig, Prefetcher, SyntheticLMStream
from repro.models.config import ModelConfig
from repro.optim import (OptimizerSpec, clip_by_global_norm, cosine_schedule,
                         global_norm, init_opt_state, opt_update)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab_size=128)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("kind", ["adamw", "adafactor", "sgd"])
def test_optimizer_decreases_quadratic(kind):
    params = {"w": jnp.zeros((256, 256)), "b": jnp.zeros((256,))}
    spec = OptimizerSpec(kind=kind, lr=0.1, clip_norm=0.0)
    state = init_opt_state(spec, params)
    losses = []
    for _ in range(120):
        g = jax.grad(quad_loss)(params)
        params, state, _ = opt_update(spec, g, state, params)
        losses.append(float(quad_loss(params)))
    assert losses[-1] < losses[0] * 0.02, f"{kind}: {losses[0]} -> {losses[-1]}"


def test_adamw_matches_reference():
    """One AdamW step against the textbook update."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, -1.5])}
    spec = OptimizerSpec(kind="adamw", lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                         clip_norm=0.0)
    st_ = init_opt_state(spec, p)
    new_p, _, _ = opt_update(spec, g, st_, p)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mh, vh = m / 0.1, v / 0.001
    ref = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)


def test_adafactor_factored_state_is_small():
    params = {"w": jnp.zeros((512, 512)), "tiny": jnp.zeros((4,))}
    spec = OptimizerSpec(kind="adafactor")
    st_ = init_opt_state(spec, params)
    f = st_["f"]
    assert set(f["w"]) == {"vr", "vc"} and f["w"]["vr"].shape == (512,)
    assert set(f["tiny"]) == {"v"}      # small leaves keep full 2nd moment


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 10}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, base_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, base_lr=1.0, warmup=10, total=100)) \
        == pytest.approx(1.0)
    assert float(cosine_schedule(100, base_lr=1.0, warmup=10, total=100)) \
        == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_host_sharded():
    dc0 = DataConfig(seed=7, global_batch=8, seq_len=16, n_hosts=2, host_id=0)
    dc1 = DataConfig(seed=7, global_batch=8, seq_len=16, n_hosts=2, host_id=1)
    s0a, s0b = SyntheticLMStream(CFG, dc0), SyntheticLMStream(CFG, dc0)
    s1 = SyntheticLMStream(CFG, dc1)
    b0a, b0b, b1 = s0a.batch(3), s0b.batch(3), s1.batch(3)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])   # reproducible
    assert not np.array_equal(b0a["tokens"], b1["tokens"])        # hosts differ
    assert b0a["tokens"].shape == (4, 16)                         # local shard
    assert b0a["tokens"].max() < CFG.vocab_size
    # labels are next-token shifted
    full = SyntheticLMStream(CFG, DataConfig(seed=1, global_batch=2, seq_len=8))
    b = full.batch(0)
    assert b["labels"].shape == b["tokens"].shape


def test_prefetcher_preserves_order():
    it = Prefetcher(iter(range(20)), depth=3)
    assert list(it) == list(range(20))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"params": {"w": jax.random.normal(k1, (8, 8)),
                       "layers": [jax.random.normal(k2, (4,)),
                                  jnp.zeros((2, 2), jnp.bfloat16)]},
            "step": jnp.asarray(17, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), tree, 17, shard_groups=3)
    assert latest_step(str(tmp_path)) == 17
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    path = save_checkpoint(str(tmp_path), tree, 1)
    shard = [f for f in os.listdir(path) if f.endswith(".npz")][0]
    with open(os.path.join(path, shard), "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad")
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), like)


def test_checkpoint_atomicity_keeps_previous(tmp_path):
    """A newer incomplete write never shadows the last complete step."""
    t1 = _tree(jax.random.PRNGKey(2))
    save_checkpoint(str(tmp_path), t1, 1)
    # simulate a crash: partial dir without LATEST bump
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t1)
    _, step = restore_checkpoint(str(tmp_path), like)
    assert step == 1


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(3))
    for s in (1, 2, 3):
        ck.save(tree, s)
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_000000002", "step_000000003"]


def test_async_checkpointer_gc_keep_zero_deletes_all(tmp_path):
    """keep=0 means retain nothing: steps[:-0] sliced to [] and silently
    kept everything instead."""
    ck = AsyncCheckpointer(str(tmp_path), keep=0)
    tree = _tree(jax.random.PRNGKey(5))
    for s in (1, 2):
        ck.save(tree, s)
    ck.wait()
    assert [d for d in os.listdir(tmp_path) if d.startswith("step_")] == []


def test_async_checkpointer_gc_retains_all_when_under_keep(tmp_path):
    """Fewer checkpoints than ``keep`` must all survive (a negative slice
    stop would wrap around and delete the oldest)."""
    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    tree = _tree(jax.random.PRNGKey(7))
    for s in (1, 2):
        ck.save(tree, s)
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_000000001", "step_000000002"]


def test_latest_step_falls_back_to_scanning_step_dirs(tmp_path):
    """A crash between the step-dir rename and the LATEST update leaves an
    empty/corrupt pointer; the restore path must scan instead of raising."""
    tree = _tree(jax.random.PRNGKey(6))
    save_checkpoint(str(tmp_path), tree, 3)
    save_checkpoint(str(tmp_path), tree, 7)
    (tmp_path / "LATEST").write_text("")            # crashed mid-write
    assert latest_step(str(tmp_path)) == 7
    (tmp_path / "LATEST").write_text("not-a-step")  # corrupt
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    _, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    # no pointer and no step dirs at all -> still None, not an exception
    empty = tmp_path / "empty"
    empty.mkdir()
    (empty / "LATEST").write_text("")
    assert latest_step(str(empty)) is None


def test_checkpoint_elastic_restore_resharded(tmp_path):
    """Restore with a sharding_fn onto the (single-device) 'new mesh'."""
    tree = _tree(jax.random.PRNGKey(4))
    save_checkpoint(str(tmp_path), tree, 5)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    dev = jax.devices()[0]
    restored, _ = restore_checkpoint(
        str(tmp_path), like,
        sharding_fn=lambda key, leaf: jax.sharding.SingleDeviceSharding(dev))
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf, jax.Array)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def _moe_cfg(E=4, k=2, cap=10.0):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                       n_experts=E, top_k=k, moe_d_ff=24,
                       capacity_factor=cap, compute_dtype="float32")


def test_moe_matches_dense_loop_reference():
    """Gather-dispatch MoE == explicit per-token loop when capacity is
    unbounded."""
    from repro.models.moe import apply_moe, init_moe
    cfg = _moe_cfg(cap=100.0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    out, aux = apply_moe(cfg, p, x)

    xt = np.asarray(x.reshape(-1, cfg.d_model), np.float64)
    logits = xt @ np.asarray(p["router"]["w"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        w = probs[t, top] / probs[t, top].sum()
        for e, wt in zip(top, w):
            g = np.tanh(0) + xt[t] @ np.asarray(p["gate"][e], np.float64)
            u = xt[t] @ np.asarray(p["up"][e], np.float64)
            h = (g / (1 + np.exp(-g))) * u          # silu(g) * u
            ref[t] += wt * (h @ np.asarray(p["down"][e], np.float64))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)), ref,
                               atol=2e-3, rtol=2e-3)
    assert np.isfinite(float(aux))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_drops_are_bounded(seed):
    from repro.models.moe import apply_moe, init_moe
    cfg = _moe_cfg(E=4, k=2, cap=1.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 32, cfg.d_model))
    out, _ = apply_moe(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()
    # capacity 1.0: each expert processes at most ceil(k*T/E) tokens; output
    # magnitude stays bounded even with drops
    assert float(jnp.max(jnp.abs(out))) < 1e3
