"""Job-model invariants (paper §2) — unit + hypothesis property tests."""
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import (ChunkedData, ChunkRef, DataChunk, GraphValidationError,
                        Job, JobGraph, ParallelSegment, format_job_text,
                        parse_job_text)


# ---------------------------------------------------------------------------
# chunking
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 200), k=st.integers(1, 32))
@settings(max_examples=50, deadline=None)
def test_chunking_partition_property(n, k):
    """from_array splits into <=k non-empty chunks that concatenate back."""
    arr = np.arange(n, dtype=np.float32)
    cd = ChunkedData.from_array(arr, min(k, n))
    assert 1 <= cd.n_chunks() <= min(k, n)
    np.testing.assert_array_equal(np.asarray(cd.to_array()), arr)
    assert all(c.n_elem > 0 for c in cd)


@given(n=st.integers(2, 64), lo=st.integers(0, 10), width=st.integers(1, 10))
@settings(max_examples=50, deadline=None)
def test_chunkref_selection(n, lo, width):
    cd = ChunkedData.from_array(np.arange(4 * n, dtype=np.float32), n)
    k = cd.n_chunks()
    lo = lo % k
    hi = min(k, lo + width)
    ref = ChunkRef("J1", lo, hi)
    sel = ref.select(cd)
    assert sel.n_chunks() == hi - lo
    np.testing.assert_array_equal(
        np.asarray(sel.to_array()),
        np.concatenate([np.asarray(cd[i].data) for i in range(lo, hi)]))


def test_chunkref_out_of_range_rejected():
    cd = ChunkedData.from_array(np.arange(10.0), 5)
    with pytest.raises(GraphValidationError):
        ChunkRef("J1", 3, 9).select(cd)
    with pytest.raises(GraphValidationError):
        ChunkRef("J1", 4, 3).select(cd)


def test_datachunk_nbytes():
    c = DataChunk(np.zeros((4, 4), np.float32))
    assert c.nbytes == 64
    assert c.n_elem == 16


# ---------------------------------------------------------------------------
# graph structure (paper §2.1 rules)
# ---------------------------------------------------------------------------


def test_same_segment_dependency_rejected():
    with pytest.raises(GraphValidationError):
        JobGraph([ParallelSegment([
            Job("J1", 1, 0),
            Job("J2", 1, 0, (ChunkRef("J1"),)),
        ])])


def test_forward_dependency_rejected():
    g = JobGraph()
    g.add_segment([Job("J1", 1, 0, (ChunkRef("J2"),))]) if False else None
    with pytest.raises(GraphValidationError):
        JobGraph([
            ParallelSegment([Job("J1", 1, 0, (ChunkRef("J2"),))]),
            ParallelSegment([Job("J2", 1, 0)]),
        ])


def test_duplicate_names_rejected():
    with pytest.raises(GraphValidationError):
        JobGraph([ParallelSegment([Job("J1", 1, 0), Job("J1", 2, 0)])])


def test_dynamic_jobs_cannot_target_past():
    g = JobGraph([ParallelSegment([Job("J1", 1, 0)]),
                  ParallelSegment([Job("J2", 1, 0, (ChunkRef("J1"),))])])
    with pytest.raises(GraphValidationError):
        g.add_dynamic(Job("J3", 1, 0), 0, current=1)
    g.add_dynamic(Job("J3", 1, 0, (ChunkRef("J1"),)), 2, current=1)
    assert g.segment_of("J3") == 2


def test_hybrid_classification():
    # strict: one segment has >1 job and a multi-sequence job (n_threads!=1)
    g = JobGraph([ParallelSegment([Job("J1", 1, 0), Job("J2", 1, 1)])])
    assert g.is_hybrid() == (True, "strict")
    # loose: multi-job segment and multi-thread job in different segments
    g2 = JobGraph([
        ParallelSegment([Job("J1", 1, 1), Job("J2", 2, 1)]),
        ParallelSegment([Job("J3", 3, 4, (ChunkRef("J1"),))]),
    ])
    assert g2.is_hybrid() == (True, "loose")
    # purely sequential
    g3 = JobGraph([ParallelSegment([Job("J1", 1, 1)])])
    assert g3.is_hybrid()[0] is False


def test_negative_threads_rejected():
    with pytest.raises(GraphValidationError):
        Job("J1", 1, -1)


# ---------------------------------------------------------------------------
# parser (paper §3.3 format)
# ---------------------------------------------------------------------------

PAPER_SAMPLE = """J1(1,0,0), J2(2,1,0);
J3(2,2,R1[0..5],true), J4(2,2,R1[5..10],true), J5(3,0,R1 R2),
 J6(4,0,R1 R2);
J7(5,1, R2 R3 R4 R5);"""


def test_paper_sample_parses():
    g = parse_job_text(PAPER_SAMPLE)
    assert len(g.segments) == 3
    assert g.segments[0].names() == ["J1", "J2"]
    assert g.segments[1].names() == ["J3", "J4", "J5", "J6"]
    j3 = g.job("J3")
    assert j3.fn == 2 and j3.n_threads == 2 and j3.no_send_back
    assert j3.inputs == (ChunkRef("J1", 0, 5),)
    j5 = g.job("J5")
    assert j5.inputs == (ChunkRef("J1"), ChunkRef("J2"))
    assert not j5.no_send_back
    j7 = g.job("J7")
    assert [r.job for r in j7.inputs] == ["J2", "J3", "J4", "J5"]


def test_parser_round_trip():
    g = parse_job_text(PAPER_SAMPLE)
    text = format_job_text(g)
    g2 = parse_job_text(text)
    assert format_job_text(g2) == text


@given(st.lists(st.lists(st.tuples(
    st.integers(1, 9), st.integers(0, 4), st.booleans()),
    min_size=1, max_size=4), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_parser_round_trip_random_graphs(spec):
    """Random DAGs (each job depends on one job of the previous segment)
    survive a format -> parse -> format round trip."""
    segments, counter = [], 0
    prev_names: list[str] = []
    for seg in spec:
        jobs = []
        for fid, nt, nsb in seg:
            counter += 1
            deps = (ChunkRef(prev_names[counter % len(prev_names)]),) \
                if prev_names else ()
            jobs.append(Job(f"J{counter}", fid, nt, deps, no_send_back=nsb))
        segments.append(ParallelSegment(jobs))
        prev_names = [j.name for j in jobs]
    g = JobGraph(segments)
    text = format_job_text(g)
    assert format_job_text(parse_job_text(text)) == text


def test_parser_rejects_garbage():
    for bad in ["J1(1,0", "J1(1)", "J1(1,0,R1[3..2x])", "J1(1,0,0,maybe)"]:
        with pytest.raises(GraphValidationError):
            parse_job_text(bad + ";")
