"""Standalone master process for the crash-soak test.

Runs one ProcessExecutor pass of the ``repro.apps.procdemo`` graph against
a durable store.  Must be a real file (not ``python -c``/stdin): spawn
children re-resolve ``__main__`` from its path, so an inline script would
crash every worker at boot.  The soak test launches this under
``REPRO_PROCDEMO_SLEEP`` and SIGKILLs it mid-run.
"""
import sys


def main() -> None:
    store = sys.argv[1]
    width, depth, dim, seed = (int(a) for a in sys.argv[2:6])
    from repro.apps import procdemo
    from repro.core import ProcessExecutor, VirtualCluster

    ex = ProcessExecutor(VirtualCluster(n_schedulers=1, max_workers=2),
                         procdemo.make_registry(), procdemo.WORKER_FNS_SPEC,
                         store=store, heartbeat_interval_s=0.1,
                         heartbeat_max_missed=3)
    try:
        ex.run(procdemo.build_graph(width=width, depth=depth, dim=dim,
                                    seed=seed))
        print("DONE", ex.n_executed, ex.n_memoised, flush=True)
    finally:
        ex.close()


if __name__ == "__main__":
    main()
