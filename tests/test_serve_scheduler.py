"""Request-level serving scheduler: admission control, slot lifecycle,
HyPar dynamic-job integration and KV fault invalidation (DESIGN.md §8)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.job import GraphValidationError
from repro.models.transformer import init_params
from repro.serve import (Engine, HyParRequestTracker, Request, RequestQueue,
                         SamplingParams, ServeScheduler)


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              compute_dtype="float32")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)


def test_queue_admission_control():
    q = RequestQueue(max_pending=2)
    reqs = [Request(rid=q.next_rid(), tokens=np.zeros(4, np.int32), max_new=2)
            for _ in range(3)]
    assert q.submit(reqs[0]) and q.submit(reqs[1])
    assert not q.submit(reqs[2])            # shed, not queued
    assert q.n_rejected == 1 and len(q) == 2
    q.push_front(reqs[2])                   # fault requeue bypasses admission
    assert len(q) == 3 and q.pop().rid == reqs[2].rid


def test_scheduler_rejects_unplaceable_requests(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, batch=2, max_len=32)
    sched = ServeScheduler(eng, buckets=(8, 16))
    rng = np.random.default_rng(0)
    assert sched.submit(_prompt(rng, cfg, 30), max_new=4) is None   # no bucket
    assert sched.submit(_prompt(rng, cfg, 8), max_new=64) is None   # > max_len
    assert sched.queue.n_rejected == 2
    assert sched.submit(_prompt(rng, cfg, 8), max_new=4) is not None


def test_oversized_bucket_is_clamped_not_dropped(qwen):
    """A prompt whose next bucket exceeds max_len must still be placeable
    when prompt + budget fit the cache: the bucket is clamped to max_len,
    not silently dropped (which shed every such request)."""
    cfg, params = qwen
    eng = Engine(cfg, params, batch=2, max_len=52)
    sched = ServeScheduler(eng, buckets=(8, 16, 64))
    assert sched.buckets == (8, 16, 52)
    rng = np.random.default_rng(6)
    rid = sched.submit(_prompt(rng, cfg, 40), max_new=4)
    assert rid is not None
    results = sched.run()
    assert [r.rid for r in results] == [rid]
    assert results[0].n_generated == 4


def test_trace_replay_sheds_unplaceable_requests(qwen):
    """run(requests) must apply the same admission check as submit() — an
    oversized replayed request is shed, not crashed on (bucket=None)."""
    cfg, params = qwen
    eng = Engine(cfg, params, batch=2, max_len=24)
    sched = ServeScheduler(eng, buckets=(8,))
    rng = np.random.default_rng(7)
    reqs = [Request(rid=0, tokens=_prompt(rng, cfg, 20), max_new=2),   # no bucket
            Request(rid=1, tokens=_prompt(rng, cfg, 6), max_new=3)]
    results = sched.run(reqs)
    assert [r.rid for r in results] == [1]
    assert sched.queue.n_rejected == 1


def test_scheduler_drains_mixed_lengths_and_matches_standalone(qwen):
    """Six mixed-length requests over two slots: every request's output must
    equal the same prompt decoded in a standalone engine — per-slot
    positions survive insertion into a batch that is mid-decode."""
    cfg, params = qwen
    B, max_new = 2, 5
    eng = Engine(cfg, params, batch=B, max_len=64)
    sched = ServeScheduler(eng, buckets=(8, 16))
    rng = np.random.default_rng(1)
    prompts = [_prompt(rng, cfg, n) for n in (5, 8, 3, 11, 7, 4)]
    rids = [sched.submit(p, max_new=max_new) for p in prompts]
    assert all(r is not None for r in rids)
    results = {r.rid: r for r in sched.run()}
    assert sorted(results) == sorted(rids)
    assert sched.occupancy > 0.5

    for rid, prompt in zip(rids, prompts):
        res = results[rid]
        assert res.n_generated == max_new
        assert res.prompt_len == len(prompt)
        # standalone reference: same batch width, prompt replicated, so the
        # decode program (and row-wise arithmetic) is identical
        ref = Engine(cfg, params, batch=B, max_len=64)
        want = ref.generate(jnp.asarray(np.tile(prompt, (B, 1))),
                            max_new=max_new)[0]
        assert res.tokens == want.tolist(), (
            f"rid {rid} (prompt_len {len(prompt)}) diverged from standalone")


def test_scheduler_timestamps_are_ordered(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, batch=2, max_len=48)
    sched = ServeScheduler(eng, buckets=(8,))
    rng = np.random.default_rng(2)
    for _ in range(3):
        sched.submit(_prompt(rng, cfg, 6), max_new=3)
    for r in sched.run():
        assert r.ttft_s >= 0.0
        assert all(l >= 0.0 for l in r.step_latencies_s)
        assert r.token_s == sorted(r.token_s)
        assert r.finish_s >= r.token_s[-1]


def test_hypar_tracker_matches_direct_and_uses_job_model(qwen):
    cfg, params = qwen
    B, max_new = 2, 4
    rng = np.random.default_rng(3)
    prompts = [_prompt(rng, cfg, n) for n in (6, 4, 7, 5)]

    def run(tracker):
        eng = Engine(cfg, params, batch=B, max_len=48)
        sched = ServeScheduler(eng, buckets=(8,), tracker=tracker)
        rids = [sched.submit(p, max_new=max_new) for p in prompts]
        return rids, {r.rid: r.tokens for r in sched.run()}, sched

    _, direct, _ = run(None)
    tracker = HyParRequestTracker(B, strategy="cost", flops_per_token=1e6)
    rids, hypar, sched = run(tracker)
    # placement must not change results, only bookkeeping
    assert direct == hypar
    # every request went through the job model and was retired again
    assert tracker.graph.n_jobs() == 0
    assert len(tracker.store.records) == len(prompts)
    assert all(rec.data is None for rec in tracker.store.records.values())
    # results were retained worker-local (no_send_back), never sent back
    assert all(not rec.sent_back for rec in tracker.store.records.values())
    # decode timings fed the cost model's EWMA
    assert tracker.master._fn_time.get(tracker.DECODE_FN, 0.0) > 0.0


def test_hypar_fault_invalidates_kv_and_recovers(qwen):
    """Killing a slot mid-decode loses its retained KV; the request restarts
    from its prompt and still completes — the serving instance of the
    DESIGN §6 recovery contract."""
    cfg, params = qwen
    B = 2
    rng = np.random.default_rng(4)
    tracker = HyParRequestTracker(B, strategy="greedy")
    eng = Engine(cfg, params, batch=B, max_len=48)
    sched = ServeScheduler(eng, buckets=(8,), tracker=tracker)
    prompts = [_prompt(rng, cfg, 6) for _ in range(3)]
    rids = [sched.submit(p, max_new=6) for p in prompts]

    assert sched.step()                     # slots filled, one decode step
    victim_rid = sched.slots[0].request.rid
    old_wid = tracker.slot_to_wid[0]
    failed_rid = sched.fail_slot(0)
    assert failed_rid == victim_rid
    # the dead worker released its cluster slot; a replacement took over
    assert not tracker.cluster.workers[old_wid].alive
    assert tracker.slot_to_wid[0] != old_wid
    assert tracker.n_recovered == 1

    results = {r.rid: r for r in sched.run()}
    assert sorted(results) == sorted(rids)  # victim re-ran to completion
    # and its rerun output matches the same prompt run standalone
    victim_prompt = prompts[rids.index(victim_rid)]
    ref = Engine(cfg, params, batch=B, max_len=48)
    want = ref.generate(jnp.asarray(np.tile(victim_prompt, (B, 1))),
                        max_new=6)[0]
    assert results[victim_rid].tokens == want.tolist()


def test_remove_job_guards_consumers():
    from repro.core.job import ChunkRef, Job, JobGraph, ParallelSegment
    g = JobGraph([ParallelSegment([Job("J1", fn=1)]),
                  ParallelSegment([Job("J2", fn=2,
                                       inputs=(ChunkRef("J1"),))])])
    with pytest.raises(GraphValidationError, match="consumed"):
        g.remove_job("J1")
    g.remove_job("J2")
    g.remove_job("J1")                      # consumer gone -> now legal
    assert g.n_jobs() == 0
    with pytest.raises(GraphValidationError):
        g.remove_job("J1")
