"""Paged KV cache + chunked prefill (DESIGN.md §9): allocator invariants,
paged-vs-dense decode parity, chunked-vs-full prefill parity, surviving-slot
isolation, admission by free pages, bounded compiles, batched placement."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_params
from repro.serve import (Engine, HyParRequestTracker, PagedEngine,
                         PageAllocator, ServeScheduler, chunk_buckets_for,
                         chunk_plan)


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.fixture(scope="module")
def qwen():
    cfg = _fp32(get_smoke_config("qwen2-1.5b"))
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size - 1, (n,)).astype(np.int32)


# ---------------------------------------------------------------------------
# Allocator + chunk planning units (no jax)
# ---------------------------------------------------------------------------


def test_page_allocator_exhaustion_free_and_no_aliasing():
    a = PageAllocator(8)                    # page 0 reserved -> 7 usable
    assert a.n_free == 7
    p1 = a.alloc(3)
    p2 = a.alloc(4)
    assert a.alloc(1) is None               # exhausted -> admission refusal
    # no aliasing: every outstanding page is unique and never the trash page
    assert len(set(p1) | set(p2)) == 7
    assert 0 not in p1 + p2
    a.free(p1)
    assert a.n_free == 3
    with pytest.raises(ValueError):         # double free refused
        a.free(p1)
    p3 = a.alloc(3)
    assert set(p3) == set(p1)               # recycled, still unique
    a.free(p2 + p3)
    assert a.n_free == 7 and a.n_outstanding == 0


def test_chunk_plan_is_page_aligned():
    buckets = chunk_buckets_for(64, 16)
    assert buckets == (16, 32, 64)
    assert chunk_plan(70, 64, buckets) == [(0, 64, 64), (64, 16, 6)]
    assert chunk_plan(64, 64, buckets) == [(0, 64, 64)]
    assert chunk_plan(5, 64, buckets) == [(0, 16, 5)]
    for true_len in (1, 17, 64, 65, 130):
        plan = chunk_plan(true_len, 64, buckets)
        assert all(start % 16 == 0 and blen % 16 == 0
                   for start, blen, _ in plan)
        assert sum(v for _, _, v in plan) == true_len
    with pytest.raises(ValueError):
        chunk_plan(0, 64, buckets)


# ---------------------------------------------------------------------------
# Parity: paged + chunked vs dense, end to end
# ---------------------------------------------------------------------------


# tier-1 archs: qwen2 (dense attention / paged KV pool) and mamba2 (SSM
# state continuation across chunks; no attention pool at all)
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m"])
def test_paged_scheduler_matches_dense(arch):
    """The same mixed-length request set through a dense engine and a paged
    engine (multi-chunk prefills included) must produce identical tokens for
    every request."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    # 40 > prefill_chunk => multi-chunk; 5/12 => single bucket chunks
    prompts = [_prompt(rng, cfg, n) for n in (5, 40, 12, 23)]

    def run(engine):
        sched = ServeScheduler(engine, buckets=(8, 16, 32, 64))
        rids = [sched.submit(p, max_new=6) for p in prompts]
        assert all(r is not None for r in rids)
        results = {r.rid: r.tokens for r in sched.run()}
        return [results[r] for r in rids]

    dense = run(Engine(cfg, params, batch=2, max_len=64))
    paged = run(PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                            prefill_chunk=16))
    assert dense == paged


def test_chunked_prefill_logits_match_full_prefill(qwen):
    """First-token logits of a 3-chunk paged insert vs a one-shot dense
    prefill of the same prompt."""
    cfg, params = qwen
    rng = np.random.default_rng(2)
    prompt = _prompt(rng, cfg, 40)
    ref = Engine(cfg, params, batch=1, max_len=64)
    want = np.asarray(ref.prefill(jnp.asarray(prompt[None])))

    pe = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                     prefill_chunk=16)
    alloc = PageAllocator(pe.num_pages)
    pages = alloc.alloc(pe.pages_needed(len(prompt), 4))
    got = np.asarray(pe.insert(0, prompt, page_ids=pages, max_new=4))
    assert pe.trace_count("chunk_prefill") >= 2      # actually chunked
    np.testing.assert_allclose(got[0], want[0], atol=1e-4, rtol=1e-4)


def test_paged_insert_preserves_surviving_slots(qwen):
    """Mid-decode insert into a freed slot: the surviving slots' tokens are
    bit-identical to an uninterrupted run — chunk writes land only in the
    inserting slot's own pages (PR-3 parity guarantee under paging)."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    B, steps = 3, 8
    prompts = [_prompt(rng, cfg, 8) for _ in range(B)]
    newcomer = _prompt(rng, cfg, 21)                 # multi-chunk insert

    def run(insert_at):
        eng = PagedEngine(cfg, params, batch=B, max_len=64, page_size=8,
                          prefill_chunk=16)
        alloc = PageAllocator(eng.num_pages)
        slot_pages = []
        toks = np.zeros(B, np.int32)
        for b, p in enumerate(prompts):
            pages = alloc.alloc(eng.pages_needed(len(p), steps + 1))
            slot_pages.append(pages)
            lg = eng.insert(b, p, page_ids=pages, max_new=steps + 1)
            toks[b] = int(jnp.argmax(lg[0, -1]))
        outs = [toks.copy()]
        for i in range(steps):
            if insert_at is not None and i == insert_at:
                alloc.free(slot_pages[1])
                eng.free_slot(1)
                pages = alloc.alloc(eng.pages_needed(len(newcomer), steps))
                lg = eng.insert(1, newcomer, page_ids=pages, max_new=steps)
                toks = toks.copy()
                toks[1] = int(jnp.argmax(lg[0, -1]))
            lg = eng.decode(jnp.asarray(toks)[:, None])
            toks = np.asarray(jnp.argmax(lg[:, -1, :], -1), np.int32)
            outs.append(toks)
        return np.stack(outs, axis=1)

    base = run(None)
    mixed = run(3)
    assert np.array_equal(base[0], mixed[0])
    assert np.array_equal(base[2], mixed[2])
    assert not np.array_equal(base[1], mixed[1])


@pytest.mark.parametrize("arch", ["mamba2-370m", "qwen2-1.5b"])
def test_chunked_prefill_immune_to_interleaved_decode(arch):
    """Decode steps of the live batch between the chunks of a mid-prefill
    slot must not perturb that slot's state: attention K/V is parked on the
    trash page, and the live-mask freezes the dense per-slot SSM buffers.
    Logits-level check — token equality alone missed this (tiny smoke
    logit perturbations rarely flip the argmax)."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(10)
    short = _prompt(rng, cfg, 6)
    long = _prompt(rng, cfg, 40)                    # 3 chunks at 16

    def setup():
        eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                          prefill_chunk=16)
        alloc = PageAllocator(eng.num_pages)
        pg0 = alloc.alloc(eng.pages_needed(len(short), 8))
        lg = eng.insert(0, short, page_ids=pg0, max_new=8)
        tok = np.array([[int(jnp.argmax(lg[0, -1]))], [0]], np.int32)
        pages = alloc.alloc(eng.pages_needed(len(long), 4))
        return eng, pages, tok

    # reference: chunks back-to-back, no decode in between
    eng, pages, _ = setup()
    want = np.asarray(eng.insert(1, long, page_ids=pages, max_new=4))

    # interleaved: one live-batch decode step between each chunk, the
    # mid-prefill slot masked out exactly as ServeScheduler does
    eng, pages, tok = setup()
    got = None
    for start, blen, vlen in chunk_plan(len(long), eng.chunk_len,
                                        eng.chunk_buckets):
        ck = np.zeros((1, blen), np.int32)
        ck[0, :vlen] = long[start:start + vlen]
        got = eng.prefill_chunk(1, ck, pages, start, vlen)
        eng.decode(jnp.asarray(tok), live_mask=np.array([True, False]))
    eng.commit_slot(1, pages)
    np.testing.assert_allclose(np.asarray(got)[0], want[0],
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("arch", ["mamba2-370m", "qwen2-1.5b"])
def test_paged_slot_reuse_resets_state(arch):
    """A request inserted into a freed slot must see none of the previous
    occupant's state: attention skips the cache read on the first chunk,
    and the SSM path resets the slot's conv tail + SSD state to the
    fresh-prefill zeros (there is no splice step to replace them)."""
    cfg = _fp32(get_smoke_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    a, b = _prompt(rng, cfg, 20), _prompt(rng, cfg, 9)

    def insert_b(eng, alloc):
        pages = alloc.alloc(eng.pages_needed(len(b), 4))
        return np.asarray(eng.insert(0, b, page_ids=pages, max_new=4))

    fresh = PagedEngine(cfg, params, batch=1, max_len=48, page_size=8,
                        prefill_chunk=16)
    alloc = PageAllocator(fresh.num_pages)
    want = insert_b(fresh, alloc)

    used = PagedEngine(cfg, params, batch=1, max_len=48, page_size=8,
                       prefill_chunk=16)
    alloc = PageAllocator(used.num_pages)
    pages = alloc.alloc(used.pages_needed(len(a), 6))
    lg = used.insert(0, a, page_ids=pages, max_new=6)
    tok = np.array([[int(jnp.argmax(lg[0, -1]))]], np.int32)
    for _ in range(3):
        lg = used.decode(jnp.asarray(tok))
        tok = np.asarray(jnp.argmax(lg[:, -1, :], -1), np.int32)[:, None]
    alloc.free(pages)
    used.free_slot(0)
    got = insert_b(used, alloc)
    np.testing.assert_allclose(got[0], want[0], atol=1e-5, rtol=1e-5)


def test_dense_insert_masks_ssm_padding():
    """Regression for the dense insert path: a bucketed (right-padded)
    prompt into an SSM engine must produce the same tokens as the unpadded
    prompt — pad tokens must not decay into the state or the conv tail."""
    cfg = _fp32(get_smoke_config("mamba2-370m"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, cfg, 5)

    eng = Engine(cfg, params, batch=2, max_len=32)
    eng.prefill(jnp.asarray(np.stack([_prompt(rng, cfg, 8)] * 2)))
    padded = np.zeros((1, 8), np.int32)
    padded[0, :5] = prompt
    lg = eng.insert(0, jnp.asarray(padded), true_len=5)

    ref = Engine(cfg, params, batch=1, max_len=32)
    want = ref.prefill(jnp.asarray(prompt[None]))
    np.testing.assert_allclose(np.asarray(lg)[0], np.asarray(want)[0],
                               atol=1e-4, rtol=1e-4)


def test_gather_pages_masks_trash_page_garbage(qwen):
    """Regression for the trash-page contract (DESIGN.md §15): table entry
    0 is the reserved trash page — free slots, the unwritten tail of every
    slot's table row, and mid-prefill chunk writes all point there, so its
    contents are arbitrary.  ``gather_pages`` must ZERO rows gathered from
    page 0 rather than trust the kv_len mask alone: mask-by-addition turns
    NaN/Inf garbage into NaN scores that survive the softmax even at
    masked positions.  Logits-level, bit-exact — the clean and the
    NaN-poisoned pool must decode identically."""
    cfg, params = qwen
    rng = np.random.default_rng(11)
    prompt = _prompt(rng, cfg, 6)

    def run(poison):
        eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                          prefill_chunk=16)
        alloc = PageAllocator(eng.num_pages)
        pages = alloc.alloc(eng.pages_needed(len(prompt), 4))
        lg = eng.insert(0, prompt, page_ids=pages, max_new=4)
        if poison:
            for part in ("groups", "tail"):
                for bc in eng.cache[part]:
                    if isinstance(bc, dict) and "self" in bc:
                        for key in ("k", "v"):
                            pool = bc["self"][key]
                            idx = ((slice(None), 0) if pool.ndim == 5
                                   else (0,))
                            bc["self"][key] = pool.at[idx].set(jnp.nan)
        tok = np.array([[int(jnp.argmax(lg[0, -1]))], [0]], np.int32)
        out = []
        for _ in range(3):
            lg = eng.decode(jnp.asarray(tok),
                            live_mask=np.array([True, False]))
            out.append(np.asarray(lg[0]))
            tok = np.array([[int(jnp.argmax(lg[0, -1]))], [0]], np.int32)
        return np.stack(out)

    clean, poisoned = run(False), run(True)
    assert np.isfinite(clean).all()
    assert np.array_equal(clean, poisoned), \
        "trash-page garbage leaked into decode logits"


# ---------------------------------------------------------------------------
# Scheduler: admission by pages, bounded compiles, batched placement
# ---------------------------------------------------------------------------


def test_page_exhaustion_defers_admission_until_retire(qwen):
    """A pool too small for two concurrent requests serialises them instead
    of shedding: the second request waits for the first retirement's pages."""
    cfg, params = qwen
    rng = np.random.default_rng(5)
    # each request needs ceil((16+4)/8) = 3 pages; pool has 4 usable
    eng = PagedEngine(cfg, params, batch=2, max_len=32, page_size=8,
                      prefill_chunk=16, num_pages=5)
    sched = ServeScheduler(eng, buckets=(16,))
    rids = [sched.submit(_prompt(rng, cfg, 10), max_new=4) for _ in range(3)]
    assert all(r is not None for r in rids)          # all admitted (queued)
    results = sched.run()
    assert sorted(r.rid for r in results) == sorted(rids)
    assert all(r.n_generated == 4 for r in results)
    assert sched.queue.n_rejected == 0
    # every page came back
    assert sched.allocator.n_outstanding == 0
    # with 3 pages/request and 4 free, the batch=2 engine never ran both
    # slots at once: concurrency was page-bound, not slot-bound
    assert sched.occupancy <= 0.75


def test_paged_never_fits_is_shed(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(6)
    eng = PagedEngine(cfg, params, batch=2, max_len=32, page_size=8,
                      prefill_chunk=16)
    sched = ServeScheduler(eng)
    assert sched.submit(_prompt(rng, cfg, 30), max_new=8) is None  # > max_len
    assert sched.queue.n_rejected == 1


def test_paged_compile_counts_bounded(qwen):
    """N mixed-length requests compile one chunk-prefill program per chunk
    bucket and ONE decode program — compiles are workload-independent."""
    cfg, params = qwen
    rng = np.random.default_rng(7)
    eng = PagedEngine(cfg, params, batch=2, max_len=64, page_size=8,
                      prefill_chunk=16)                # buckets (8, 16)
    sched = ServeScheduler(eng)
    for n in (5, 12, 7, 20, 3, 40, 9, 14):
        assert sched.submit(_prompt(rng, cfg, n), max_new=4) is not None
    results = sched.run()
    assert len(results) == 8
    assert eng.trace_count("chunk_prefill") == len(eng.chunk_buckets) == 2
    assert eng.trace_count("decode") == 1


def test_admission_wave_issues_single_plan_segment_call(qwen):
    """Batched HyPar placement: one fill wave of N requests = ONE
    plan_segment call (PR 3 issued one per request — the ~25% serve
    overhead the ROADMAP flagged)."""
    from repro.core.scheduler import MasterScheduler
    cfg, params = qwen
    rng = np.random.default_rng(8)
    tracker = HyParRequestTracker(4, strategy="greedy")
    calls = []
    orig = tracker.master.plan_segment

    def counting(jobs, store, **kw):
        calls.append(len(jobs))
        return orig(jobs, store, **kw)

    tracker.master.plan_segment = counting
    eng = Engine(cfg, params, batch=4, max_len=32)
    sched = ServeScheduler(eng, buckets=(8,), tracker=tracker)
    rids = [sched.submit(_prompt(rng, cfg, 6), max_new=3) for _ in range(4)]
    results = sched.run()
    assert sorted(r.rid for r in results) == sorted(rids)
    assert calls[0] == 4                     # the whole wave in one call
    assert len(calls) == 1
    # and the graph/store were cleaned up per-request as before
    assert tracker.graph.n_jobs() == 0


def test_paged_hypar_tracker_matches_direct(qwen):
    """Placement through the job machinery must not change paged results."""
    cfg, params = qwen
    rng = np.random.default_rng(9)
    prompts = [_prompt(rng, cfg, n) for n in (6, 20, 7, 5)]

    def run(tracker):
        eng = PagedEngine(cfg, params, batch=2, max_len=48, page_size=8,
                          prefill_chunk=16)
        sched = ServeScheduler(eng, tracker=tracker)
        rids = [sched.submit(p, max_new=4) for p in prompts]
        return rids, {r.rid: r.tokens for r in sched.run()}

    _, direct = run(None)
    _, hypar = run(HyParRequestTracker(2, strategy="cost",
                                       flops_per_token=1e6))
    assert direct == hypar
