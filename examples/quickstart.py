"""Quickstart: the paper's own example (§2.2) — find the maximum of an
array with chunked jobs — using the public HyPar API, including the
paper's plain-text job-file format (§3.3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (ChunkedData, ChunkRef, FunctionRegistry, Job,
                        JobGraph, LocalExecutor, VirtualCluster,
                        parse_job_text)

# 1. register user functions (paper §3.2 — 'fat workers' hold all functions)
reg = FunctionRegistry()


@reg.chunkwise(1)                      # fn id 1: runs once per data chunk
def search_max(chunk):
    return jnp.max(chunk)


@reg.whole(2)                          # fn id 2: sees all chunks assembled
def combine_max(*inputs):
    vals = [a for cd in inputs for a in cd.arrays()]
    return ChunkedData.from_arrays([jnp.max(jnp.stack(vals))])


# 2. describe the algorithm — two parallel jobs, then a combiner.  This is
#    the paper's job-file syntax: fn id, n_threads (0 = all cores), inputs.
graph = parse_job_text("""
  J1(1,0,0), J2(1,0,0);          # segment 1: search chunks in parallel
  J3(2,1,R1 R2);                 # segment 2: combine both results
""")

# 3. bind the input data as chunks (paper: "input data ... in amount of chunks")
A = np.random.default_rng(0).standard_normal(1000).astype(np.float32)
graph.bind_input("J1", A[:600], n_chunks=6)
graph.bind_input("J2", A[600:], n_chunks=4)

# 4. run — the framework handles placement, transfers and synchronisation
cluster = VirtualCluster(n_schedulers=2, cores_per_worker=4, max_workers=4)
results, report = LocalExecutor(cluster, reg).run(graph)

print("maximum found:", float(results["J3"].to_array()))
print("numpy says:   ", float(A.max()))
print("execution:    ", report.summary())
print("hybrid class: ", graph.is_hybrid()[1])
