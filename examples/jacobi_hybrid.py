"""The paper's §4 experiment: a Jacobi solver parallelised through the
framework vs the tailored implementation, at demo scale.

Run:  PYTHONPATH=src python examples/jacobi_hybrid.py [n]
"""
import sys

import numpy as np

from repro.apps.jacobi import (jacobi_hypar, jacobi_spmd, jacobi_tailored,
                               make_system)

n = int(sys.argv[1]) if len(sys.argv) > 1 else 1024
A, b, x_true = make_system(n)
print(f"solving {n}x{n} diagonally-dominant system, 200 iterations\n")

for name, fn in [("tailored (fused while_loop)", jacobi_tailored),
                 ("HyPar job graph (paper)", jacobi_hypar),
                 ("HyPar SPMD-fused (beyond paper)", jacobi_spmd)]:
    r = fn(A, b, iters=200, tol=1e-5)
    err = np.max(np.abs(r.x - x_true))
    print(f"{name:34s} iters={r.iters:3d} residual={r.residual:.2e} "
          f"err={err:.2e} time={r.seconds*1e3:8.1f}ms")
