"""Serving examples on a reduced config of an assigned architecture.

Wave mode (batched prefill + decode, continuous batching demo)::

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]

Request-trace mode (Poisson arrivals, mixed prompt lengths, HyPar
dynamic-job scheduling)::

    PYTHONPATH=src python examples/serve_lm.py --trace --engine hypar
"""
import sys

from repro.launch.serve import main

args = ["--arch", "qwen2-1.5b", "--smoke", "--batch", "4",
        "--prompt-len", "16", "--max-new", "16", "--requests", "2"]
extra = sys.argv[1:]
if "--trace" in extra:
    args = ["--arch", "qwen2-1.5b", "--smoke", "--batch", "4",
            "--max-new", "12", "--n-requests", "8",
            "--prompt-lens", "6", "10", "14"]
main(args + extra)
