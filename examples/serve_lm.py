"""Batched serving example: prefill + decode with continuous batching on a
reduced config of an assigned architecture.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]
"""
import sys

from repro.launch.serve import main

args = ["--arch", "qwen2-1.5b", "--smoke", "--batch", "4",
        "--prompt-len", "16", "--max-new", "16", "--requests", "2"]
main(args + sys.argv[1:])
