"""End-to-end training driver: a ~100M-parameter qwen2-family model for a
few hundred steps on synthetic data, with checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py  (add --steps 300 for the
full run; defaults stay small so the example finishes quickly on CPU)
"""
import sys

from repro.launch.train import main

args = ["--arch", "qwen2-1.5b", "--layers", "8", "--d-model", "768",
        "--steps", "60", "--batch", "8", "--seq", "256",
        "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50",
        "--log-every", "10"]
main(args + sys.argv[1:])
