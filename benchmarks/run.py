"""Benchmark harness entry point — one CLI over every sub-benchmark.

    python -m benchmarks.run [--suite kernels|jacobi|hypar|all]
                             [--paper] [--smoke]

Each suite writes a ``BENCH_<suite>.json`` file at the repo root with a
stable schema (the perf trajectory the ROADMAP tracks)::

    {"schema_version": 1,
     "rows": [{"name": ..., "backend": ..., "shape": [...], "dtype": ...,
               "median_s": ..., "bytes": ..., "flops": ..., ...}, ...]}

Suites:

  kernels — per-kernel reference timings + the autotune pass
            (``kernel_bench``): populates the persistent tuning cache, so
            a second run reuses tuned configs without re-timing (rows
            carry ``cache: hit|miss``).  -> BENCH_kernels.json
  jacobi  — the paper's Fig. 3 (framework vs tailored Jacobi, fused
            single-matvec iterations; ``--paper`` for the full
            2709/4209/7209 × 500 table).  -> BENCH_jacobi.json
  hypar   — framework-vs-tailored on the LM training workload.
            -> BENCH_hypar.json
  serve   — request-level continuous batching (Poisson trace, mixed
            prompt lengths) for --engine direct AND hypar: tok/s, TTFT,
            p50/p95 per-token latency, slot occupancy.
            -> BENCH_serve.json

``--smoke`` shrinks every suite to CI-sized shapes (used by the
benchmark-smoke CI step, which uploads the BENCH_*.json artifacts).
With ``--suite all`` the dry-run roofline table
(``benchmarks/results/dryrun.jsonl``, if present) is summarised as well.
"""
from __future__ import annotations

import argparse
import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA_VERSION = 1


def _write(filename: str, rows: list[dict]) -> None:
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "rows": rows}, f,
                  indent=1)
    print(f"-> wrote {path} ({len(rows)} rows)")


def suite_kernels(*, smoke: bool = False) -> list[dict]:
    print("== kernels (ref timings + autotune) ==")
    from . import kernel_bench
    rows = kernel_bench.run(smoke=smoke)
    for r in rows:
        extra = (f"  config={r['config']} cache={r['cache']}"
                 if "config" in r else "")
        print(f"  {r['name']:>28}: {r['median_s'] * 1e6:10.1f} us{extra}")
    _write("BENCH_kernels.json", rows)
    return rows


def suite_jacobi(*, paper: bool = False, smoke: bool = False) -> list[dict]:
    print("== jacobi_fig3 (paper Fig. 3, fused-residual sweeps) ==")
    from . import jacobi_paper
    if smoke:
        jrows = jacobi_paper.run(sizes=(256,), iters=50)
    else:
        jrows = jacobi_paper.main(quick=not paper)
    rows = jacobi_paper.bench_rows(jrows)
    _write("BENCH_jacobi.json", rows)
    return rows


def suite_hypar(*, smoke: bool = False) -> list[dict]:
    print("== hypar_lm (framework vs tailored, LM training) ==")
    from . import hypar_overhead
    from .kernel_bench import bench_row
    h = hypar_overhead.run(steps=2 if smoke else 4)
    rows = [bench_row(f"hypar_lm_{k}", (), "float32", h[f"{k}_s"],
                      overhead_pct=h["overhead_pct"] if k == "hypar" else 0.0)
            for k in ("tailored", "hypar")]
    print("== hypar_proc (process-worker vs thread dispatch) ==")
    p = hypar_overhead.run_proc_dispatch(
        **(dict(depth=4, dim=128, repeats=2) if smoke else {}))
    rows.append(bench_row("hypar_proc", (), "float64", p["proc_s"],
                          thread_s=p["thread_s"],
                          proc_vs_thread_pct=p["proc_vs_thread_pct"],
                          n_jobs=p["n_jobs"]))
    _write("BENCH_hypar.json", rows)
    return rows


def suite_serve(*, smoke: bool = False) -> list[dict]:
    print("== serve (request-level continuous batching, direct vs hypar) ==")
    from . import serve_bench
    rows = serve_bench.run(smoke=smoke)
    for r in rows:
        print(f"  {r['name']:>14}: {r['tok_per_s']:8.1f} tok/s  "
              f"ttft p50 {r['ttft_p50_s'] * 1e3:7.1f} ms  "
              f"lat p50/p95 {r['lat_p50_s'] * 1e3:6.1f}/"
              f"{r['lat_p95_s'] * 1e3:6.1f} ms  "
              f"occ {r['occupancy'] * 100:4.0f}%")
    _write("BENCH_serve.json", rows)
    return rows


def print_roofline() -> None:
    """Summarise the dry-run roofline table if present (produced by
    ``python -m repro.launch.dryrun --all``) — print-only, no BENCH file."""
    results = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results", "dryrun.jsonl")
    if not os.path.exists(results):
        return
    print("== roofline (from dry-run) ==")
    with open(results) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    for r in recs:
        step_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
        print(f"  roofline_{r['arch']}_{r['cell']}_{r['mesh']}: "
              f"{step_ms:.1f} ms/step dom={r['dominant']} "
              f"frac={r['roofline_fraction'] * 100:.1f}%")


SUITES = {"kernels": suite_kernels, "jacobi": suite_jacobi,
          "hypar": suite_hypar, "serve": suite_serve}


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(prog="benchmarks.run", description=__doc__)
    p.add_argument("--suite", choices=[*SUITES, "all"], default="all")
    p.add_argument("--paper", action="store_true",
                   help="full paper sizes (2709/4209/7209 x 500 iters)")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized shapes for every suite")
    args = p.parse_args(argv)

    if args.suite in ("kernels", "all"):
        suite_kernels(smoke=args.smoke)
    if args.suite in ("jacobi", "all"):
        suite_jacobi(paper=args.paper, smoke=args.smoke)
    if args.suite in ("hypar", "all"):
        suite_hypar(smoke=args.smoke)
    if args.suite in ("serve", "all"):
        suite_serve(smoke=args.smoke)
    if args.suite == "all":
        print_roofline()


if __name__ == "__main__":
    main()
