"""Benchmark harness entry point — one benchmark per paper table/figure
plus framework-level measurements.  Prints ``name,us_per_call,derived``
CSV rows (plus the detailed per-benchmark output above them).

  jacobi_fig3      — the paper's only results figure (Fig. 3): framework vs
                     tailored Jacobi at 3 sizes × 500 iterations (default
                     sizes shrink for CI; pass ``--paper`` for 2709/4209/7209
                     × 500 as in the paper).
  hypar_lm         — the same framework-vs-tailored claim on the LM
                     training workload (this framework's primary domain)
  kernels          — per-kernel microbenchmarks
  roofline         — summarises the dry-run roofline table if
                     benchmarks/results/dryrun.jsonl exists (produced by
                     ``python -m repro.launch.dryrun --all``)
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    quick = "--paper" not in sys.argv
    rows: list[tuple[str, float, str]] = []

    print("== jacobi_fig3 (paper Fig. 3) ==")
    from . import jacobi_paper
    jrows = jacobi_paper.main(quick=quick)
    for r in jrows:
        rows.append((f"jacobi_n{r['n']}_tailored", r["tailored_s"] * 1e6 / r["iters"],
                     "us/iter"))
        rows.append((f"jacobi_n{r['n']}_hypar", r["hypar_s"] * 1e6 / r["iters"],
                     f"overhead={r['overhead_pct']:+.1f}%"))
        rows.append((f"jacobi_n{r['n']}_spmdfused", r["spmd_s"] * 1e6 / r["iters"],
                     f"overhead={r['spmd_overhead_pct']:+.1f}%"))

    print("\n== hypar_lm (framework vs tailored, LM training) ==")
    from . import hypar_overhead
    h = hypar_overhead.run(steps=4 if quick else 10)
    rows.append(("hypar_lm_tailored", h["tailored_s"] * 1e6, "total"))
    rows.append(("hypar_lm_framework", h["hypar_s"] * 1e6,
                 f"overhead={h['overhead_pct']:+.1f}%"))

    print("\n== kernels ==")
    from . import kernel_bench
    for name, us, derived in kernel_bench.run():
        rows.append((name, us, derived))

    results = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")
    if os.path.exists(results):
        print("\n== roofline (from dry-run) ==")
        with open(results) as f:
            recs = [json.loads(l) for l in f if l.strip()]
        for r in recs:
            key = f"roofline_{r['arch']}_{r['cell']}_{r['mesh']}"
            step_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
            rows.append((key, step_ms * 1e3,
                         f"dom={r['dominant']},frac={r['roofline_fraction']*100:.1f}%"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
