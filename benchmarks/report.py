"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.jsonl."""
from __future__ import annotations

import json
import sys


def load(path="benchmarks/results/dryrun.jsonl"):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def ms(s):
    return f"{s*1e3:.1f}"


def dryrun_table(rows):
    out = ["| arch | cell | mesh | compile s | accum | GiB/dev | fits 16G | collectives (AR/AG/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        c = r["collective_counts"]
        cc = "/".join(str(c.get(k, 0)) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | {r['compile_s']} | "
            f"{r['grad_accum']} | {fmt_bytes(r['bytes_per_device'])} | "
            f"{'yes' if r['fits_hbm'] else '**NO**'} | {cc} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | cell | compute ms | memory ms | collective ms | dominant | "
           "MODEL_FLOPS | useful % | roofline frac % |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['cell']} | {ms(r['compute_s'])} | "
            f"{ms(r['memory_s'])} | {ms(r['collective_s'])} | {r['dominant']} | "
            f"{r['model_flops']:.2e} | {100*r['useful_ratio']:.1f} | "
            f"{100*r['roofline_fraction']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1 else
                "benchmarks/results/dryrun.jsonl")
    print("## Dry-run\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows))
