"""Diff two ``BENCH_<suite>.json`` files: per-row median deltas.

    python -m benchmarks.compare BASELINE.json NEW.json [--threshold 10]

Rows are matched by ``name``; for each match the median_s delta is printed
(positive = NEW is slower).  Exits non-zero when any row regresses by more
than ``--threshold`` percent — CI runs this informationally against the
committed baselines after the benchmark-smoke step, so a hot-path
regression shows up in the log the moment a PR introduces it, without
hard-failing on machine noise (`|| true` in the workflow).

Rows present in only one file are reported but never fail the diff: suites
legitimately gain rows (new workloads) and, rarely, retire them.  The same
holds for row EXTRAS: robustness counters (goodput, typed shed counts,
watchdog trips) are printed as informational deltas when present but never
counted — only ``median_s`` gates, because the extras measure workload
composition (how much was shed under an overload trace), not kernel speed.
"""
from __future__ import annotations

import argparse
import json
import sys

#: row extras surfaced informationally in the diff — robustness telemetry
#: (DESIGN.md §14) whose drift is worth seeing but must never gate
INFO_EXTRAS = ("goodput_tok_per_s", "goodput_gain_pct", "shed_deadline",
               "shed_queue_full", "shed_never_fits", "n_expired",
               "watchdog_trips", "speedup_vs_gather_pct")


def extras_notes(b: dict, n: dict) -> list[str]:
    """Informational deltas for the robustness extras a matched row pair
    carries — new extras (an old baseline predating them) are labelled,
    never treated as schema drift."""
    notes = []
    for k in INFO_EXTRAS:
        bv, nv = b.get(k), n.get(k)
        if nv is None:
            continue
        if bv is None:
            notes.append(f"{k}={nv:g} (new extra, informational)")
        elif bv != nv:
            notes.append(f"{k} {bv:g} -> {nv:g}")
    return notes


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    if "rows" not in doc:
        raise SystemExit(f"{path}: not a BENCH file (no 'rows')")
    return {r["name"]: r for r in doc["rows"]}


def compare(base: dict[str, dict], new: dict[str, dict],
            threshold_pct: float) -> tuple[list[str], int]:
    lines, n_regressed = [], 0
    for name in sorted(base.keys() | new.keys()):
        b, n = base.get(name), new.get(name)
        if b is None:
            med = n.get("median_s")
            lines.append(f"  {name:>28}: (new row) median "
                         + (f"{med * 1e6:10.1f} us" if med is not None
                            else "missing"))
            continue
        if n is None:
            lines.append(f"  {name:>28}: (row removed)")
            continue
        b_med, n_med = b.get("median_s"), n.get("median_s")
        if n_med is None:
            # schema drift: a row without median_s can't be compared — like
            # a NaN, that must show up as a regression, not a silent pass
            lines.append(f"  {name:>28}: NEW row has no median_s  "
                         f"<-- REGRESSION (schema drift)")
            n_regressed += 1
            continue
        if b_med is None or not b_med or b_med != b_med:        # 0 or NaN
            lines.append(f"  {name:>28}: baseline median unusable, skipped")
            continue
        if n_med != n_med:                                       # NaN
            # a broken run records NaN medians (see run_trace) — that is
            # the worst regression, not a pass
            lines.append(f"  {name:>28}: NEW median is NaN  <-- REGRESSION "
                         f"(broken run)")
            n_regressed += 1
            continue
        delta = (n_med / b_med - 1.0) * 100.0
        flag = ""
        if b.get("mesh") != n.get("mesh"):
            # the row was re-measured on a different device mesh — its
            # median moved because the shape of the run changed, not
            # because a kernel got slower.  Note it, never count it.
            flag = (f"  (mesh changed {b.get('mesh')} -> {n.get('mesh')}, "
                    f"not comparable)")
        elif delta > threshold_pct:
            flag = f"  <-- REGRESSION (> {threshold_pct:g}%)"
            n_regressed += 1
        elif delta < -threshold_pct:
            flag = "  (improved)"
        lines.append(f"  {name:>28}: {b_med * 1e6:10.1f} -> "
                     f"{n_med * 1e6:10.1f} us  {delta:+7.1f}%{flag}")
        for note in extras_notes(b, n):
            lines.append(f"  {'':>28}  . {note}")
    return lines, n_regressed


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.compare",
                                 description=__doc__)
    ap.add_argument("baseline", help="BENCH_<suite>.json to compare against")
    ap.add_argument("new", help="freshly generated BENCH_<suite>.json")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="median_s regression tolerance, percent "
                         "(default 10)")
    args = ap.parse_args(argv)

    base, new = load_rows(args.baseline), load_rows(args.new)
    lines, n_regressed = compare(base, new, args.threshold)
    print(f"== {args.baseline} vs {args.new} "
          f"(threshold {args.threshold:g}%) ==")
    for line in lines:
        print(line)
    if n_regressed:
        print(f"{n_regressed} row(s) regressed beyond {args.threshold:g}%")
        return 1
    print("no regression beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
