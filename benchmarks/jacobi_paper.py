"""Paper Fig. 3 reproduction: parallel Jacobi, framework vs tailored.

Paper setup: sizes 2709², 4209², 7209², 500 iterations; framework runtimes
"vary (mean value) around 10 % from the runtime of an efficient MPI
implementation".  Here: HyPar LocalExecutor (scheduler dispatch per
iteration, the paper-faithful path) vs a fused jitted while_loop (the
tailored stand-in), plus the beyond-paper SPMD-fused variant which removes
the host round-trip the paper's design pays per dynamic-job iteration.

CPU wall-times are not TPU wall-times, but the *ratio* framework/tailored
is the paper's claim and is hardware-meaningful (dispatch overhead /
compute).

All three variants run the fused-residual sweep (one A-matvec per
iteration; see ``repro.apps.jacobi``), so the compute halves relative to
the original sweep+residual pair while the framework/tailored ratio stays
comparable.  ``bench_rows`` re-expresses the table in the stable BENCH
schema for ``benchmarks/run.py``.
"""
from __future__ import annotations

import json

import numpy as np

from repro.apps.jacobi import (jacobi_hypar, jacobi_spmd, jacobi_tailored,
                               make_system)

SIZES = (2709, 4209, 7209)
ITERS = 500


def run(sizes=SIZES, iters=ITERS, *, n_chunks: int = 4) -> list[dict]:
    rows = []
    for n in sizes:
        A, b, x_true = make_system(n)
        rt = jacobi_tailored(A, b, iters=iters, tol=0.0)
        rh = jacobi_hypar(A, b, iters=iters, tol=0.0, n_chunks=n_chunks)
        rs = jacobi_spmd(A, b, iters=iters, tol=0.0)
        err_h = float(np.max(np.abs(rh.x - rt.x)))
        rows.append({
            "n": n, "iters": iters,
            "tailored_s": rt.seconds, "hypar_s": rh.seconds,
            "spmd_s": rs.seconds,
            "overhead_pct": 100.0 * (rh.seconds / rt.seconds - 1.0),
            "spmd_overhead_pct": 100.0 * (rs.seconds / rt.seconds - 1.0),
            "max_diff_vs_tailored": err_h,
        })
        r = rows[-1]
        print(f"n={n}: tailored {rt.seconds:.2f}s | hypar {rh.seconds:.2f}s "
              f"({r['overhead_pct']:+.1f}%) | spmd-fused {rs.seconds:.2f}s "
              f"({r['spmd_overhead_pct']:+.1f}%) | Δx {err_h:.1e}")
    mean = float(np.mean([r["overhead_pct"] for r in rows]))
    print(f"mean framework overhead: {mean:+.1f}%  (paper: ~10 %)")
    return rows


def bench_rows(rows: list[dict]) -> list[dict]:
    """Fig.-3 table -> stable BENCH schema (one row per variant/size;
    median_s is per iteration; flops/bytes are the fused single matvec)."""
    from .kernel_bench import bench_row
    out = []
    for r in rows:
        n, iters = r["n"], r["iters"]
        for variant, key in (("tailored", "tailored_s"), ("hypar", "hypar_s"),
                             ("spmd", "spmd_s")):
            overhead = (r["overhead_pct"] if variant == "hypar"
                        else r["spmd_overhead_pct"] if variant == "spmd"
                        else 0.0)
            out.append(bench_row(
                f"jacobi_{variant}_n{n}", (n, n), "float32", r[key] / iters,
                flops=2.0 * n * n, nbytes=4.0 * n * n, total_s=r[key],
                iters=iters, overhead_pct=overhead))
    return out


def main(out: str | None = None, quick: bool = False):
    # quick sizes stay large enough that compute dominates the per-iteration
    # dispatch floor — below ~1k the ratio measures the host loop, not the
    # framework/compute overhead the paper reports (its smallest n is 2709)
    rows = run(sizes=(1024, 2048) if quick else SIZES,
               iters=100 if quick else ITERS)
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
