"""Serving benchmark: request-level continuous batching, dense vs paged.

Two traces, each replayed through ``ServeScheduler`` (the measurement is
``launch/serve.py::run_trace`` — same code path and metric definitions as
the CLI):

* the **smoke trace** (short prompts, PR-3 continuity): ``serve_direct``
  and ``serve_hypar`` rows — the hypar row's ``overhead_pct`` is the cost
  of routing every request through the job machinery, with admission waves
  placed by ONE batched ``plan_segment`` call (PR 4; was one call per
  request at ~25%).
* the **mixed trace** (short + long prompts — the ragged workload the
  paper's job model exists for): ``serve_direct_mixed`` is the dense
  baseline, ``serve_direct_paged``/``serve_hypar_paged`` run the paged KV
  cache + chunked prefill path at the SAME batch and the dense engine's
  exact KV byte budget; ``serve_paged_preempt`` reruns the mixed trace on
  a page pool HALVED to below the working set, comparing full-lifetime
  reservation (which must defer admissions) against reserve-on-demand +
  vLLM-style preemption at equal pool bytes — extras ``preempt_count``,
  ``resume_tokens_recomputed`` and ``speedup_vs_lifetime_pct``
  (DESIGN.md §10).
* the mixed trace again as ``serve_paged_kernel``: paged decode through
  the in-kernel page gather (DESIGN.md §15, ``attn_impl="auto"``) vs the
  materialising gather path at equal pool bytes — a tie on CPU (auto
  resolves to gather off-TPU), the real comparison on TPU; extras
  ``gather_tok_per_s``, ``speedup_vs_gather_pct``.
* the **shared-prefix trace** (every prompt opens with the same system
  prefix): ``serve_prefix_cache`` compares the paged engine with prefix
  caching + copy-on-write page sharing on vs off at equal pool bytes —
  a cache hit's admission prefills only the tail chunk, so the row's
  ``ttft_p50_s``/``ttft_p95_s`` undercut the no-cache references; extras
  ``prefix_hit_rate``, ``pages_shared``, ``cow_copies`` (DESIGN.md §11).  A paged insert is ONE chunk-prefill call writing
  straight into the slot's pages, vs the dense trio (fresh mini-cache +
  bucket-padded prefill + whole-cache splice), at equal decode cost —
  the measured tok/s and TTFT-tail edge.  Paged rows carry
  ``kv_budget_tokens`` (identical to the dense row's), ``n_slots`` and
  the engine trace counters (``chunk_traces``/``decode_traces`` —
  bounded: one chunk program per chunk-length bucket, ONE decode
  program).  Variants under comparison are measured by round-robined
  replays (``compare_engines``) so minute-scale machine drift cannot
  land on one engine.

Row schema (via ``kernel_bench.bench_row``; ``median_s`` is the median
per-token decode latency so the serve trajectory is comparable across PRs
like every other suite)::

    name=serve_<variant>  median_s=<p50 token latency>
    extras: tok_per_s, ttft_p50_s, ttft_p95_s, lat_p50_s, lat_p95_s,
            occupancy, n_requests, gen_tokens, overhead_pct vs direct

Run via ``python -m benchmarks.run --suite serve [--smoke]``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile

import jax

from .kernel_bench import bench_row


@dataclasses.dataclass
class _Args:
    """The subset of launch/serve.py CLI args run_trace consumes."""
    engine: str
    batch: int
    strategy: str
    prompt_lens: tuple
    max_pending: int | None
    n_requests: int
    rate: float
    max_new: int
    seed: int
    paged: bool = False
    page_size: int = 16
    num_pages: int | None = None
    prefill_chunk: int = 64
    reserve: str = "lifetime"
    preempt_policy: str = "fewest"
    admit_watermark: int = 0
    max_new_mix: tuple | None = None
    prefix_cache: bool = False
    shared_prefix_len: int = 0
    # paged decode attention impl (DESIGN.md §15): auto = kernel on TPU,
    # gather path on CPU
    paged_attn_impl: str = "auto"
    # deadline-aware serving (DESIGN.md §14)
    ttft_deadline: float | None = None
    total_deadline: float | None = None
    enforce_deadlines: bool = True
    watchdog_budget: float | None = None
    max_restarts: int | None = None


def _smoke_args():
    # 24 requests ≈ 200 ms of measured decode — long enough that
    # overhead_pct reflects scheduling cost, not wall-clock noise (the PR-3
    # 8-request trace measured ~45 ms walls, where one OS hiccup was ±20%)
    return dict(batch=4, n_requests=24, max_new=8, prompt_lens=(6, 10, 14))


def _full_args():
    return dict(batch=8, n_requests=48, max_new=32, prompt_lens=(16, 32, 64))


def _smoke_mixed():
    # short + long prompts at batch 8, identical KV byte budget.  Dense pays
    # three dispatches per insert (fresh mini-cache + bucket-padded prefill
    # + whole-cache splice); a paged insert is ONE chunk-prefill call
    # writing straight into the slot's pages (96-token prompts are a single
    # 96 chunk here — multi-chunk interleaving is exercised by the full
    # suite's 256-token prompts and the paged unit tests), which is what
    # buys the tok/s and TTFT-tail edge at equal decode cost.
    return dict(batch=8, n_requests=48, max_new=32, prompt_lens=(8, 16, 96),
                page_size=16, prefill_chunk=96)


def _full_mixed():
    # 256-token prompts split into 2 x 128 chunks with decode steps between
    # them: the long-prompt stall the chunk interleaving policy exists for
    return dict(batch=8, n_requests=48, max_new=32, prompt_lens=(16, 32, 256),
                page_size=16, prefill_chunk=128)


def _smoke_prefix():
    # the system-prompt workload: every prompt opens with the same 64-token
    # prefix (4 pages, 2 chunks) followed by a random remainder.  With the
    # cache on, a hit's admission prefills ONLY the tail chunk (1 x 32 vs
    # 3 x 32 chunks for 80-96-token prompts) — the ttft_p50/p95 edge the
    # acceptance row asserts, at equal pool bytes
    return dict(batch=8, n_requests=24, max_new=16, prompt_lens=(80, 96),
                page_size=16, prefill_chunk=32, shared_prefix_len=64)


def _full_prefix():
    return dict(batch=8, n_requests=48, max_new=32, prompt_lens=(160, 192),
                page_size=16, prefill_chunk=64, shared_prefix_len=128)


def _smoke_constrained():
    # the preemption trace: clients declare a 64-token cap but realised
    # lengths average ~30 (the max_new_mix), so full-lifetime reservation
    # provisions pages most requests never touch; the pool is 40% of the
    # dense footprint — small enough that lifetime must defer admissions
    # and demand must preempt at least once, large enough that recompute
    # stays a sliver of the useful work
    return dict(batch=8, n_requests=24, max_new=64, prompt_lens=(8, 16, 96),
                page_size=16, prefill_chunk=96, max_new_mix=(8, 16, 32, 64))


def _full_constrained():
    return dict(batch=8, n_requests=48, max_new=64,
                prompt_lens=(16, 32, 256), page_size=16, prefill_chunk=128,
                max_new_mix=(8, 16, 32, 64))


def _smoke_overload():
    # the shedding trace: Poisson arrivals at 2x the calibrated service
    # capacity with a TTFT deadline every request declares.  Without
    # shedding the queue grows linearly and late requests burn decode slots
    # on answers nobody is waiting for; deadline-aware admission drops them
    # up front, so the slots serve requests that can still meet their SLO
    return dict(batch=4, n_requests=24, max_new=8, prompt_lens=(8, 16),
                page_size=16, prefill_chunk=32)


def _full_overload():
    return dict(batch=8, n_requests=48, max_new=16, prompt_lens=(8, 16, 32),
                page_size=16, prefill_chunk=64)


def _make_args(engine: str, *, batch, n_requests, max_new, prompt_lens,
               rate_per_s: float = 0.0, seed: int = 0, paged: bool = False,
               page_size: int = 16, num_pages: int | None = None,
               prefill_chunk: int = 64, reserve: str = "lifetime",
               preempt_policy: str = "fewest",
               admit_watermark: int = 0,
               max_new_mix: tuple | None = None,
               prefix_cache: bool = False,
               shared_prefix_len: int = 0,
               paged_attn_impl: str = "auto",
               ttft_deadline: float | None = None,
               total_deadline: float | None = None,
               enforce_deadlines: bool = True,
               watchdog_budget: float | None = None,
               max_restarts: int | None = None) -> _Args:
    return _Args(engine=engine, batch=batch, strategy="greedy",
                 prompt_lens=tuple(prompt_lens), max_pending=None,
                 n_requests=n_requests, rate=rate_per_s, max_new=max_new,
                 seed=seed, paged=paged, page_size=page_size,
                 num_pages=num_pages, prefill_chunk=prefill_chunk,
                 reserve=reserve, preempt_policy=preempt_policy,
                 admit_watermark=admit_watermark, max_new_mix=max_new_mix,
                 prefix_cache=prefix_cache,
                 shared_prefix_len=shared_prefix_len,
                 paged_attn_impl=paged_attn_impl,
                 ttft_deadline=ttft_deadline, total_deadline=total_deadline,
                 enforce_deadlines=enforce_deadlines,
                 watchdog_budget=watchdog_budget, max_restarts=max_restarts)


def run_engine(engine: str, *, cfg, params, repeats: int = 1, **kw) -> dict:
    from repro.launch.serve import run_trace
    from repro.serve import SamplingParams

    return run_trace(cfg, params, _make_args(engine, **kw),
                     sp=SamplingParams(), repeats=repeats)


def compare_engines(variants: dict[str, _Args], *, cfg, params,
                    rounds: int = 3) -> dict[str, dict]:
    """Measure several engine configurations AGAINST machine drift.

    All variants are warmed first, then their measured replays are
    round-robined (A B C A B C …) so a slow minute on a shared box hits
    every variant instead of whichever ran last; each variant reports its
    best replay.  This is what makes overhead_pct / speedup_vs_dense_pct
    numbers in BENCH_serve.json comparisons rather than coin flips.
    """
    from repro.launch.serve import prepare_trace, replay_trace, trace_stats
    from repro.serve import SamplingParams

    prepared = {name: (args, *prepare_trace(cfg, params, args,
                                            sp=SamplingParams()))
                for name, args in variants.items()}
    snaps: dict[str, list] = {name: [] for name in variants}
    for _ in range(max(1, rounds)):
        for name, (_, sched, reqs) in prepared.items():
            snaps[name].append(replay_trace(sched, reqs))
    return {name: trace_stats(args, sched,
                              max(snaps[name], key=lambda s: s[0]))
            for name, (args, sched, _) in prepared.items()}


def _row(name, batch, max_new, s, overhead=0.0, **extra):
    return bench_row(
        name, (batch, max_new), "int32", s["lat_p50_s"],
        tok_per_s=s["tok_per_s"],
        ttft_p50_s=s["ttft_p50_s"], ttft_p95_s=s["ttft_p95_s"],
        lat_p50_s=s["lat_p50_s"], lat_p95_s=s["lat_p95_s"],
        occupancy=s["occupancy"], n_requests=s["n_requests"],
        gen_tokens=s["gen_tokens"], overhead_pct=overhead, **extra)


def _overhead(direct_tok_s, s) -> float:
    if direct_tok_s and s["tok_per_s"] > 0:
        return (direct_tok_s / s["tok_per_s"] - 1.0) * 100.0
    return 0.0


def _run_sharded_variant(name: str, extra_cli: list[str], *,
                         trace_cli: list[str], tmpdir: str) -> dict:
    """One ``launch/serve.py`` run in a forced-2-device subprocess (the
    XLA device-count flag must be set before jax initialises, which this
    already-running process is long past) — returns its ``--stats-json``."""
    out = os.path.join(tmpdir, f"{name}.json")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(repo, "src"),
                    env.get("PYTHONPATH", "")] if p)
    cmd = [sys.executable, "-m", "repro.launch.serve", "--smoke", "--trace",
           "--paged", "--reserve", "demand", "--admit-watermark", "1",
           "--page-size", "8", "--prefill-chunk", "16",
           "--stats-json", out] + trace_cli + extra_cli
    proc = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                          text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded variant {name!r} failed "
                           f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}")
    with open(out) as f:
        return json.load(f)


def run_sharded(smoke: bool = False) -> dict:
    """The multi-device row (DESIGN.md §13): one 2-device comparison of

    * ``base``  — 1 device, no mesh, pool of P pages on batch B;
    * ``tp2``   — ``--mesh 2,1``: the SAME pool TP-sharded over kv_heads —
      per-device pool bytes must halve;
    * ``dp2``   — ``--mesh 1,2``: batch 2B and pool ~2P split into two
      device groups at EQUAL per-device pool bytes — both groups must do
      nonzero work (``group_occupancy``).

    Forced host devices share one CPU's FLOPs, so ``speedup_vs_1dev_pct``
    records scheduling/collective overhead rather than real speedup — the
    row's value is the invariants (byte halving, group balance) tracked
    over PRs."""
    n_req = 12 if smoke else 24
    base_batch, base_pages = 4, 18          # even page count so DP shards
    trace = ["--prompt-lens", "8", "16", "--max-new", "8",
             "--n-requests", str(n_req), "--seed", "0"]
    with tempfile.TemporaryDirectory() as td:
        base = _run_sharded_variant(
            "base", ["--batch", str(base_batch),
                     "--num-pages", str(base_pages)],
            trace_cli=trace, tmpdir=td)
        tp2 = _run_sharded_variant(
            "tp2", ["--batch", str(base_batch),
                    "--num-pages", str(base_pages), "--mesh", "2,1"],
            trace_cli=trace, tmpdir=td)
        dp2 = _run_sharded_variant(
            "dp2", ["--batch", str(2 * base_batch),
                    "--num-pages", str(2 * base_pages), "--mesh", "1,2"],
            trace_cli=trace, tmpdir=td)
    base_tok_s = base["tok_per_s"]
    return _row(
        "serve_sharded", 2 * base_batch, 8, dp2,
        mesh=dp2["mesh"], device_groups=dp2["device_groups"],
        group_occupancy=dp2["group_occupancy"],
        kv_budget_tokens=dp2["kv_budget_tokens"],
        per_device_pool_bytes=dp2["per_device_pool_bytes"],
        base_per_device_pool_bytes=base["per_device_pool_bytes"],
        tp2_per_device_pool_bytes=tp2["per_device_pool_bytes"],
        tp2_pool_halved=(2 * tp2["per_device_pool_bytes"]
                         == base["per_device_pool_bytes"]),
        base_tok_per_s=base_tok_s,
        tp2_tok_per_s=tp2["tok_per_s"],
        speedup_vs_1dev_pct=(dp2["tok_per_s"] / base_tok_s - 1.0) * 100.0
        if base_tok_s else 0.0)


def run(smoke: bool = False) -> list[dict]:
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params

    kw = _smoke_args() if smoke else _full_args()
    mx = _smoke_mixed() if smoke else _full_mixed()
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []

    # -- smoke trace: direct vs hypar (batched-placement overhead) ----------
    stats = compare_engines(
        {"direct": _make_args("direct", **kw),
         "hypar": _make_args("hypar", **kw)}, cfg=cfg, params=params)
    rows.append(_row("serve_direct", kw["batch"], kw["max_new"],
                     stats["direct"]))
    rows.append(_row("serve_hypar", kw["batch"], kw["max_new"],
                     stats["hypar"],
                     _overhead(stats["direct"]["tok_per_s"],
                               stats["hypar"])))

    # -- mixed trace: dense baseline vs paged + chunked prefill -------------
    batch = mx["batch"]
    max_len = max(mx["prompt_lens"]) + mx["max_new"] + 8   # = run_trace's
    kv_budget_tokens = batch * max_len
    # same pool bytes as the dense engine's batch x max_len reservation,
    # split into pages (+ the trash page)
    num_pages = 1 + batch * (-(-max_len // mx["page_size"]))
    paged = dict(mx, paged=True, num_pages=num_pages)
    stats = compare_engines(
        {"dense": _make_args("direct", **mx),
         "paged": _make_args("direct", **paged),
         "hypar_paged": _make_args("hypar", **paged)},
        cfg=cfg, params=params)

    dense_tok_s = stats["dense"]["tok_per_s"]
    rows.append(_row("serve_direct_mixed", batch, mx["max_new"],
                     stats["dense"], kv_budget_tokens=kv_budget_tokens))
    for name, key in (("serve_direct_paged", "paged"),
                      ("serve_hypar_paged", "hypar_paged")):
        s = stats[key]
        rows.append(_row(
            name, batch, mx["max_new"], s,
            _overhead(stats["paged"]["tok_per_s"], s)
            if key == "hypar_paged" else 0.0,
            kv_budget_tokens=kv_budget_tokens, n_slots=batch,
            speedup_vs_dense_pct=(s["tok_per_s"] / dense_tok_s - 1.0)
            * 100.0 if dense_tok_s else 0.0,
            chunk_traces=s["trace_counts"]["chunk_prefill"],
            decode_traces=s["trace_counts"]["decode"]))

    # -- paged-kernel trace: in-kernel page gather (DESIGN.md §15) vs the
    # materialising gather path, same mixed trace at EQUAL pool bytes.
    # attn_impl="auto" resolves to the Pallas kernel on TPU and to the
    # gather path on CPU (the interpreter cannot serve), so on CPU CI the
    # variants tie within round-robin noise — the row exists to carry the
    # TPU comparison and to keep the dispatch plumbing measured.
    stats = compare_engines(
        {"gather": _make_args("direct",
                              **dict(paged, paged_attn_impl="ref")),
         "kernel": _make_args("direct",
                              **dict(paged, paged_attn_impl="auto"))},
        cfg=cfg, params=params)
    ga, kn = stats["gather"], stats["kernel"]
    rows.append(_row(
        "serve_paged_kernel", batch, mx["max_new"], kn,
        kv_budget_tokens=kv_budget_tokens, n_slots=batch,
        attn_impl="auto",
        gather_tok_per_s=ga["tok_per_s"],
        speedup_vs_gather_pct=(kn["tok_per_s"] / ga["tok_per_s"] - 1.0)
        * 100.0 if ga["tok_per_s"] else 0.0,
        chunk_traces=kn["trace_counts"]["chunk_prefill"],
        decode_traces=kn["trace_counts"]["decode"]))

    # -- page-constrained trace: full-lifetime reservation vs
    # reserve-on-demand + preemption at EQUAL pool bytes.  The pool holds
    # 40% of the dense footprint, so lifetime reservation (provisioning the
    # declared 64-token cap) must defer admissions while demand mode admits
    # prompt spans, appends decode pages as realised lengths grow, and
    # preempts (recompute-resume) on exhaustion — more live slots per
    # (full-batch) decode step is the tok/s and TTFT edge.
    cn = _smoke_constrained() if smoke else _full_constrained()
    cbatch = cn["batch"]
    cmax_len = max(cn["prompt_lens"]) + cn["max_new"] + 8
    con_pages = 1 + int(cbatch * (-(-cmax_len // cn["page_size"])) * 0.4)
    con = dict(cn, paged=True, num_pages=con_pages)
    stats = compare_engines(
        {"lifetime": _make_args("direct", **con),
         "preempt": _make_args("direct", **dict(con, reserve="demand"))},
        cfg=cfg, params=params)
    lt, s = stats["lifetime"], stats["preempt"]
    rows.append(_row(
        "serve_paged_preempt", cbatch, cn["max_new"], s,
        kv_budget_tokens=(con_pages - 1) * cn["page_size"],
        pool_pages=con_pages, n_slots=cbatch,
        preempt_count=s["preempt_count"],
        resume_tokens_recomputed=s["resume_tokens_recomputed"],
        admit_deferred=s["admit_deferred"],
        lifetime_tok_per_s=lt["tok_per_s"],
        lifetime_ttft_p95_s=lt["ttft_p95_s"],
        lifetime_admit_deferred=lt["admit_deferred"],
        speedup_vs_lifetime_pct=(s["tok_per_s"] / lt["tok_per_s"] - 1.0)
        * 100.0 if lt["tok_per_s"] else 0.0,
        chunk_traces=s["trace_counts"]["chunk_prefill"],
        decode_traces=s["trace_counts"]["decode"]))

    # -- shared-prefix trace: prefix caching + COW page sharing vs the
    # no-cache paged engine at EQUAL pool bytes (DESIGN.md §11).  Every
    # prompt repeats the same system prefix; with the cache on, admissions
    # after the first wave map the prefix onto shared pool pages and
    # prefill only the tail chunk — lower ttft at the same tok/s budget.
    pf = _smoke_prefix() if smoke else _full_prefix()
    pbatch = pf["batch"]
    pmax_len = max(pf["prompt_lens"]) + pf["max_new"] + 8
    pf_pages = 1 + pbatch * (-(-pmax_len // pf["page_size"]))
    pbase = dict(pf, paged=True, num_pages=pf_pages)
    stats = compare_engines(
        {"nocache": _make_args("direct", **pbase),
         "cache": _make_args("direct", **dict(pbase, prefix_cache=True))},
        cfg=cfg, params=params)
    nc, pc = stats["nocache"], stats["cache"]
    rows.append(_row(
        "serve_prefix_cache", pbatch, pf["max_new"], pc,
        kv_budget_tokens=(pf_pages - 1) * pf["page_size"],
        pool_pages=pf_pages, n_slots=pbatch,
        shared_prefix_len=pf["shared_prefix_len"],
        prefix_hit_rate=pc["prefix_hit_rate"],
        pages_shared=pc["pages_shared"],
        cow_copies=pc["cow_copies"],
        nocache_tok_per_s=nc["tok_per_s"],
        nocache_ttft_p50_s=nc["ttft_p50_s"],
        nocache_ttft_p95_s=nc["ttft_p95_s"],
        ttft_p50_gain_pct=(nc["ttft_p50_s"] / pc["ttft_p50_s"] - 1.0)
        * 100.0 if pc["ttft_p50_s"] else 0.0,
        speedup_vs_nocache_pct=(pc["tok_per_s"] / nc["tok_per_s"] - 1.0)
        * 100.0 if nc["tok_per_s"] else 0.0,
        chunk_traces=pc["trace_counts"]["chunk_prefill"],
        decode_traces=pc["trace_counts"]["decode"]))

    # -- overload trace: deadline-aware shedding vs serve-everything under
    # a 2x-overloaded Poisson trace at EQUAL pool bytes (DESIGN.md §14).
    # A calibration run (same engine, closed-loop) measures sustainable
    # tok/s; the overload trace then arrives at twice the implied request
    # rate with a TTFT deadline sized so roughly the first half of the
    # backlog is meetable.  ``goodput_tok_per_s`` counts only tokens of
    # requests that met every declared deadline — the shedding scheduler
    # must beat the no-shedding baseline on it (tok_per_s alone would
    # reward the baseline for generating tokens nobody is waiting for).
    ov = _smoke_overload() if smoke else _full_overload()
    obatch = ov["batch"]
    omax_len = max(ov["prompt_lens"]) + ov["max_new"] + 8
    ov_pages = 1 + obatch * (-(-omax_len // ov["page_size"]))
    ovp = dict(ov, paged=True, num_pages=ov_pages)
    calib = run_engine("direct", cfg=cfg, params=params, **ovp)
    cap_tok_s = calib["tok_per_s"] or 1.0
    rate = 2.0 * cap_tok_s / ov["max_new"]          # 2x sustainable req/s
    ttft = ov["n_requests"] * ov["max_new"] / (4.0 * cap_tok_s)
    over = dict(ovp, rate_per_s=rate, ttft_deadline=ttft)
    stats = compare_engines(
        {"shed": _make_args("direct", **over),
         "noshed": _make_args("direct",
                              **dict(over, enforce_deadlines=False))},
        cfg=cfg, params=params)
    sh, ns = stats["shed"], stats["noshed"]
    rows.append(_row(
        "serve_overload", obatch, ov["max_new"], sh,
        kv_budget_tokens=(ov_pages - 1) * ov["page_size"],
        pool_pages=ov_pages, n_slots=obatch,
        offered_rate_req_s=rate,
        capacity_tok_per_s=cap_tok_s,
        ttft_deadline_s=ttft,
        goodput_tok_per_s=sh["goodput_tok_per_s"],
        shed_deadline=sh["shed_deadline"],
        shed_queue_full=sh["shed_queue_full"],
        shed_never_fits=sh["shed_never_fits"],
        n_expired=sh["n_expired"],
        noshed_goodput_tok_per_s=ns["goodput_tok_per_s"],
        noshed_tok_per_s=ns["tok_per_s"],
        goodput_gain_pct=(sh["goodput_tok_per_s"]
                          / ns["goodput_tok_per_s"] - 1.0) * 100.0
        if ns["goodput_tok_per_s"] else 0.0))

    # -- sharded trace: TP/DP device-mesh serving in forced-2-device
    # subprocesses (DESIGN.md §13)
    rows.append(run_sharded(smoke))
    return rows
