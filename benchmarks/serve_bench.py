"""Serving benchmark: request-level continuous batching, direct vs hypar.

Replays the same open-loop request trace (Poisson arrivals, mixed prompt
lengths) through ``ServeScheduler`` twice — once with direct slot filling,
once with every request routed through the HyPar job machinery
(dynamic control-spawned jobs + MasterScheduler placement + ResultStore
retention) — and emits one BENCH row per engine.  The measurement itself
is ``launch/serve.py::run_trace`` (same code path as the CLI), so the
BENCH rows and the CLI report the same metric definitions.

Row schema (via ``kernel_bench.bench_row``; ``median_s`` is the median
per-token decode latency so the serve trajectory is comparable across PRs
like every other suite)::

    name=serve_<engine>  median_s=<p50 token latency>
    extras: tok_per_s, ttft_p50_s, ttft_p95_s, lat_p50_s, lat_p95_s,
            occupancy, n_requests, gen_tokens, overhead_pct vs direct

Run via ``python -m benchmarks.run --suite serve [--smoke]``.
"""
from __future__ import annotations

import dataclasses

import jax

from .kernel_bench import bench_row


@dataclasses.dataclass
class _Args:
    """The subset of launch/serve.py CLI args run_trace consumes."""
    engine: str
    batch: int
    strategy: str
    prompt_lens: tuple
    max_pending: int | None
    n_requests: int
    rate: float
    max_new: int
    seed: int


def _smoke_args():
    return dict(batch=4, n_requests=8, max_new=8, prompt_lens=(6, 10, 14))


def _full_args():
    return dict(batch=8, n_requests=48, max_new=32, prompt_lens=(16, 32, 64))


def run_engine(engine: str, *, cfg, params, batch, n_requests, max_new,
               prompt_lens, rate_per_s: float = 0.0, seed: int = 0) -> dict:
    from repro.launch.serve import run_trace
    from repro.serve import SamplingParams

    args = _Args(engine=engine, batch=batch, strategy="greedy",
                 prompt_lens=tuple(prompt_lens), max_pending=None,
                 n_requests=n_requests, rate=rate_per_s, max_new=max_new,
                 seed=seed)
    return run_trace(cfg, params, args, sp=SamplingParams())


def run(smoke: bool = False) -> list[dict]:
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params

    kw = _smoke_args() if smoke else _full_args()
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    rows = []
    direct_tok_s = None
    for engine in ("direct", "hypar"):
        s = run_engine(engine, cfg=cfg, params=params, **kw)
        overhead = 0.0
        if engine == "direct":
            direct_tok_s = s["tok_per_s"]
        elif direct_tok_s and s["tok_per_s"] > 0:
            overhead = (direct_tok_s / s["tok_per_s"] - 1.0) * 100.0
        rows.append(bench_row(
            f"serve_{engine}", (kw["batch"], kw["max_new"]), "int32",
            s["lat_p50_s"],
            tok_per_s=s["tok_per_s"],
            ttft_p50_s=s["ttft_p50_s"], ttft_p95_s=s["ttft_p95_s"],
            lat_p50_s=s["lat_p50_s"], lat_p95_s=s["lat_p95_s"],
            occupancy=s["occupancy"], n_requests=s["n_requests"],
            gen_tokens=s["gen_tokens"], overhead_pct=overhead))
    return rows
