"""Framework-vs-tailored on the LM workload (the paper's Fig. 3 experiment
shape applied to this framework's primary domain).

Tailored = one fused jitted train step (grad accumulation inside).
Framework = the same optimisation expressed as a HyPar job graph (GRAD
microbatch jobs with no_send_back + OPT job) on the LocalExecutor.
Numerical equivalence is asserted; the reported number is overhead %.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLMStream
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec
from repro.train import HyParTrainer, TrainState, make_train_step

CFG = ModelConfig(name="bench-lm", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=4096,
                  compute_dtype="float32")


def run(steps: int = 10, n_micro: int = 2, batch: int = 8, seq: int = 128):
    spec = OptimizerSpec(kind="adamw", lr=1e-3)
    dc = DataConfig(global_batch=batch, seq_len=seq)
    stream = SyntheticLMStream(CFG, dc)
    batches_host = [stream.batch(s) for s in range(steps)]

    # tailored: fused jit
    step = jax.jit(make_train_step(CFG, spec, grad_accum=n_micro))
    state = TrainState.create(CFG, spec, jax.random.PRNGKey(0))
    b0 = jax.tree.map(jnp.asarray, batches_host[0])
    state, _ = step(state, b0)                       # compile
    state = TrainState.create(CFG, spec, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    for b in batches_host:
        state, m = step(state, jax.tree.map(jnp.asarray, b))
    jax.block_until_ready(state.params)
    t_tailored = time.perf_counter() - t0

    # framework: HyPar scheduled
    mb = batch // n_micro
    hp_batches = [[{k: jnp.asarray(v[i * mb:(i + 1) * mb]) for k, v in b.items()}
                   for i in range(n_micro)] for b in batches_host]
    trainer = HyParTrainer(CFG, spec, n_micro=n_micro)
    t0 = time.perf_counter()
    fp, fo, report = trainer.run(hp_batches, key=jax.random.PRNGKey(0))
    t_hypar = time.perf_counter() - t0

    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(fp), jax.tree.leaves(state.params)))
    overhead = 100.0 * (t_hypar / t_tailored - 1.0)
    print(f"LM train {steps} steps: tailored {t_tailored:.2f}s | "
          f"hypar {t_hypar:.2f}s ({overhead:+.1f}%) | param diff {d:.1e} | "
          f"{report.summary()}")
    return {"tailored_s": t_tailored, "hypar_s": t_hypar,
            "overhead_pct": overhead, "param_diff": d}


if __name__ == "__main__":
    run()
